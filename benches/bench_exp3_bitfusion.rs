//! End-to-end bench for experiment 3 (paper Tables 7-8 / Figs. 9-10):
//! Bitfusion search throughput, the bit-brick speedup model, and the
//! beacon retraining step cost (the expensive operation Algorithm 1
//! rations). The hermetic sections (bit-brick model, surrogate search)
//! feed the bench-gate JSON report; the retraining and artifact-backed
//! search parts need the AOT bundle and are skipped without it.

use std::sync::Arc;

use mohaq::coordinator::{ExperimentSpec, ScoredObjective, SearchSession, Trainer};
use mohaq::hw::{bitfusion::Bitfusion, Platform};
use mohaq::model::ModelDesc;
use mohaq::quant::{Bits, QuantConfig};
use mohaq::runtime::{Artifacts, Runtime};
use mohaq::util::bench::Bencher;
use mohaq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new(100, 1500, 1_000_000);
    println!("== bitfusion model micro-benchmarks (paper-dims model) ==");
    let model = ModelDesc::paper();
    let bf = Bitfusion::paper_experiment();
    let mut rng = Rng::new(5);
    let qcs: Vec<QuantConfig> = (0..64)
        .map(|_| QuantConfig {
            w_bits: (0..8).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect(),
            a_bits: (0..8).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect(),
        })
        .collect();
    let mut i = 0;
    b.bench("bitfusion speedup (bit-brick Eq.4)", || {
        i = (i + 1) % qcs.len();
        bf.speedup(&model, &qcs[i])
    });
    b.bench("beacon distance (8 layers)", || {
        i = (i + 1) % qcs.len();
        qcs[i].beacon_distance(&qcs[(i + 7) % qcs.len()])
    });
    b.emit_json("exp3_bitfusion_model")?;

    // Hermetic end-to-end search throughput: the full NSGA-II loop over
    // the surrogate evaluator (synthetic artifacts), micro-batched PTQ
    // eval included — the searches/s trajectory the bench gate tracks.
    println!("\n== hermetic surrogate search throughput ==");
    let spec = ExperimentSpec::builder()
        .name("bench-surrogate-search")
        .platform("bitfusion")
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(16)
        .initial_pop_size(24)
        .generations(6)
        .seed(0xCAFE)
        .err_feasible_pp(35.0)
        .build()?;
    let session = SearchSession::synthetic()?;
    let once = session.run(&spec)?;
    let mut hb = Bencher::new(100, 1500, 50);
    hb.bench_items(
        "surrogate search (6 gens, pop 16)",
        once.evaluations as u64,
        || session.run(&spec).unwrap().rows.len(),
    );
    hb.emit_json("exp3_surrogate_search")?;

    let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("\nbench_exp3: no artifacts at {dir}; skipping end-to-end parts");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let arts = Arc::new(Artifacts::load(&dir)?);

    // Beacon retraining step cost (binary-connect SGD via AOT train step).
    let mut trainer = Trainer::new(&rt, arts.clone(), 7)?;
    let qc2 = QuantConfig::uniform(arts.layer_names.len(), Bits::B2, Bits::B8);
    let mut bench = Bencher::new(200, 2500, 1000);
    println!("\n== beacon retraining cost ==");
    let weights = arts.weights.clone();
    bench.bench("binary-connect train step (batch 32)", || {
        trainer.retrain(&weights, &qc2, 1, 1e-3).unwrap().1.wall_secs
    });

    println!("\n== bench_exp3: Bitfusion search, inference-only (scaled: 5 gens) ==");
    let mut spec = ExperimentSpec::exp3_bitfusion(false);
    spec.ga.generations = 5;
    let t0 = std::time::Instant::now();
    let session = SearchSession::with_runtime(arts.clone(), rt)?;
    let outcome = session.run(&spec)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "evaluations {:>6} ({:.1}/s)   execs {:>6}   pareto {}   wall {:.1}s",
        outcome.evaluations,
        outcome.evaluations as f64 / secs,
        outcome.exec_calls,
        outcome.rows.len(),
        secs
    );
    let best_sp = outcome.rows.iter().filter_map(|r| r.speedup).fold(0.0, f64::max);
    println!("max speedup {best_sp:.1}x (paper reaches 40.7x inference-only)");

    // Beacon-enabled search: exercises plan_batch + pool-parallel beacon
    // retraining (forked RNG streams) end-to-end at a scaled gen count.
    println!("\n== bench_exp3: Bitfusion search with beacons (scaled: 3 gens) ==");
    let mut bspec = ExperimentSpec::exp3_bitfusion(true);
    bspec.ga.generations = 3;
    let t0 = std::time::Instant::now();
    let outcome = session.run(&bspec)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "evaluations {:>6} ({:.1}/s)   execs {:>6}   pareto {}   wall {:.1}s",
        outcome.evaluations,
        outcome.evaluations as f64 / secs,
        outcome.exec_calls,
        outcome.rows.len(),
        secs
    );
    Ok(())
}
