//! End-to-end bench for experiment 2 (paper Table 6 / Fig. 8): the
//! 3-objective SiLago search, plus micro-benches of the analytical
//! hardware objectives (Eq. 3 / Eq. 4) that price every candidate.

use std::sync::Arc;

use mohaq::coordinator::{ExperimentSpec, SearchSession};
use mohaq::hw::{silago::SiLago, Platform};
use mohaq::model::ModelDesc;
use mohaq::quant::{Bits, QuantConfig};
use mohaq::runtime::{Artifacts, Runtime};
use mohaq::util::bench::Bencher;
use mohaq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::new(100, 1500, 1_000_000);
    println!("== hardware-objective micro-benchmarks (paper-dims model) ==");
    let model = ModelDesc::paper();
    let silago = SiLago::paper_experiment();
    let mut rng = Rng::new(3);
    let mut qcs = Vec::new();
    for _ in 0..64 {
        let bits: Vec<Bits> = (0..8)
            .map(|_| *rng.choose(&[Bits::B4, Bits::B8, Bits::B16]))
            .collect();
        qcs.push(QuantConfig { w_bits: bits.clone(), a_bits: bits });
    }
    let mut i = 0;
    b.bench("silago speedup (Eq.4)", || {
        i = (i + 1) % qcs.len();
        silago.speedup(&model, &qcs[i])
    });
    b.bench("silago energy (Eq.3)", || {
        i = (i + 1) % qcs.len();
        silago.energy_pj(&model, &qcs[i]).unwrap()
    });
    b.bench("sram violation + size", || {
        i = (i + 1) % qcs.len();
        silago.sram_violation(&model, &qcs[i])
    });

    let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("\nbench_exp2: no artifacts at {dir}; skipping end-to-end search");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let arts = Arc::new(Artifacts::load(&dir)?);
    let session = SearchSession::with_runtime(arts.clone(), rt)?;

    println!("\n== bench_exp2: SiLago 3-objective search (scaled: 5 generations) ==");
    let mut spec = ExperimentSpec::exp2_silago();
    spec.ga.generations = 5;
    let t0 = std::time::Instant::now();
    let outcome = session.run(&spec)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "evaluations {:>6} ({:.1}/s)   execs {:>6}   pareto {}   wall {:.1}s",
        outcome.evaluations,
        outcome.evaluations as f64 / secs,
        outcome.exec_calls,
        outcome.rows.len(),
        secs
    );
    let best_sp = outcome.rows.iter().filter_map(|r| r.speedup).fold(0.0, f64::max);
    let min_e = outcome
        .rows
        .iter()
        .filter_map(|r| r.energy_uj)
        .fold(f64::INFINITY, f64::min);
    println!("max speedup {best_sp:.2}x   min energy {min_e:.4} uJ");
    Ok(())
}
