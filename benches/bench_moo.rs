//! GA-engine micro/ablation benches: non-dominated sort, crowding,
//! hypervolume, one NSGA-II generation, and the NSGA-II-vs-baselines
//! quality ablation (hypervolume at equal evaluation budgets) that backs
//! the paper's §1 claim that a MOOP search beats single-objective runs.

use mohaq::moo::baselines::{random_search, weighted_sum_ga};
use mohaq::moo::problems::{Zdt, ZdtVariant};
use mohaq::moo::sort::{assign_crowding, fast_nondominated_sort};
use mohaq::moo::{Individual, IslandConfig, IslandModel, Nsga2, Nsga2Config, Topology};
use mohaq::pareto::crowding_distances;
use mohaq::pareto::hypervolume::{hypervolume_2d, hypervolume_3d};
use mohaq::util::bench::Bencher;
use mohaq::util::rng::Rng;

fn random_pop(n: usize, m: usize, seed: u64) -> Vec<Individual> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut ind = Individual::new(vec![]);
            ind.objectives = (0..m).map(|_| rng.f64()).collect();
            ind
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new(100, 1500, 100_000);
    println!("== moo engine micro-benchmarks ==");

    for &n in &[100usize, 400, 1000] {
        let pop = random_pop(n, 2, 1);
        b.bench(&format!("fast_nondominated_sort n={n} m=2"), || {
            let mut p = pop.clone();
            fast_nondominated_sort(&mut p).len()
        });
    }
    let pop3 = random_pop(400, 3, 2);
    b.bench("fast_nondominated_sort n=400 m=3", || {
        let mut p = pop3.clone();
        fast_nondominated_sort(&mut p).len()
    });

    let pts2: Vec<Vec<f64>> = random_pop(500, 2, 3).into_iter().map(|i| i.objectives).collect();
    b.bench("crowding_distances n=500", || crowding_distances(&pts2));
    b.bench("hypervolume_2d n=500", || hypervolume_2d(&pts2, &[1.1, 1.1]));
    let pts3: Vec<Vec<f64>> = random_pop(200, 3, 4).into_iter().map(|i| i.objectives).collect();
    b.bench("hypervolume_3d n=200", || {
        hypervolume_3d(&pts3, &[1.1, 1.1, 1.1])
    });

    b.bench("sort+crowding pipeline n=400", || {
        let mut p = random_pop(400, 2, 5);
        let fronts = fast_nondominated_sort(&mut p);
        assign_crowding(&mut p, &fronts);
    });

    b.bench_items("nsga2 zdt1 60gens pop40 (full run)", 40 + 60 * 40, || {
        let mut problem = Zdt::new(ZdtVariant::Zdt1, 12, 64);
        let mut algo = Nsga2::new(Nsga2Config {
            pop_size: 40,
            initial_pop_size: 40,
            generations: 60,
            seed: 7,
            ..Default::default()
        });
        algo.run(&mut problem, |_| {}).len()
    });

    // Island-model engine overhead (migration + merge bookkeeping on top
    // of the same evaluation count as a 4x10 archipelago).
    b.bench_items("island 4x ring zdt1 30gens pop10/isl", 4 * (10 + 30 * 10), || {
        let mut problem = Zdt::new(ZdtVariant::Zdt1, 12, 64);
        let mut model = IslandModel::new(
            Nsga2Config {
                pop_size: 10,
                initial_pop_size: 10,
                generations: 30,
                seed: 7,
                ..Default::default()
            },
            IslandConfig {
                islands: 4,
                migration_interval: 5,
                topology: Topology::Ring,
                migrants: 2,
            },
        );
        model.run(&mut problem, |_| {}).len()
    });

    // ---- Ablation: search quality at equal budgets ----------------------
    println!("\n== ablation: front quality (hypervolume, ZDT1, budget 2440, ref (1.1, 7)) ==");
    let hv_of = |inds: &[Individual]| {
        let pts: Vec<Vec<f64>> = inds.iter().map(|i| i.objectives.clone()).collect();
        // ZDT1 random solutions land around f2 ~ 5.5; a (1.1, 7) reference
        // makes the baselines visible instead of scoring zero.
        hypervolume_2d(&pts, &[1.1, 7.0])
    };
    let mut p = Zdt::new(ZdtVariant::Zdt1, 12, 64);
    let mut algo = Nsga2::new(Nsga2Config {
        pop_size: 40,
        initial_pop_size: 40,
        generations: 60,
        seed: 11,
        ..Default::default()
    });
    let nsga_front = Nsga2::pareto_set(&algo.run(&mut p, |_| {}));
    println!(
        "  nsga2          hv = {:.4} ({} solutions)",
        hv_of(&nsga_front),
        nsga_front.len()
    );

    let mut p = Zdt::new(ZdtVariant::Zdt1, 12, 64);
    let rnd = random_search(&mut p, 2440, 11);
    println!("  random search  hv = {:.4}", hv_of(&rnd));

    let mut p = Zdt::new(ZdtVariant::Zdt1, 12, 64);
    let ws = weighted_sum_ga(&mut p, &[0.5, 0.5], 40, 60, 11);
    println!("  weighted-sum   hv = {:.4} (single-objective GA)", hv_of(&ws));

    let mut p = Zdt::new(ZdtVariant::Zdt1, 12, 64);
    let mut model = IslandModel::new(
        Nsga2Config {
            pop_size: 10,
            initial_pop_size: 10,
            generations: 60,
            seed: 11,
            ..Default::default()
        },
        IslandConfig::default(),
    );
    let merged = Nsga2::pareto_set(&model.run(&mut p, |_| {}));
    println!(
        "  island 4x10    hv = {:.4} ({} solutions, {} evals)",
        hv_of(&merged),
        merged.len(),
        model.evaluations()
    );
    println!("\n(the MOOP front should dominate both baselines)");

    b.emit_json("bench_moo").expect("write bench json report");
}
