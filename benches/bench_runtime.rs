//! Runtime hot-path benches: a calibration spin (the normalization anchor
//! for the bench-regression gate), the micro-batched PTQ eval throughput
//! of the surrogate EvalService (hermetic — the headline eval-throughput
//! number `mohaq bench-gate` protects), parallel generation evaluation
//! (1 thread vs one-per-core), then the PJRT inference call (literal vs
//! pre-uploaded-buffer input paths), parameter-set upload, qparam
//! resolution and the full val_error evaluation — the numbers behind
//! EXPERIMENTS.md §Perf L3.
//!
//! The PJRT sections need the AOT artifact bundle; they are skipped with a
//! notice otherwise.

use std::sync::Arc;

use mohaq::eval::{CacheKey, EvalService};
use mohaq::moo::{Evaluation, Parallel, Problem, SyncProblem};
use mohaq::quant::{Bits, QuantConfig};
use mohaq::runtime::{Artifacts, Input, Runtime};
use mohaq::util::bench::Bencher;
use mohaq::util::pool;
use mohaq::util::rng::Rng;

/// Fixed integer spin measured like any other bench: the gate divides
/// every throughput by this file's spin throughput so the verdict
/// compares machine-relative scores, not raw items/s across runners
/// (see util::benchgate).
fn bench_calibration() -> std::io::Result<()> {
    println!("== calibration spin (bench-gate normalization anchor) ==");
    let mut b = Bencher::new(100, 1000, 10_000);
    b.bench_items("calibration spin", 4096, || {
        let mut acc = 0x5eedu64;
        for i in 0..4096u64 {
            acc = acc.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(i);
        }
        acc
    });
    b.emit_json("calibration")
}

/// A deterministic pool of fully-searchable 8-layer candidates (packable
/// cache keys, no B32).
fn candidate_pool(n_layers: usize, count: usize) -> Vec<QuantConfig> {
    let mut rng = Rng::new(0xba7c4);
    (0..count)
        .map(|_| QuantConfig {
            w_bits: (0..n_layers).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect(),
            a_bits: (0..n_layers).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect(),
        })
        .collect()
}

/// Per-candidate `val_error` vs micro-batched `val_error_batch` on the
/// hermetic surrogate engine — the eval-throughput trajectory the gate
/// protects. Cold numbers rebuild the service every iteration (nothing
/// memoized); hot numbers re-score one generation against a warm cache.
fn bench_eval_throughput() -> anyhow::Result<()> {
    println!("\n== EvalService PTQ eval throughput (hermetic surrogate) ==");
    let arts = Arc::new(Artifacts::synthetic());
    let n = arts.layer_names.len();
    let pool = candidate_pool(n, 64);
    let mut b = Bencher::new(150, 1500, 5_000);

    b.bench_items("val_error x64 (per-candidate, cold)", 64, || {
        let svc = EvalService::surrogate(arts.clone()).unwrap();
        pool.iter().map(|qc| svc.val_error(qc, 0).unwrap()).sum::<f64>()
    });
    b.bench_items("val_error_batch x64 (cold)", 64, || {
        let svc = EvalService::surrogate(arts.clone()).unwrap();
        svc.val_error_batch(&pool, 0).unwrap()
    });

    let warm = EvalService::surrogate(arts.clone())?;
    warm.val_error_batch(&pool, 0)?;
    b.bench_items("val_error x64 (per-candidate, cache-hot)", 64, || {
        pool.iter().map(|qc| warm.val_error(qc, 0).unwrap()).sum::<f64>()
    });
    b.bench_items("val_error_batch x64 (cache-hot)", 64, || {
        warm.val_error_batch(&pool, 0).unwrap()
    });

    // Cache-key construction: packed (usize, u64, u64) vs the wide
    // clone-both-gene-vectors representation it replaced.
    b.bench_items("CacheKey x64 (packed u64 genes)", 64, || {
        pool.iter()
            .map(|qc| match CacheKey::new(0, qc) {
                CacheKey::Packed(s, w, a) => s as u64 ^ w ^ a,
                CacheKey::Wide(s, w, _) => s as u64 ^ w.len() as u64,
            })
            .fold(0u64, u64::wrapping_add)
    });
    b.bench_items("CacheKey x64 (wide clone baseline)", 64, || {
        pool.iter()
            .map(|qc| {
                let k = CacheKey::Wide(0, qc.w_bits.clone(), qc.a_bits.clone());
                match &k {
                    CacheKey::Wide(_, w, a) => (w.len() + a.len()) as u64,
                    CacheKey::Packed(..) => 0,
                }
            })
            .fold(0u64, u64::wrapping_add)
    });

    // Qparam resolution on the hot path: the dense [layer][bits] table.
    // (The string-keyed BTreeMap formulation it replaced is now a
    // test-only oracle in quant::, no longer benched.)
    b.bench_items("QparamTable::resolve x64 (dense rows)", 64, || {
        pool.iter().map(|qc| arts.qtable.resolve(qc).unwrap().0[0]).sum::<f32>()
    });

    b.emit_json("eval_throughput")?;
    Ok(())
}

/// Stand-in for one candidate evaluation: a genome-dependent compute spin
/// roughly shaped like a small inference call, so the 1-vs-N-thread ratio
/// reflects real generation-evaluation scaling.
struct SyntheticEval {
    spin: u64,
}

impl SyncProblem for SyntheticEval {
    fn vars(&self) -> usize {
        16
    }
    fn objectives(&self) -> usize {
        2
    }
    fn gene_range(&self, _i: usize) -> (i64, i64) {
        (1, 4)
    }
    fn eval(&self, genome: &[i64]) -> Evaluation {
        let mut acc = 0x5eedu64;
        for _ in 0..self.spin {
            for &g in genome {
                acc = acc.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(g as u64);
            }
        }
        let h = std::hint::black_box(acc);
        let f1 = (h % 1000) as f64 / 1000.0;
        Evaluation { objectives: vec![f1, 1.0 - f1], violation: 0.0 }
    }
}

/// 1-thread vs N-thread evaluation of one generation (pop 40), tracking
/// the SearchSession speedup in the perf trajectory.
fn bench_parallel_eval(b: &mut Bencher) {
    println!("== parallel generation evaluation (hermetic) ==");
    let problem = SyntheticEval { spin: 12_000 };
    let genomes: Vec<Vec<i64>> = (0..40)
        .map(|i| (0..16).map(|j| 1 + ((i + j) % 4) as i64).collect())
        .collect();
    let threads = pool::default_threads();

    let r1 = b
        .bench_items("generation eval, 1 thread (pop 40)", 40, || {
            Parallel::new(&problem, 1).evaluate_batch(&genomes)
        })
        .mean_ns;
    let rn = b
        .bench_items(
            &format!("generation eval, {threads} threads (pop 40)"),
            40,
            || Parallel::new(&problem, threads).evaluate_batch(&genomes),
        )
        .mean_ns;
    println!("parallel eval speedup: {:.2}x on {threads} threads\n", r1 / rn);
}

fn main() -> anyhow::Result<()> {
    bench_calibration()?;
    bench_eval_throughput()?;
    let mut hb = Bencher::new(200, 2000, 10_000);
    bench_parallel_eval(&mut hb);
    hb.emit_json("bench_runtime_parallel_eval")?;

    let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("bench_runtime: no artifacts at {dir}; skipping the PJRT sections");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let arts = Arc::new(Artifacts::load(&dir)?);
    let mut b = Bencher::new(300, 3000, 10_000);
    println!("== runtime hot-path benchmarks ==");

    b.bench("Artifacts::load (full bundle)", || Artifacts::load(&dir).unwrap());

    let exec = rt.load(arts.hlo_path("infer")?)?;
    let n = arts.layer_names.len();
    let qc = QuantConfig::uniform(n, Bits::B4, Bits::B8);
    b.bench("QparamTable::resolve (8 layers)", || arts.qtable.resolve(&qc).unwrap());

    // One inference batch, literal path (weights re-uploaded every call).
    let (wq, aq) = arts.qtable.resolve(&qc)?;
    let (bsz, t, f) = (arts.batch, arts.seq_len, arts.feat_dim);
    let split = &arts.val_subsets[0];
    let (x, y) = split.batch(0, bsz, t, f);
    let shapes: Vec<Vec<i64>> = arts
        .tensors
        .iter()
        .map(|i| i.shape.iter().map(|&d| d as i64).collect())
        .collect();
    let frames = (bsz * t) as u64;

    b.bench_items("infer batch (all-literal inputs)", frames, || {
        let mut inputs: Vec<Input> = Vec::with_capacity(arts.weights.len() + 4);
        for (data, shape) in arts.weights.iter().zip(&shapes) {
            inputs.push(Input::F32(data, shape.clone()));
        }
        inputs.push(Input::F32(&wq, vec![n as i64, 4]));
        inputs.push(Input::F32(&aq, vec![n as i64, 4]));
        inputs.push(Input::F32(x, vec![bsz as i64, t as i64, f as i64]));
        inputs.push(Input::I32(y, vec![bsz as i64, t as i64]));
        exec.run_literals(&inputs).unwrap()
    });

    // Same batch, weights resident on device (the production path).
    let statics: Vec<_> = arts
        .weights
        .iter()
        .zip(&shapes)
        .map(|(data, shape)| exec.upload(&Input::F32(data, shape.clone())).unwrap())
        .collect();
    b.bench_items("infer batch (device-resident weights)", frames, || {
        let fresh = [
            Input::F32(&wq, vec![n as i64, 4]),
            Input::F32(&aq, vec![n as i64, 4]),
            Input::F32(x, vec![bsz as i64, t as i64, f as i64]),
            Input::I32(y, vec![bsz as i64, t as i64]),
        ];
        exec.run_mixed(&statics, &fresh).unwrap()
    });

    // One-shot: param-set upload cost (kept alive afterwards — PJRT CPU
    // aborts if buffers with in-flight transfers are freed in a tight
    // alloc/free loop, so this is measured once, not in a loop).
    let t0 = std::time::Instant::now();
    let kept: Vec<_> = arts
        .weights
        .iter()
        .zip(&shapes)
        .map(|(data, shape)| exec.upload(&Input::F32(data, shape.clone())).unwrap())
        .collect();
    println!(
        "{:<48} {:>10.2} µs one-shot ({} tensors)",
        "upload full param set",
        t0.elapsed().as_secs_f64() * 1e6,
        kept.len()
    );

    // Full candidate evaluation (4 subsets, max rule) through EvalService.
    let svc = EvalService::new(&rt, arts.clone())?;
    let mut rng = mohaq::util::rng::Rng::new(0xeea1);
    let mut bc = Bencher::new(300, 4000, 12);
    bc.bench("EvalService::val_error (uncached candidate)", || {
        // Fresh random genome every iteration: never hits the cache.
        let w: Vec<Bits> = (0..n).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect();
        let a: Vec<Bits> = (0..n).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect();
        svc.val_error(&QuantConfig { w_bits: w, a_bits: a }, 0).unwrap()
    });
    let qc_fixed = QuantConfig::uniform(n, Bits::B8, Bits::B8);
    svc.val_error(&qc_fixed, 0)?;
    b.bench("EvalService::val_error (cache hit)", || {
        svc.val_error(&qc_fixed, 0).unwrap()
    });

    println!("\nstats: {:?} execs", svc.stats().executions);

    // L2 graph comparison: interpret-mode Pallas lowering vs the pure-jnp
    // lowering of the SAME computation (numerics pytest-identical).
    if std::path::Path::new(&dir).join("infer_ref.hlo.txt").exists() {
        println!("\n== L2 graph comparison (one inference batch) ==");
        let exec_ref = rt.load(arts.hlo_path("infer_ref")?)?;
        let mut bg = Bencher::new(300, 4000, 60);
        bg.bench_items("infer batch (pallas graph)", frames, || {
            let mut inputs: Vec<Input> = Vec::with_capacity(arts.weights.len() + 4);
            for (data, shape) in arts.weights.iter().zip(&shapes) {
                inputs.push(Input::F32(data, shape.clone()));
            }
            inputs.push(Input::F32(&wq, vec![n as i64, 4]));
            inputs.push(Input::F32(&aq, vec![n as i64, 4]));
            inputs.push(Input::F32(x, vec![bsz as i64, t as i64, f as i64]));
            inputs.push(Input::I32(y, vec![bsz as i64, t as i64]));
            exec.run_literals(&inputs).unwrap()
        });
        bg.bench_items("infer batch (pure-jnp graph)", frames, || {
            let mut inputs: Vec<Input> = Vec::with_capacity(arts.weights.len() + 4);
            for (data, shape) in arts.weights.iter().zip(&shapes) {
                inputs.push(Input::F32(data, shape.clone()));
            }
            inputs.push(Input::F32(&wq, vec![n as i64, 4]));
            inputs.push(Input::F32(&aq, vec![n as i64, 4]));
            inputs.push(Input::F32(x, vec![bsz as i64, t as i64, f as i64]));
            inputs.push(Input::I32(y, vec![bsz as i64, t as i64]));
            exec_ref.run_literals(&inputs).unwrap()
        });
        bg.emit_json("bench_runtime_l2_graphs")?;
    }

    b.emit_json("bench_runtime_pjrt")?;
    bc.emit_json("bench_runtime_val_error")?;
    Ok(())
}
