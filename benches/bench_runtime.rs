//! Runtime hot-path benches: the PJRT inference call (literal vs
//! pre-uploaded-buffer input paths), parameter-set upload, qparam
//! resolution and the full val_error evaluation — the numbers behind
//! EXPERIMENTS.md §Perf L3.
//!
//! Needs `make artifacts`; exits 0 with a notice otherwise.

use std::rc::Rc;

use mohaq::eval::EvalService;
use mohaq::quant::{resolve_qparams, Bits, QuantConfig};
use mohaq::runtime::{Artifacts, Input, Runtime};
use mohaq::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        println!("bench_runtime: no artifacts at {dir}; run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let arts = Rc::new(Artifacts::load(&dir)?);
    let mut b = Bencher::new(300, 3000, 10_000);
    println!("== runtime hot-path benchmarks ==");

    b.bench("Artifacts::load (full bundle)", || Artifacts::load(&dir).unwrap());

    let exec = rt.load(arts.hlo_path("infer")?)?;
    let n = arts.layer_names.len();
    let qc = QuantConfig::uniform(n, Bits::B4, Bits::B8);
    b.bench("resolve_qparams (8 layers)", || {
        resolve_qparams(&qc, &arts.layer_names, &arts.w_clips, &arts.a_clips).unwrap()
    });

    // One inference batch, literal path (weights re-uploaded every call).
    let (wq, aq) = resolve_qparams(&qc, &arts.layer_names, &arts.w_clips, &arts.a_clips)?;
    let (bsz, t, f) = (arts.batch, arts.seq_len, arts.feat_dim);
    let split = &arts.val_subsets[0];
    let (x, y) = split.batch(0, bsz, t, f);
    let shapes: Vec<Vec<i64>> = arts
        .tensors
        .iter()
        .map(|i| i.shape.iter().map(|&d| d as i64).collect())
        .collect();
    let frames = (bsz * t) as u64;

    b.bench_items("infer batch (all-literal inputs)", frames, || {
        let mut inputs: Vec<Input> = Vec::with_capacity(arts.weights.len() + 4);
        for (data, shape) in arts.weights.iter().zip(&shapes) {
            inputs.push(Input::F32(data, shape.clone()));
        }
        inputs.push(Input::F32(&wq, vec![n as i64, 4]));
        inputs.push(Input::F32(&aq, vec![n as i64, 4]));
        inputs.push(Input::F32(x, vec![bsz as i64, t as i64, f as i64]));
        inputs.push(Input::I32(y, vec![bsz as i64, t as i64]));
        exec.run_literals(&inputs).unwrap()
    });

    // Same batch, weights resident on device (the production path).
    let statics: Vec<_> = arts
        .weights
        .iter()
        .zip(&shapes)
        .map(|(data, shape)| exec.upload(&Input::F32(data, shape.clone())).unwrap())
        .collect();
    b.bench_items("infer batch (device-resident weights)", frames, || {
        let fresh = [
            Input::F32(&wq, vec![n as i64, 4]),
            Input::F32(&aq, vec![n as i64, 4]),
            Input::F32(x, vec![bsz as i64, t as i64, f as i64]),
            Input::I32(y, vec![bsz as i64, t as i64]),
        ];
        exec.run_mixed(&statics, &fresh).unwrap()
    });

    // One-shot: param-set upload cost (kept alive afterwards — PJRT CPU
    // aborts if buffers with in-flight transfers are freed in a tight
    // alloc/free loop, so this is measured once, not in a loop).
    let t0 = std::time::Instant::now();
    let kept: Vec<_> = arts
        .weights
        .iter()
        .zip(&shapes)
        .map(|(data, shape)| exec.upload(&Input::F32(data, shape.clone())).unwrap())
        .collect();
    println!(
        "{:<48} {:>10.2} µs one-shot ({} tensors)",
        "upload full param set",
        t0.elapsed().as_secs_f64() * 1e6,
        kept.len()
    );

    // Full candidate evaluation (4 subsets, max rule) through EvalService.
    let mut svc = EvalService::new(&rt, arts.clone())?;
    let mut rng = mohaq::util::rng::Rng::new(0xeea1);
    let mut bc = Bencher::new(300, 4000, 12);
    bc.bench("EvalService::val_error (uncached candidate)", || {
        // Fresh random genome every iteration: never hits the cache.
        let w: Vec<Bits> = (0..n).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect();
        let a: Vec<Bits> = (0..n).map(|_| *rng.choose(&Bits::SEARCHABLE)).collect();
        svc.val_error(&QuantConfig { w_bits: w, a_bits: a }, 0).unwrap()
    });
    let qc_fixed = QuantConfig::uniform(n, Bits::B8, Bits::B8);
    svc.val_error(&qc_fixed, 0)?;
    b.bench("EvalService::val_error (cache hit)", || {
        svc.val_error(&qc_fixed, 0).unwrap()
    });

    println!("\nstats: {:?} execs", svc.stats().executions);

    // L2 graph comparison: interpret-mode Pallas lowering vs the pure-jnp
    // lowering of the SAME computation (numerics pytest-identical).
    if std::path::Path::new(&dir).join("infer_ref.hlo.txt").exists() {
        println!("\n== L2 graph comparison (one inference batch) ==");
        let exec_ref = rt.load(arts.hlo_path("infer_ref")?)?;
        let mut bg = Bencher::new(300, 4000, 60);
        bg.bench_items("infer batch (pallas graph)", frames, || {
            let mut inputs: Vec<Input> = Vec::with_capacity(arts.weights.len() + 4);
            for (data, shape) in arts.weights.iter().zip(&shapes) {
                inputs.push(Input::F32(data, shape.clone()));
            }
            inputs.push(Input::F32(&wq, vec![n as i64, 4]));
            inputs.push(Input::F32(&aq, vec![n as i64, 4]));
            inputs.push(Input::F32(x, vec![bsz as i64, t as i64, f as i64]));
            inputs.push(Input::I32(y, vec![bsz as i64, t as i64]));
            exec.run_literals(&inputs).unwrap()
        });
        bg.bench_items("infer batch (pure-jnp graph)", frames, || {
            let mut inputs: Vec<Input> = Vec::with_capacity(arts.weights.len() + 4);
            for (data, shape) in arts.weights.iter().zip(&shapes) {
                inputs.push(Input::F32(data, shape.clone()));
            }
            inputs.push(Input::F32(&wq, vec![n as i64, 4]));
            inputs.push(Input::F32(&aq, vec![n as i64, 4]));
            inputs.push(Input::F32(x, vec![bsz as i64, t as i64, f as i64]));
            inputs.push(Input::I32(y, vec![bsz as i64, t as i64]));
            exec_ref.run_literals(&inputs).unwrap()
        });
    }
    Ok(())
}
