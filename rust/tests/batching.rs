//! Equivalence guarantees for the micro-batched eval path (hermetic —
//! surrogate engine over synthetic artifacts):
//!
//! 1. `EvalService::val_error_batch` is BITWISE-identical to scoring the
//!    same candidates one `val_error` call at a time, for arbitrary batch
//!    geometry including duplicates and unpackable (B32) cache keys —
//!    and the services end in the same observable state (same execution
//!    and memoization counts, duplicates counted as cache hits).
//! 2. Whole searches reproduce the SAME front bitwise for any evaluation
//!    backend geometry: 1 thread, N threads, or a shared serve-mode
//!    WorkQueue. Batching may only change the wall clock.

use std::sync::Arc;

use mohaq::coordinator::{ExperimentSpec, ScoredObjective, SearchOutcome, SearchSession};
use mohaq::eval::EvalService;
use mohaq::quant::{Bits, QuantConfig};
use mohaq::runtime::Artifacts;
use mohaq::util::pool::WorkQueue;
use mohaq::util::prop::check_prop;
use mohaq::util::rng::Rng;

/// Random batch: 1..=24 candidates over every precision (B32 included so
/// some cache keys take the Wide fallback), with a forced duplicate run
/// so the dedup-and-fan-out path sees repeated keys.
fn gen_batch(rng: &mut Rng) -> Vec<QuantConfig> {
    let n_layers = Artifacts::synthetic().layer_names.len();
    let all = [Bits::B2, Bits::B4, Bits::B8, Bits::B16, Bits::B32];
    let len = 1 + rng.below(24);
    let mut batch: Vec<QuantConfig> = (0..len)
        .map(|_| QuantConfig {
            w_bits: (0..n_layers).map(|_| *rng.choose(&all)).collect(),
            a_bits: (0..n_layers).map(|_| *rng.choose(&all)).collect(),
        })
        .collect();
    // Duplicate a random prefix element to a random later slot.
    if len > 1 {
        let src = rng.below(len);
        let dst = rng.below(len);
        batch[dst] = batch[src].clone();
    }
    batch
}

#[test]
fn val_error_batch_is_bitwise_identical_to_sequential() {
    let arts = Arc::new(Artifacts::synthetic());
    check_prop(
        "val_error_batch == sequential val_error",
        60,
        gen_batch,
        |batch| {
            // Fresh services so cold-cache behavior is compared too.
            let seq = EvalService::surrogate(arts.clone()).map_err(|e| e.to_string())?;
            let bat = EvalService::surrogate(arts.clone()).map_err(|e| e.to_string())?;
            let want: Vec<f64> = batch
                .iter()
                .map(|qc| seq.val_error(qc, 0).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;
            let got = bat.val_error_batch(batch, 0).map_err(|e| e.to_string())?;
            if want.len() != got.len() {
                return Err(format!("length mismatch: {} vs {}", want.len(), got.len()));
            }
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                if w.to_bits() != g.to_bits() {
                    return Err(format!("candidate {i}: sequential {w} != batched {g}"));
                }
            }
            // Same executions, same memoized keys, duplicates as hits.
            if seq.stats() != bat.stats() {
                return Err(format!(
                    "service state diverged: sequential {:?} vs batched {:?}",
                    seq.stats(),
                    bat.stats()
                ));
            }
            Ok(())
        },
    );
}

fn spec() -> ExperimentSpec {
    ExperimentSpec::builder()
        .name("batch-front-identity")
        .platform("bitfusion")
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(8)
        .initial_pop_size(12)
        .generations(4)
        .seed(0xCAFE)
        .err_feasible_pp(35.0)
        .build()
        .unwrap()
}

/// Everything observable about a front, with errors as raw bits.
fn fingerprint(o: &SearchOutcome) -> Vec<(String, u64, u64, String)> {
    o.rows
        .iter()
        .map(|r| (r.qc.display_wa(), r.wer_v.to_bits(), r.wer_t.to_bits(), r.param_set.clone()))
        .collect()
}

#[test]
fn front_is_bitwise_identical_across_eval_backends() {
    let spec = spec();
    let reference = SearchSession::synthetic().unwrap().threads(1).run(&spec).unwrap();
    assert!(!reference.rows.is_empty(), "degenerate reference front");

    for threads in [3, 8] {
        let got = SearchSession::synthetic().unwrap().threads(threads).run(&spec).unwrap();
        assert_eq!(fingerprint(&reference), fingerprint(&got), "{threads} threads");
        assert_eq!(reference.evaluations, got.evaluations, "{threads} threads");
        // Batching dedups identically, so the unique-miss count (device
        // executions) must match the sequential run exactly.
        assert_eq!(reference.exec_calls, got.exec_calls, "{threads} threads");
    }

    // Serve-mode geometry: candidate chunks submitted to a shared queue.
    let queue = Arc::new(WorkQueue::new(2));
    let got = SearchSession::synthetic().unwrap().shared_queue(queue).run(&spec).unwrap();
    assert_eq!(fingerprint(&reference), fingerprint(&got), "shared queue");
    assert_eq!(reference.evaluations, got.evaluations, "shared queue");
    assert_eq!(reference.exec_calls, got.exec_calls, "shared queue");
}
