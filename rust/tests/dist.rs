//! Distributed island-sharding integration tests — hermetic (surrogate
//! evaluator, no artifacts): a real coordinator driving real worker
//! servers over loopback TCP.
//!
//! Covers the acceptance contracts of the dist tentpole:
//!   * determinism — fixed seed + fixed shard map produce a merged front
//!     bitwise-identical to the single-process `IslandModel` run of the
//!     same spec, for ring AND fully-connected topologies;
//!   * worker failure — killing a worker process mid-run re-shards its
//!     islands onto the survivors, surfaces a typed `ShardLost` event,
//!     and still completes with the SAME bitwise-identical front
//!     (restore from the last migration snapshot is exact);
//!   * retry exhaustion — losing every worker yields a typed
//!     `SearchError::WorkerLost`, never a panic or a hang;
//!   * beacon replication — a beacon-enabled distributed run (coordinator
//!     selects + retrains at migration boundaries, finalized parameter
//!     sets replicate to every shard via `param_push`) merges a front
//!     bitwise-identical to the single-process beacon run, every worker
//!     replica's param table matches the coordinator's bit-for-bit
//!     (`param_fetch`), and a mid-run worker loss replays the
//!     replication journal onto the survivors with the same front.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use mohaq::coordinator::{
    BeaconPolicyOverrides, CancelToken, ExperimentSpec, ScoredObjective, SearchEvent,
    SearchOutcome, SearchSession,
};
use mohaq::dist::DistConfig;
use mohaq::moo::{IslandConfig, Topology};
use mohaq::serve::{ServeClient, ServeState, Server};

/// Start a hermetic worker server on an ephemeral port; returns its
/// address and the accept-loop thread (joined to assert clean shutdown).
fn spawn_worker() -> (SocketAddr, std::thread::JoinHandle<std::io::Result<()>>) {
    let state = ServeState::worker(SearchSession::synthetic().unwrap(), 2);
    let server = Server::bind("127.0.0.1:0", state).unwrap();
    let addr = server.local_addr().unwrap();
    (addr, std::thread::spawn(move || server.run()))
}

/// Shut one worker down the way an operator (or a fault) would: a
/// shutdown frame on a fresh connection. The worker's heartbeat thread
/// notices, cancels any in-flight shard advance, and tears its sockets.
fn stop_worker(addr: SocketAddr) {
    if let Ok(mut s) = TcpStream::connect(addr) {
        let _ = s.write_all(b"{\"op\":\"shutdown\"}\n");
        let _ = s.flush();
        let mut line = String::new();
        let _ = BufReader::new(s).read_line(&mut line);
    }
}

/// The shared fixture spec: 4 islands over the surrogate evaluator. The
/// widened feasibility area keeps the front non-empty for any seed.
fn dist_spec(topology: Topology) -> ExperimentSpec {
    let mut spec = ExperimentSpec::builder()
        .name("dist-silago")
        .platform("silago")
        .sram_mb(6.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(8)
        .initial_pop_size(16)
        .generations(6)
        .seed(0xD157)
        .err_feasible_pp(25.0)
        .build()
        .unwrap();
    spec.island = Some(IslandConfig {
        islands: 4,
        migration_interval: 2,
        topology,
        migrants: 2,
    });
    spec
}

/// The beacon fixture: the dist spec plus a beacon policy sized for the
/// surrogate evaluator — cheap retrains, capped at 2 beacons, default
/// threshold. Boundary elites on the surrogate span the beacon-feasible
/// error band (mid-precision genomes land ~0.15 above the baseline,
/// inside paper_defaults' [base+0.04, base+0.35] create window), so the
/// window pass reliably creates beacons; the tests assert it did.
fn beacon_spec(topology: Topology) -> ExperimentSpec {
    let mut spec = dist_spec(topology);
    spec.name = "dist-silago-beacon".into();
    spec.beacon = Some(BeaconPolicyOverrides {
        threshold: None,
        retrain_steps: Some(6),
        max_beacons: Some(2),
    });
    spec
}

/// The determinism contract, at full strength: same front, bit for bit.
fn assert_fronts_bitwise_equal(dist: &SearchOutcome, local: &SearchOutcome) {
    assert_eq!(dist.objective_names, local.objective_names, "objective labels diverged");
    assert_eq!(dist.evaluations, local.evaluations, "evaluation totals diverged");
    assert_eq!(dist.rows.len(), local.rows.len(), "front size diverged");
    for (d, l) in dist.rows.iter().zip(&local.rows) {
        assert_eq!(d.qc.display_wa(), l.qc.display_wa(), "genomes diverged");
        assert_eq!(d.wer_v.to_bits(), l.wer_v.to_bits(), "wer_v not bitwise equal");
        assert_eq!(d.wer_t.to_bits(), l.wer_t.to_bits(), "wer_t not bitwise equal");
        assert_eq!(d.size_mb.to_bits(), l.size_mb.to_bits());
        assert_eq!(d.hw.len(), l.hw.len());
        for (dh, lh) in d.hw.iter().zip(&l.hw) {
            assert_eq!(dh.platform, lh.platform);
            assert_eq!(dh.speedup.to_bits(), lh.speedup.to_bits());
        }
    }
    match (dist.front_hypervolume, local.front_hypervolume) {
        (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits(), "hypervolume diverged"),
        (a, b) => assert_eq!(a.is_some(), b.is_some(), "hypervolume presence diverged"),
    }
}

#[test]
fn distributed_front_matches_single_process_bitwise_on_both_topologies() {
    for topology in [Topology::Ring, Topology::FullyConnected] {
        let spec = dist_spec(topology);
        // Reference: the in-process island model, fresh session.
        let local = SearchSession::synthetic().unwrap().run(&spec).unwrap();
        assert!(!local.rows.is_empty(), "reference front is empty (bad fixture)");

        // 3 workers for 4 islands: shard map [ [0,1], [2], [3] ] — one
        // worker holds a multi-island shard, exercising cross-island
        // batching worker-side.
        let workers: Vec<_> = (0..3).map(|_| spawn_worker()).collect();
        let addrs: Vec<String> = workers.iter().map(|(a, _)| a.to_string()).collect();

        let mut assigned = 0usize;
        let mut migrations = 0usize;
        let outcome = SearchSession::synthetic()
            .unwrap()
            .run_distributed(
                &spec,
                &addrs,
                &DistConfig::default(),
                |event| match event {
                    SearchEvent::ShardAssigned { .. } => assigned += 1,
                    SearchEvent::Migration { .. } => migrations += 1,
                    SearchEvent::ShardLost { .. } => panic!("no worker should be lost here"),
                    _ => {}
                },
                &CancelToken::new(),
            )
            .unwrap();

        assert_eq!(assigned, 3, "every worker should ack its shard");
        assert!(migrations > 0, "migration boundaries should fire ({topology:?})");
        assert_fronts_bitwise_equal(&outcome, &local);

        for (addr, handle) in workers {
            stop_worker(addr);
            handle.join().unwrap().unwrap();
        }
    }
}

#[test]
fn killing_a_worker_mid_run_reshards_and_completes_with_the_same_front() {
    let spec = dist_spec(Topology::Ring);
    let local = SearchSession::synthetic().unwrap().run(&spec).unwrap();

    let workers: Vec<_> = (0..3).map(|_| spawn_worker()).collect();
    let addrs: Vec<String> = workers.iter().map(|(a, _)| a.to_string()).collect();
    let victim = workers[2].0;

    let mut killed = false;
    let mut lost: Vec<(usize, Vec<usize>, usize)> = Vec::new();
    let outcome = SearchSession::synthetic()
        .unwrap()
        .run_distributed(
            &spec,
            &addrs,
            &DistConfig { heartbeat_timeout: Duration::from_secs(10), max_retries: 2 },
            |event| match event {
                // First sign of life from the fleet: pull the plug on
                // worker 2 while the advance is in flight.
                SearchEvent::Generation(_) if !killed => {
                    killed = true;
                    stop_worker(victim);
                }
                SearchEvent::ShardLost { worker, islands, retry } => {
                    lost.push((*worker, islands.clone(), *retry));
                }
                _ => {}
            },
            &CancelToken::new(),
        )
        .expect("search must survive a single worker loss");

    assert!(killed, "the kill never triggered");
    assert_eq!(lost.len(), 1, "expected exactly one shard loss, got {lost:?}");
    let (worker, islands, retry) = &lost[0];
    assert_eq!(*worker, 2, "the victim was worker 2");
    assert_eq!(islands, &vec![3], "worker 2 owned island 3 in the 4/3 shard map");
    assert_eq!(*retry, 0, "first (and only) re-shard");

    // The re-sharded, replayed search still lands on the identical front.
    assert_fronts_bitwise_equal(&outcome, &local);

    // The victim's accept loop has wound down; the survivors shut down
    // cleanly on request.
    let mut workers = workers;
    let (_, victim_handle) = workers.remove(2);
    victim_handle.join().unwrap().unwrap();
    for (addr, handle) in workers {
        stop_worker(addr);
        handle.join().unwrap().unwrap();
    }
}

#[test]
fn losing_every_worker_is_a_typed_error_not_a_hang() {
    let spec = dist_spec(Topology::Ring);
    // One real worker, killed mid-run, nobody left: the search must end
    // in SearchError::WorkerLost (kind "worker_lost"), not panic or spin.
    let (addr, handle) = spawn_worker();
    let mut killed = false;
    let err = SearchSession::synthetic()
        .unwrap()
        .run_distributed(
            &spec,
            &[addr.to_string()],
            &DistConfig { heartbeat_timeout: Duration::from_secs(10), max_retries: 2 },
            |event| {
                if matches!(event, SearchEvent::Generation(_)) && !killed {
                    killed = true;
                    stop_worker(addr);
                }
            },
            &CancelToken::new(),
        )
        .expect_err("no survivors: the search cannot complete");
    assert!(killed);
    assert!(
        matches!(err, mohaq::coordinator::SearchError::WorkerLost(_)),
        "expected WorkerLost, got {err:?}"
    );
    handle.join().unwrap().unwrap();
}

#[test]
fn unreachable_workers_fail_over_to_the_reachable_one() {
    let spec = dist_spec(Topology::Ring);
    let local = SearchSession::synthetic().unwrap().run(&spec).unwrap();

    // One live worker plus two addresses nobody listens on: the
    // connect failures burn the retry budget's losses but the fleet
    // converges on the survivor and completes.
    let (addr, handle) = spawn_worker();
    let dead_a = {
        // Bind-then-drop reserves an address that is closed by the time
        // the coordinator dials it.
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let dead_b = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let addrs = vec![dead_a, addr.to_string(), dead_b];

    let mut lost_workers: Vec<usize> = Vec::new();
    let outcome = SearchSession::synthetic()
        .unwrap()
        .run_distributed(
            &spec,
            &addrs,
            &DistConfig { heartbeat_timeout: Duration::from_secs(10), max_retries: 2 },
            |event| {
                if let SearchEvent::ShardLost { worker, .. } = event {
                    lost_workers.push(*worker);
                }
            },
            &CancelToken::new(),
        )
        .expect("one reachable worker is enough");

    assert_eq!(lost_workers, vec![0, 2], "both dead addresses reported lost");
    assert_fronts_bitwise_equal(&outcome, &local);

    stop_worker(addr);
    handle.join().unwrap().unwrap();
}

/// One worker replica's param table vs the coordinator's authoritative
/// store, through the `param_fetch` verification op: same names, same
/// tensors, bit for bit.
fn assert_replica_matches_coordinator(addr: SocketAddr, coord: &SearchSession) {
    let n = coord.eval().num_param_sets().unwrap();
    let mut client = ServeClient::connect(addr).unwrap();
    // Index 0 is the baseline (never pushed — workers register their
    // own); every index past it is a replicated beacon set.
    for idx in 1..n {
        let set = coord.eval().param_set(idx).unwrap();
        let (name, tensors) = client.param_fetch(idx).unwrap();
        assert_eq!(name, set.name, "replica set {idx} name diverged");
        assert_eq!(tensors.len(), set.host.len(), "replica set {idx} tensor count diverged");
        for (t, (a, b)) in tensors.iter().zip(&set.host).enumerate() {
            assert_eq!(a.len(), b.len(), "set {idx} tensor {t} length diverged");
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "set {idx} tensor {t} not bitwise equal");
            }
        }
    }
}

#[test]
fn distributed_beacon_front_matches_single_process_bitwise_on_both_topologies() {
    for topology in [Topology::Ring, Topology::FullyConnected] {
        let spec = beacon_spec(topology);
        // Reference: the single-process windowed island+beacon schedule.
        let local = SearchSession::synthetic().unwrap().run(&spec).unwrap();
        assert!(!local.rows.is_empty(), "reference front is empty (bad fixture)");
        assert!(
            !local.beacons.is_empty(),
            "reference run created no beacons ({topology:?}); the fixture must exercise \
             retraining for this test to mean anything"
        );

        let workers: Vec<_> = (0..2).map(|_| spawn_worker()).collect();
        let addrs: Vec<String> = workers.iter().map(|(a, _)| a.to_string()).collect();

        let coord = SearchSession::synthetic().unwrap();
        let mut created: Vec<(String, usize)> = Vec::new();
        let outcome = coord
            .run_distributed(
                &spec,
                &addrs,
                &DistConfig::default(),
                |event| match event {
                    SearchEvent::BeaconCreated { name, retrain_steps } => {
                        created.push((name.clone(), *retrain_steps));
                    }
                    SearchEvent::ShardLost { .. } => panic!("no worker should be lost here"),
                    _ => {}
                },
                &CancelToken::new(),
            )
            .unwrap();

        // Same beacons, by name and retrain budget, in creation order —
        // both in the outcome and as streamed events.
        assert_eq!(outcome.beacons, local.beacons, "beacon outcomes diverged ({topology:?})");
        assert_eq!(created, local.beacons, "streamed BeaconCreated events diverged");
        assert_fronts_bitwise_equal(&outcome, &local);

        // Every worker holds every finalized set, bit for bit.
        assert!(coord.eval().num_param_sets().unwrap() >= 2, "no beacon sets registered");
        for (addr, _) in &workers {
            assert_replica_matches_coordinator(*addr, &coord);
        }

        for (addr, handle) in workers {
            stop_worker(addr);
            handle.join().unwrap().unwrap();
        }
    }
}

#[test]
fn killing_a_worker_mid_beacon_run_replays_replication_and_keeps_the_front() {
    let spec = beacon_spec(Topology::Ring);
    let local = SearchSession::synthetic().unwrap().run(&spec).unwrap();
    assert!(!local.beacons.is_empty(), "reference run created no beacons (bad fixture)");

    let workers: Vec<_> = (0..3).map(|_| spawn_worker()).collect();
    let addrs: Vec<String> = workers.iter().map(|(a, _)| a.to_string()).collect();
    let victim = workers[2].0;

    let coord = SearchSession::synthetic().unwrap();
    let mut killed = false;
    let mut lost = 0usize;
    let outcome = coord
        .run_distributed(
            &spec,
            &addrs,
            &DistConfig { heartbeat_timeout: Duration::from_secs(10), max_retries: 2 },
            |event| match event {
                // Pull the plug as soon as the fleet shows life; the
                // re-shard must replay the full replication journal onto
                // the survivors (push_sets after reconnect), not just
                // sets finalized after the loss.
                SearchEvent::Generation(_) if !killed => {
                    killed = true;
                    stop_worker(victim);
                }
                SearchEvent::ShardLost { .. } => lost += 1,
                _ => {}
            },
            &CancelToken::new(),
        )
        .expect("beacon search must survive a single worker loss");

    assert!(killed, "the kill never triggered");
    assert_eq!(lost, 1, "expected exactly one shard loss");
    assert_eq!(outcome.beacons, local.beacons, "beacon outcomes diverged after re-shard");
    assert_fronts_bitwise_equal(&outcome, &local);

    // The survivors' replicas absorbed the journal replay.
    for (addr, _) in workers.iter().take(2) {
        assert_replica_matches_coordinator(*addr, &coord);
    }

    let mut workers = workers;
    let (_, victim_handle) = workers.remove(2);
    victim_handle.join().unwrap().unwrap();
    for (addr, handle) in workers {
        stop_worker(addr);
        handle.join().unwrap().unwrap();
    }
}
