//! Serve-mode integration tests — hermetic (surrogate evaluator, no
//! artifacts): a real TCP server over one shared `SearchSession`, driven
//! by real clients.
//!
//! Covers the acceptance contracts of the serve tentpole:
//!   * concurrent clients with DIFFERENT per-tenant platform tables get
//!     seed-deterministic fronts bitwise-identical to offline
//!     `SearchSession` runs of the same specs;
//!   * the shared PTQ cache serves hits across requests (cross-tenant
//!     reuse), visible in per-request and server-level stats;
//!   * cancellation mid-search returns a typed `cancelled` error frame;
//!   * malformed frames and invalid specs produce typed error frames on
//!     a connection that stays alive — no panics cross the boundary.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use mohaq::coordinator::{ExperimentSpec, ScoredObjective, SearchSession};
use mohaq::serve::{ClientError, Frame, Request, SearchReply, ServeClient, ServeState, Server};
use mohaq::util::json::Json;

/// Start a hermetic server on an ephemeral port; returns its address and
/// the thread driving the accept loop (joined to assert clean shutdown).
fn spawn_server() -> (SocketAddr, std::thread::JoinHandle<std::io::Result<()>>) {
    let state = ServeState::new(SearchSession::synthetic().unwrap(), 2);
    let server = Server::bind("127.0.0.1:0", state).unwrap();
    let addr = server.local_addr().unwrap();
    (addr, std::thread::spawn(move || server.run()))
}

fn connect(addr: SocketAddr) -> ServeClient {
    ServeClient::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap()
}

/// Send one raw line on a bare TCP stream (protocol-abuse cases the
/// typed client cannot express).
fn raw_send(stream: &mut TcpStream, line: &str) {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
}

fn raw_read(reader: &mut BufReader<TcpStream>) -> Frame {
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "server closed the connection");
    Frame::parse(&line).unwrap()
}

/// Tenant A: SiLago platform table (tied genome). The widened
/// feasibility area keeps every reachable surrogate error feasible, so
/// the front is never empty regardless of seed.
fn silago_spec() -> ExperimentSpec {
    ExperimentSpec::builder()
        .name("tenant-silago")
        .platform("silago")
        .sram_mb(6.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(8)
        .initial_pop_size(16)
        .generations(6)
        .seed(0x5117A60)
        .err_feasible_pp(25.0)
        .build()
        .unwrap()
}

/// Tenant B: Bitfusion platform table (untied genome, extra objective).
/// The 8 MB SRAM keeps the surrogate's feasible region wide (the paper's
/// 2 MB budget forces 2/4-bit weights, whose surrogate error then trips
/// the feasibility area — fine for a real search, flaky for a fixture).
fn bitfusion_spec() -> ExperimentSpec {
    ExperimentSpec::builder()
        .name("tenant-bitfusion")
        .platform("bitfusion")
        .sram_mb(8.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .objective(ScoredObjective::size_mb())
        .pop_size(8)
        .initial_pop_size(16)
        .generations(6)
        .seed(0xB17F)
        .err_feasible_pp(35.0)
        .build()
        .unwrap()
}

/// Served front == offline front, bit for bit.
fn assert_matches_offline(reply: &SearchReply, spec: &ExperimentSpec) {
    // A fresh offline session: same spec, same seed, independent cache.
    let offline = SearchSession::synthetic().unwrap().run(spec).unwrap();
    assert_eq!(reply.objectives, offline.objective_names, "objective labels diverged");
    assert_eq!(reply.evaluations, offline.evaluations, "evaluation counts diverged");
    assert_eq!(reply.rows.len(), offline.rows.len(), "front size diverged");
    for (served, local) in reply.rows.iter().zip(&offline.rows) {
        assert_eq!(served.config, local.qc.display_wa());
        assert_eq!(served.wer_v.to_bits(), local.wer_v.to_bits(), "wer_v not bitwise equal");
        assert_eq!(served.wer_t.to_bits(), local.wer_t.to_bits(), "wer_t not bitwise equal");
        assert_eq!(served.size_mb.to_bits(), local.size_mb.to_bits());
        assert_eq!(served.hw.len(), local.hw.len());
        for (sh, lh) in served.hw.iter().zip(&local.hw) {
            assert_eq!(sh.platform, lh.platform);
            assert_eq!(sh.speedup.to_bits(), lh.speedup.to_bits());
        }
    }
    match (reply.hypervolume, offline.front_hypervolume) {
        (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits(), "hypervolume diverged"),
        (a, b) => assert_eq!(a.is_some(), b.is_some(), "hypervolume presence diverged"),
    }
}

#[test]
fn concurrent_tenants_match_offline_and_share_the_cache() {
    let (addr, server) = spawn_server();

    // Two clients, two DIFFERENT platform tables, truly concurrent.
    let (reply_a, reply_b) = std::thread::scope(|scope| {
        let a = scope.spawn(move || connect(addr).search(&silago_spec()).unwrap());
        let b = scope.spawn(move || connect(addr).search(&bitfusion_spec()).unwrap());
        (a.join().unwrap(), b.join().unwrap())
    });
    assert!(!reply_a.rows.is_empty(), "tenant A front is empty");
    assert!(!reply_b.rows.is_empty(), "tenant B front is empty");
    assert!(reply_a.generations > 0, "no generation frames streamed");
    assert_eq!(reply_a.objectives, vec!["WER_V", "-speedup@silago"]);
    assert_eq!(reply_b.objectives, vec!["WER_V", "-speedup@bitfusion", "size_MB"]);

    // Seed determinism: each served front is bitwise-identical to an
    // offline SearchSession run of the same spec — even though the two
    // requests shared one cache and one worker pool while racing.
    assert_matches_offline(&reply_a, &silago_spec());
    assert_matches_offline(&reply_b, &bitfusion_spec());

    // Cross-request reuse: re-submitting tenant A's spec is served from
    // the shared PTQ cache — plenty of hits, and fresh executions only
    // for the final report's uncached test-split scoring (one per Pareto
    // row) — the search itself is execution-free.
    let mut client = connect(addr);
    let rerun = client.search(&silago_spec()).unwrap();
    assert!(rerun.cache_hits > 0, "repeat request must hit the shared cache");
    assert!(
        rerun.exec_calls <= rerun.rows.len(),
        "search phase re-executed {} times for {} rows: cache not shared",
        rerun.exec_calls,
        rerun.rows.len()
    );
    assert_eq!(rerun.rows.len(), reply_a.rows.len());
    for (x, y) in rerun.rows.iter().zip(&reply_a.rows) {
        assert_eq!(x.config, y.config);
        assert_eq!(x.wer_v.to_bits(), y.wer_v.to_bits());
    }

    // Server-level stats agree: one shared service, cumulative counters.
    let stats = client.server_stats().unwrap();
    assert!(stats.surrogate);
    assert_eq!(stats.requests, 3);
    assert!(stats.cache_hits >= rerun.cache_hits);
    assert!(stats.unique_solutions > 0);
    assert!(!stats.poisoned);

    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn cancel_mid_search_returns_typed_error_frame() {
    let (addr, server) = spawn_server();
    let mut client = connect(addr);

    // A long search (many generations) cancelled at the first generation
    // frame: the server must answer with a `cancelled` error frame, not
    // a front and not a dead socket.
    let mut spec = silago_spec();
    spec.ga.generations = 100_000;
    let err = client
        .search_with(&spec, |frame| matches!(frame, Frame::Generation { .. }))
        .unwrap_err();
    match err {
        ClientError::Server { kind, .. } => assert_eq!(kind, "cancelled"),
        other => panic!("expected server-side cancelled error, got {other:?}"),
    }

    // The connection survives cancellation.
    client.ping().unwrap();
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn malformed_and_invalid_requests_get_error_frames_not_disconnects() {
    let (addr, server) = spawn_server();

    let mut raw = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());

    // Not JSON at all: protocol error, uncorrelated.
    raw_send(&mut raw, "this is not json");
    match raw_read(&mut reader) {
        Frame::Error { id, kind, .. } => {
            assert_eq!(id, None);
            assert_eq!(kind, "protocol");
        }
        other => panic!("expected protocol error frame, got {other:?}"),
    }

    // Unknown op: protocol error, id still correlated.
    raw_send(&mut raw, r#"{"op":"warp","id":4}"#);
    match raw_read(&mut reader) {
        Frame::Error { id, kind, .. } => {
            assert_eq!(id, Some(4), "id correlated even for unknown ops");
            assert_eq!(kind, "protocol");
        }
        other => panic!("expected protocol error frame, got {other:?}"),
    }

    // An invalid spec (no objectives): typed invalid_spec error frame on
    // the SAME still-alive connection.
    let bad = Json::parse(r#"{"name": "x", "objectives": []}"#).unwrap();
    raw_send(&mut raw, &Request::Search { id: 9, spec: bad }.to_line());
    match raw_read(&mut reader) {
        Frame::Error { id, kind, .. } => {
            assert_eq!(id, Some(9));
            assert_eq!(kind, "invalid_spec");
        }
        other => panic!("expected invalid_spec error frame, got {other:?}"),
    }

    // An unknown platform in the tenant's table: typed unknown_platform.
    let tpu = Json::parse(
        r#"{"name": "x", "platforms": [{"name": "tpu-v9"}], "objectives": ["error"]}"#,
    )
    .unwrap();
    raw_send(&mut raw, &Request::Search { id: 10, spec: tpu }.to_line());
    match raw_read(&mut reader) {
        Frame::Error { id, kind, message } => {
            assert_eq!(id, Some(10));
            assert_eq!(kind, "unknown_platform");
            assert!(message.contains("tpu-v9"), "{message}");
        }
        other => panic!("expected unknown_platform error frame, got {other:?}"),
    }

    // After all that abuse the connection still serves a real search.
    raw_send(&mut raw, &Request::Search { id: 11, spec: silago_spec().to_json() }.to_line());
    loop {
        match raw_read(&mut reader) {
            Frame::Front { id, rows, .. } => {
                assert_eq!(id, 11);
                assert!(!rows.is_empty());
                break;
            }
            Frame::Error { kind, message, .. } => {
                panic!("search after abuse failed [{kind}]: {message}")
            }
            _ => continue,
        }
    }

    let mut client = connect(addr);
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn torn_and_batched_frames_parse_like_whole_lines() {
    let (addr, server) = spawn_server();
    let mut raw = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());

    // One frame torn across many tiny writes with pauses long enough to
    // straddle the server's read-timeout polling: the reader must keep
    // the partial line and resume it, not reject the fragments.
    let line = format!("{}\n", Request::Ping.to_line());
    for chunk in line.as_bytes().chunks(3) {
        raw.write_all(chunk).unwrap();
        raw.flush().unwrap();
        std::thread::sleep(Duration::from_millis(60));
    }
    assert!(matches!(raw_read(&mut reader), Frame::Pong), "torn ping not answered");

    // Several frames batched into ONE write: each gets its own reply.
    let batch = format!("{}\n{}\n{}\n", Request::Ping.to_line(), Request::Stats.to_line(), Request::Ping.to_line());
    raw.write_all(batch.as_bytes()).unwrap();
    raw.flush().unwrap();
    assert!(matches!(raw_read(&mut reader), Frame::Pong));
    assert!(matches!(raw_read(&mut reader), Frame::Stats(_)));
    assert!(matches!(raw_read(&mut reader), Frame::Pong));

    // A torn SEARCH request (split mid-JSON) still runs end to end.
    let search = format!("{}\n", Request::Search { id: 7, spec: silago_spec().to_json() }.to_line());
    let (a, b) = search.as_bytes().split_at(search.len() / 2);
    raw.write_all(a).unwrap();
    raw.flush().unwrap();
    std::thread::sleep(Duration::from_millis(60));
    raw.write_all(b).unwrap();
    raw.flush().unwrap();
    loop {
        match raw_read(&mut reader) {
            Frame::Front { id, rows, .. } => {
                assert_eq!(id, 7);
                assert!(!rows.is_empty());
                break;
            }
            Frame::Error { kind, message, .. } => panic!("torn search failed [{kind}]: {message}"),
            _ => continue,
        }
    }

    let mut client = connect(addr);
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn oversized_frame_gets_an_error_frame_then_teardown() {
    let (addr, server) = spawn_server();
    let mut raw = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());

    // Stream > MAX_LINE_BYTES without a newline. The server must answer
    // with a typed protocol error and close THIS connection only —
    // growing the buffer forever or killing the server are both wrong.
    let chunk = vec![b'x'; 1 << 20];
    for _ in 0..5 {
        if raw.write_all(&chunk).is_err() {
            break; // server may tear down before we finish pushing
        }
    }
    let mut line = String::new();
    if reader.read_line(&mut line).unwrap() > 0 {
        match Frame::parse(&line).unwrap() {
            Frame::Error { id, kind, message } => {
                assert_eq!(id, None);
                assert_eq!(kind, "protocol");
                assert!(message.contains("exceeds"), "{message}");
            }
            other => panic!("expected oversized-frame error, got {other:?}"),
        }
    }
    // Teardown: the stream reaches EOF.
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "connection not torn down");

    // The server itself is fine: a fresh connection still works.
    let mut client = connect(addr);
    client.ping().unwrap();
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn shard_ops_on_a_non_worker_server_get_typed_errors() {
    let (addr, server) = spawn_server();
    let mut raw = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());

    // A plain serve server refuses dist shard ops with a typed,
    // id-correlated error frame — the connection stays alive.
    raw_send(&mut raw, r#"{"op":"shard_front","id":21}"#);
    match raw_read(&mut reader) {
        Frame::Error { id, kind, message } => {
            assert_eq!(id, Some(21));
            assert_eq!(kind, "protocol");
            assert!(message.contains("worker"), "{message}");
        }
        other => panic!("expected protocol error frame, got {other:?}"),
    }
    raw_send(&mut raw, r#"{"op":"run_islands","id":22,"upto_gen":5}"#);
    match raw_read(&mut reader) {
        Frame::Error { id, kind, .. } => {
            assert_eq!(id, Some(22));
            assert_eq!(kind, "protocol");
        }
        other => panic!("expected protocol error frame, got {other:?}"),
    }

    // Still alive and serving.
    raw_send(&mut raw, &Request::Ping.to_line());
    assert!(matches!(raw_read(&mut reader), Frame::Pong));

    let mut client = connect(addr);
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

/// Tenant platform manifests: registered per CONNECTION, never visible
/// to other connections or the process registry; a search bound to a
/// tenant manifest transcribing SiLago scores bitwise like the builtin.
#[test]
fn tenant_manifests_are_connection_scoped_and_bitwise_equivalent() {
    use mohaq::hw::PlatformManifest;

    let (addr, server) = spawn_server();

    let path = format!("{}/platforms/silago_lut.json", env!("CARGO_MANIFEST_DIR"));
    let mut m = PlatformManifest::load_file(path).unwrap();
    m.name = "tenant_lut".into();

    let mut raw = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());

    // Register, then idempotently re-register the identical manifest.
    for id in [1u64, 2] {
        raw_send(&mut raw, &Request::RegisterPlatform { id, manifest: m.to_json() }.to_line());
        match raw_read(&mut reader) {
            Frame::PlatformRegistered { id: fid, name } => {
                assert_eq!(fid, id);
                assert_eq!(name, "tenant_lut");
            }
            other => panic!("expected platform_registered, got {other:?}"),
        }
    }

    // Same name, DIFFERENT contents: rejected, existing entry intact.
    let mut changed = m.clone();
    changed.sram_mb = Some(1.0);
    raw_send(&mut raw, &Request::RegisterPlatform { id: 3, manifest: changed.to_json() }.to_line());
    match raw_read(&mut reader) {
        Frame::Error { id, kind, message } => {
            assert_eq!(id, Some(3));
            assert_eq!(kind, "manifest");
            assert!(message.contains("different contents"), "{message}");
        }
        other => panic!("expected manifest error frame, got {other:?}"),
    }

    // Shadowing a builtin name: rejected with the collision message.
    let mut shadow = m.clone();
    shadow.name = "silago".into();
    raw_send(&mut raw, &Request::RegisterPlatform { id: 4, manifest: shadow.to_json() }.to_line());
    match raw_read(&mut reader) {
        Frame::Error { id, kind, message } => {
            assert_eq!(id, Some(4));
            assert_eq!(kind, "manifest");
            assert!(message.contains("builtin"), "{message}");
        }
        other => panic!("expected manifest error frame, got {other:?}"),
    }

    // An INVALID manifest is rejected and leaves the tenant registry
    // untouched: a later search naming it still says unknown_platform.
    raw_send(
        &mut raw,
        r#"{"op":"register_platform","id":5,"manifest":{"format_version":1,"name":"ghost"}}"#,
    );
    match raw_read(&mut reader) {
        Frame::Error { id, kind, .. } => {
            assert_eq!(id, Some(5));
            assert_eq!(kind, "manifest");
        }
        other => panic!("expected manifest error frame, got {other:?}"),
    }
    let ghost =
        Json::parse(r#"{"name":"g","platforms":[{"name":"ghost"}],"objectives":["error"]}"#)
            .unwrap();
    raw_send(&mut raw, &Request::Search { id: 6, spec: ghost }.to_line());
    match raw_read(&mut reader) {
        Frame::Error { id, kind, .. } => {
            assert_eq!(id, Some(6));
            assert_eq!(kind, "unknown_platform");
        }
        other => panic!("expected unknown_platform error frame, got {other:?}"),
    }

    // A search bound to the tenant manifest matches an offline run of
    // the SAME spec on the builtin platform, bit for bit (the manifest
    // transcribes SiLago's tables; only the label differs).
    let spec_json =
        Json::parse(&silago_spec().to_json().to_string().replace("silago", "tenant_lut"))
            .unwrap();
    raw_send(&mut raw, &Request::Search { id: 7, spec: spec_json }.to_line());
    let rows = loop {
        match raw_read(&mut reader) {
            Frame::Front { id, rows, .. } => {
                assert_eq!(id, 7);
                break rows;
            }
            Frame::Error { kind, message, .. } => {
                panic!("tenant search failed [{kind}]: {message}")
            }
            _ => continue,
        }
    };
    let offline = SearchSession::synthetic().unwrap().run(&silago_spec()).unwrap();
    assert!(!rows.is_empty(), "tenant front is empty");
    assert_eq!(rows.len(), offline.rows.len(), "front size diverged");
    for (served, local) in rows.iter().zip(&offline.rows) {
        assert_eq!(served.config, local.qc.display_wa());
        assert_eq!(served.wer_v.to_bits(), local.wer_v.to_bits());
        assert_eq!(served.hw.len(), local.hw.len());
        for (sh, lh) in served.hw.iter().zip(&local.hw) {
            assert_eq!(sh.platform, "tenant_lut");
            assert_eq!(sh.speedup.to_bits(), lh.speedup.to_bits());
        }
    }

    // Discovery on THIS connection lists the tenant platform; the ghost
    // never made it in.
    raw_send(&mut raw, &Request::Platforms.to_line());
    match raw_read(&mut reader) {
        Frame::Platforms { platforms } => {
            let find = |n: &str| platforms.iter().find(|p| p.name == n);
            assert_eq!(find("silago").unwrap().source, "builtin");
            assert_eq!(find("tenant_lut").unwrap().source, "manifest (tenant)");
            assert!(find("ghost").is_none(), "rejected manifest leaked into discovery");
        }
        other => panic!("expected platforms frame, got {other:?}"),
    }

    // A SECOND connection sees no tenant platform — not in discovery,
    // not resolvable by a search.
    let mut b = connect(addr);
    assert!(
        b.platforms().unwrap().iter().all(|p| p.name != "tenant_lut"),
        "tenant platform leaked to another connection"
    );
    let mut raw_b = TcpStream::connect(addr).unwrap();
    let mut reader_b = BufReader::new(raw_b.try_clone().unwrap());
    let foreign =
        Json::parse(r#"{"name":"b","platforms":[{"name":"tenant_lut"}],"objectives":["error"]}"#)
            .unwrap();
    raw_send(&mut raw_b, &Request::Search { id: 1, spec: foreign }.to_line());
    match raw_read(&mut reader_b) {
        Frame::Error { id, kind, .. } => {
            assert_eq!(id, Some(1));
            assert_eq!(kind, "unknown_platform");
        }
        other => panic!("expected unknown_platform error frame, got {other:?}"),
    }

    // The typed client helper drives the same ops.
    let mut m_b = m.clone();
    m_b.name = "tenant_b".into();
    assert_eq!(b.register_platform(&m_b).unwrap(), "tenant_b");
    assert!(
        b.platforms()
            .unwrap()
            .iter()
            .any(|p| p.name == "tenant_b" && p.source == "manifest (tenant)"),
        "typed registration missing from discovery"
    );

    b.shutdown().unwrap();
    server.join().unwrap().unwrap();
}

#[test]
fn disconnect_cancels_in_flight_searches() {
    let (addr, server) = spawn_server();

    // Fire a huge search, then drop the connection after the first
    // frame. The server must cancel the orphaned search (the `active`
    // count drains) rather than grind on forever.
    {
        let mut spec = silago_spec();
        spec.ga.generations = 300_000;
        spec.ga.seed = 0xD15C0;
        let mut raw = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(raw.try_clone().unwrap());
        raw_send(&mut raw, &Request::Search { id: 1, spec: spec.to_json() }.to_line());
        let first = raw_read(&mut reader);
        assert!(matches!(first, Frame::Started { .. }), "expected started, got {first:?}");
        // Abandon the connection mid-search.
    }

    // The orphaned search drains: `active` returns to 0.
    let mut client = connect(addr);
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let stats = client.server_stats().unwrap();
        if stats.active == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "orphaned search did not cancel: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    client.shutdown().unwrap();
    server.join().unwrap().unwrap();
}
