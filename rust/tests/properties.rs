//! Cross-module property tests (hermetic — no artifacts needed): GA
//! invariants, Pareto-set algebra, hardware-model monotonicity, and the
//! quantization math the Python side mirrors.

use mohaq::coordinator::SearchSession;
use mohaq::hw::{bitfusion, silago, Platform};
use mohaq::model::ModelDesc;
use mohaq::moo::island::{IslandConfig, Topology};
use mohaq::moo::problems::{Zdt, ZdtVariant};
use mohaq::moo::sort::{assign_crowding, fast_nondominated_sort};
use mohaq::moo::{Individual, Nsga2, Nsga2Config, Problem};
use mohaq::pareto::{dominates, hypervolume::hypervolume_2d, pareto_front_indices};
use mohaq::quant::{Bits, QuantConfig};
use mohaq::util::prop::check_prop;
use mohaq::util::rng::Rng;

fn random_points(rng: &mut Rng, n: usize, m: usize) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..m).map(|_| rng.f64()).collect()).collect()
}

#[test]
fn front_members_are_mutually_nondominated() {
    check_prop(
        "front_nondominated",
        60,
        |r| {
            let (n, m) = (3 + r.below(40), 2 + r.below(2));
            random_points(r, n, m)
        },
        |pts| {
            let front = pareto_front_indices(pts);
            if front.is_empty() {
                return Err("front must be non-empty".into());
            }
            for &i in &front {
                for &j in &front {
                    if i != j && dominates(&pts[i], &pts[j]) {
                        return Err(format!("front member {i} dominates {j}"));
                    }
                }
            }
            // Every non-front point is dominated by some front point.
            for k in 0..pts.len() {
                if front.contains(&k) {
                    continue;
                }
                if !front.iter().any(|&i| dominates(&pts[i], &pts[k])) {
                    return Err(format!("point {k} excluded but not dominated"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn nondominated_sort_ranks_are_consistent() {
    check_prop(
        "sort_rank_consistency",
        40,
        |r| {
            let n = 5 + r.below(40);
            random_points(r, n, 2)
        },
        |pts| {
            let mut pop: Vec<Individual> = pts
                .iter()
                .map(|p| {
                    let mut i = Individual::new(vec![]);
                    i.objectives = p.clone();
                    i
                })
                .collect();
            let fronts = fast_nondominated_sort(&mut pop);
            // Partition: every index in exactly one front.
            let total: usize = fronts.iter().map(|f| f.len()).sum();
            if total != pop.len() {
                return Err(format!("fronts cover {total}/{} points", pop.len()));
            }
            // No one in front k is dominated by anyone in front >= k.
            for (k, front) in fronts.iter().enumerate() {
                for &i in front {
                    for later in &fronts[k..] {
                        for &j in later {
                            if j != i && dominates(&pop[j].objectives, &pop[i].objectives)
                                && pop[j].rank >= pop[i].rank
                            {
                                return Err(format!(
                                    "rank violation: {j}(r{}) dominates {i}(r{})",
                                    pop[j].rank, pop[i].rank
                                ));
                            }
                        }
                    }
                }
            }
            assign_crowding(&mut pop, &fronts);
            Ok(())
        },
    );
}

#[test]
fn hypervolume_monotone_under_point_addition() {
    check_prop(
        "hv_monotone",
        60,
        |r| {
            let n = 1 + r.below(20);
            let base = random_points(r, n, 2);
            let extra: Vec<f64> = (0..2).map(|_| r.f64()).collect();
            (base, extra)
        },
        |(base, extra)| {
            let reference = [1.1, 1.1];
            let hv1 = hypervolume_2d(base, &reference);
            let mut bigger = base.clone();
            bigger.push(extra.clone());
            let hv2 = hypervolume_2d(&bigger, &reference);
            if hv2 + 1e-12 < hv1 {
                return Err(format!("hv decreased: {hv1} -> {hv2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn nsga2_population_always_within_gene_bounds_and_sized() {
    check_prop(
        "nsga2_bounds",
        8,
        |r| (r.next_u64(), 2 + r.below(6), 4 + r.below(20)),
        |&(seed, gens, resolution)| {
            let mut problem = Zdt::new(ZdtVariant::Zdt2, 5, resolution as i64);
            let mut algo = Nsga2::new(Nsga2Config {
                pop_size: 8,
                initial_pop_size: 12,
                generations: gens,
                seed,
                ..Default::default()
            });
            let pop = algo.run(&mut problem, |s| {
                if s.population.len() != 8 {
                    panic!("population size drifted: {}", s.population.len());
                }
            });
            for ind in &pop {
                if ind.genome.len() != problem.num_vars() {
                    return Err("genome length drifted".into());
                }
                for (i, &g) in ind.genome.iter().enumerate() {
                    let (lo, hi) = problem.var_range(i);
                    if g < lo || g > hi {
                        return Err(format!("gene {g} out of [{lo},{hi}]"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Bit-for-bit front key: genomes + raw IEEE bits of the objectives.
fn front_key(front: &[Individual]) -> Vec<(Vec<i64>, Vec<u64>)> {
    front
        .iter()
        .map(|i| {
            (
                i.genome.clone(),
                i.objectives.iter().map(|v| v.to_bits()).collect(),
            )
        })
        .collect()
}

#[test]
fn island_merged_front_bitwise_identical_across_thread_counts() {
    for topology in [Topology::Ring, Topology::FullyConnected] {
        for variant in [ZdtVariant::Zdt1, ZdtVariant::Zdt2, ZdtVariant::Zdt3] {
            let problem = Zdt::new(variant, 10, 48);
            let ga = Nsga2Config {
                pop_size: 10,
                initial_pop_size: 14,
                generations: 12,
                seed: 0x151_a4d,
                ..Default::default()
            };
            let cfg = IslandConfig {
                islands: 4,
                migration_interval: 3,
                topology,
                migrants: 2,
            };
            let one = SearchSession::run_generic_islands(&problem, ga.clone(), cfg.clone(), 1);
            let two = SearchSession::run_generic_islands(&problem, ga.clone(), cfg.clone(), 2);
            let eight = SearchSession::run_generic_islands(&problem, ga, cfg, 8);
            assert!(!one.is_empty(), "{variant:?}/{topology:?}: empty merged front");
            assert_eq!(
                front_key(&one),
                front_key(&two),
                "{variant:?}/{topology:?}: 1 vs 2 threads diverged"
            );
            assert_eq!(
                front_key(&one),
                front_key(&eight),
                "{variant:?}/{topology:?}: 1 vs 8 threads diverged"
            );
        }
    }
}

#[test]
fn island_search_scales_hypervolume_on_zdt_suite() {
    // The archipelago's value is population scaling: K islands evaluate
    // K*pop candidates per generation, all fanned out across the worker
    // pool, so at the SAME generation schedule (equal wall clock, K times
    // the evaluations) the merged front must dominate what a single
    // population produces. Simulation puts this margin at +0.07..+0.37 hv
    // across seeds and variants, so the assertion is stable. (At equal
    // *evaluation* counts a panmictic population is at least as good on
    // the unimodal ZDT fronts — see DESIGN.md for the trade-off.)
    for (variant, seed) in [
        (ZdtVariant::Zdt1, 101u64),
        (ZdtVariant::Zdt2, 202),
        (ZdtVariant::Zdt3, 303),
    ] {
        let ga = Nsga2Config {
            pop_size: 10,
            initial_pop_size: 10,
            generations: 60,
            seed,
            ..Default::default()
        };
        let problem = Zdt::new(variant, 12, 64);
        let merged =
            SearchSession::run_generic_islands(&problem, ga.clone(), IslandConfig::default(), 4);

        let mut single_problem = Zdt::new(variant, 12, 64);
        let mut single = Nsga2::new(ga);
        let single_front = Nsga2::pareto_set(&single.run(&mut single_problem, |_| {}));

        let hv = |front: &[Individual]| {
            let pts: Vec<Vec<f64>> = front.iter().map(|i| i.objectives.clone()).collect();
            hypervolume_2d(&pts, &[1.1, 1.1])
        };
        let (hv_islands, hv_single) = (hv(&merged), hv(&single_front));
        assert!(
            hv_islands >= hv_single,
            "{variant:?}: 4-island merged front hv {hv_islands:.4} fell below the \
             single-population hv {hv_single:.4} at the same generation schedule"
        );
    }
}

#[test]
fn island_rng_streams_never_overlap_in_first_10k_draws() {
    let mut base = Rng::new(0xA11_5EED);
    let streams = base.split(4);
    let mut seen = std::collections::HashSet::new();
    for (i, mut stream) in streams.into_iter().enumerate() {
        for draw in 0..10_000 {
            let v = stream.next_u64();
            assert!(
                seen.insert(v),
                "island stream {i} repeated draw {draw} (value {v:#x}) of an earlier stream"
            );
        }
    }
}

#[test]
fn silago_speedup_monotone_in_layer_precision() {
    // Lowering any single layer's precision must not reduce speedup and
    // must not increase energy (Eq. 3 / Eq. 4 monotonicity).
    let model = ModelDesc::paper();
    let p = silago::SiLago::new(None);
    check_prop(
        "silago_monotone",
        100,
        |r| {
            let bits: Vec<Bits> = (0..8)
                .map(|_| *r.choose(&[Bits::B8, Bits::B16]))
                .collect();
            (bits, r.below(8))
        },
        |(bits, layer)| {
            let qc = QuantConfig { w_bits: bits.clone(), a_bits: bits.clone() };
            let mut lower = bits.clone();
            lower[*layer] = match lower[*layer] {
                Bits::B16 => Bits::B8,
                _ => Bits::B4,
            };
            let qc_low = QuantConfig { w_bits: lower.clone(), a_bits: lower };
            if p.speedup(&model, &qc_low) < p.speedup(&model, &qc) - 1e-12 {
                return Err("speedup decreased with lower precision".into());
            }
            let (e_hi, e_lo) = (
                p.energy_pj(&model, &qc).unwrap(),
                p.energy_pj(&model, &qc_low).unwrap(),
            );
            if e_lo > e_hi + 1e-9 {
                return Err(format!("energy increased: {e_hi} -> {e_lo}"));
            }
            Ok(())
        },
    );
}

#[test]
fn bitfusion_speedup_bounded_by_brick_limits() {
    let model = ModelDesc::paper();
    let p = bitfusion::Bitfusion::new(None);
    check_prop(
        "bitfusion_bounds",
        100,
        |r| {
            let w: Vec<Bits> = (0..8).map(|_| *r.choose(&Bits::SEARCHABLE)).collect();
            let a: Vec<Bits> = (0..8).map(|_| *r.choose(&Bits::SEARCHABLE)).collect();
            QuantConfig { w_bits: w, a_bits: a }
        },
        |qc| {
            let s = p.speedup(&model, qc);
            // Bounded by the 2-bit x 2-bit peak and >= the 16x16 floor
            // diluted by fixed ops.
            if !(0.9..=64.0).contains(&s) {
                return Err(format!("speedup {s} out of physical range"));
            }
            Ok(())
        },
    );
}

#[test]
fn compression_ratio_bounds_hold() {
    let model = ModelDesc::paper();
    check_prop(
        "compression_bounds",
        100,
        |r| {
            (0..8)
                .map(|_| *r.choose(&Bits::SEARCHABLE))
                .collect::<Vec<Bits>>()
        },
        |bits| {
            let cp = model.compression_ratio(bits);
            // Between all-16-bit (2x) and all-2-bit (~15.65x).
            if !(1.9..=15.8).contains(&cp) {
                return Err(format!("cp {cp} out of range"));
            }
            let size = model.size_bits(bits);
            if size >= model.baseline_size_bits() {
                return Err("quantized size not smaller than float".into());
            }
            Ok(())
        },
    );
}

/// A valid manifest text to mutate (the checked-in SiLago-equivalent).
fn manifest_text() -> String {
    std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/platforms/silago_lut.json"
    ))
    .unwrap()
}

/// Malformed-input robustness (manifest loader + platform-spec parser):
/// every hostile payload must come back as a typed error, never a panic.
/// Deterministic worst cases first, then randomized truncation/splicing.
#[test]
fn hostile_json_yields_typed_errors_never_panics() {
    use mohaq::hw::{PlatformManifest, PlatformSpec};

    let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
    let cases: &[&str] = &[
        "",                                     // empty
        "{",                                    // truncated object
        "nul",                                  // truncated literal
        &deep,                                  // over-deep nesting
        r#"{"format_version": 1, "format_version": 1}"#, // duplicate keys
        r#"{"format_version": "one", "name": "x"}"#,     // wrong type
        r#"{"format_version": 1, "name": 7}"#,           // wrong type
        r#"{"format_version": 1e99, "name": "x"}"#,      // absurd version
        r#"{"name": {"nested": true}}"#,        // wrong shape
        r#"[1, 2, 3]"#,                         // not an object
        "\"just a string\"",
        r#"{"format_version": 1, "name": "x", "supported_bits": [4.5]}"#,
        r#"{"format_version": 1, "name": "x", "supported_bits": "all"}"#,
        r#"{"format_version": 1, "name": "x", "supported_bits": [8],
            "speedup": {"8x8": "fast"}}"#,
        r#"{"format_version": 1, "name": "x", "supported_bits": [8],
            "speedup": {"8x8": NaN}}"#,
    ];
    for case in cases {
        // The Err contents differ per case; the property is purely
        // "returns Result, never unwinds".
        let _ = PlatformManifest::from_json_str(case);
        let _ = PlatformSpec::from_json_str(case);
        let _ = mohaq::coordinator::ExperimentSpec::from_json_str(case);
    }

    // Randomized: truncate / splice the valid manifest at arbitrary
    // byte-safe points and re-parse. Any panic fails check_prop.
    let valid = manifest_text();
    check_prop(
        "manifest_truncation_robustness",
        200,
        |r| (r.below(valid.len()), r.below(valid.len())),
        |&(a, b)| {
            let cut = |mut i: usize| {
                while !valid.is_char_boundary(i) {
                    i -= 1;
                }
                i
            };
            let (a, b) = (cut(a), cut(b));
            let truncated = &valid[..a];
            let spliced = format!("{}{}", &valid[..a], &valid[b..]);
            for text in [truncated, spliced.as_str()] {
                let _ = PlatformManifest::from_json_str(text);
                let _ = PlatformSpec::from_json_str(text);
            }
            Ok(())
        },
    );
}

/// A failed manifest registration must leave the registry untouched —
/// the serve-mode per-request registration path relies on this.
#[test]
fn failed_registration_leaves_registry_untouched() {
    use mohaq::hw::{registry, PlatformManifest};

    // Shadowing a builtin: rejected, registry unchanged.
    let mut m = PlatformManifest::from_json_str(&manifest_text()).unwrap();
    m.name = "silago".into();
    let before = registry::known_platforms();
    let err = registry::register_manifest(&m).unwrap_err();
    assert!(err.to_string().contains("builtin"), "{err}");
    assert_eq!(registry::known_platforms(), before);

    // An invalid manifest: rejected before any insertion.
    let mut invalid = PlatformManifest::from_json_str(&manifest_text()).unwrap();
    invalid.name = "prop-invalid-entry".into();
    invalid.speedup.clear(); // coverage check must fail
    assert!(registry::register_manifest(&invalid).is_err());
    assert!(
        !registry::known_platforms().contains(&"prop-invalid-entry".to_string()),
        "rejected manifest leaked into the registry"
    );
}

/// A valid serialized checkpoint to mutate: harvested from a real
/// 2-island run so the populations/RNG states are genuine.
fn valid_checkpoint_text() -> String {
    use mohaq::coordinator::{CancelToken, ExperimentSpec, ScoredObjective};
    use mohaq::moo::IslandSnapshot;
    use mohaq::store::SearchCheckpoint;

    let mut spec = ExperimentSpec::builder()
        .name("prop-store")
        .platform("silago")
        .sram_mb(6.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(8)
        .initial_pop_size(16)
        .generations(4)
        .seed(0x9E0)
        .err_feasible_pp(25.0)
        .build()
        .unwrap();
    spec.island = Some(IslandConfig {
        islands: 2,
        migration_interval: 2,
        topology: Topology::Ring,
        migrants: 1,
    });
    use mohaq::coordinator::BeaconSnapshot;
    let mut first: Option<(usize, Vec<IslandSnapshot>)> = None;
    let mut sink = |gen: usize, snaps: &[IslandSnapshot], _beacons: &[BeaconSnapshot]| {
        if first.is_none() {
            first = Some((gen, snaps.to_vec()));
        }
    };
    let sink_opt: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])> =
        Some(&mut sink);
    SearchSession::synthetic()
        .unwrap()
        .run_checkpointed(&spec, |_| {}, sink_opt, &CancelToken::new())
        .unwrap();
    let (gen, snaps) = first.expect("a 2-island 4-generation run must hit a boundary");
    SearchCheckpoint::new(spec, gen, snaps, Vec::new()).unwrap().to_json().to_string()
}

/// A valid serialized eval store to mutate: a real memo entry under the
/// baseline parameter set.
fn valid_eval_store_text() -> String {
    let s = SearchSession::synthetic().unwrap();
    let n = s.artifacts().layer_names.len();
    let qc = QuantConfig::uniform(n, Bits::B4, Bits::B4);
    s.eval().val_error(&qc, 0).unwrap();
    mohaq::store::eval_store::to_json(s.eval()).unwrap().to_string()
}

/// Malformed-input robustness for the durable-state files: every hostile
/// payload through BOTH strict parsers (checkpoint + eval store) must
/// come back as a typed `StoreError`, never a panic and never a silent
/// partial parse. Deterministic worst cases first, then randomized
/// truncation/splicing of genuine files.
#[test]
fn hostile_store_files_yield_typed_errors_never_panics() {
    use mohaq::store::{EvalStoreData, SearchCheckpoint, StoreError};

    let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
    let generic: &[&str] = &[
        "",                                                   // empty
        "{",                                                  // truncated object
        "nul",                                                // truncated literal
        &deep,                                                // over-deep nesting
        "[1, 2, 3]",                                          // not an object
        "\"just a string\"",
        r#"{"kind": 7, "format_version": 1}"#,                // kind wrong type
        r#"{"format_version": "one", "kind": "mohaq-checkpoint"}"#,
        r#"{"format_version": 1.5, "kind": "mohaq-checkpoint"}"#, // fractional
        r#"{"format_version": -1, "kind": "mohaq-checkpoint"}"#,  // negative
    ];
    for case in generic {
        assert!(SearchCheckpoint::from_str(case).is_err(), "checkpoint accepted: {case:?}");
        assert!(EvalStoreData::from_str(case).is_err(), "eval store accepted: {case:?}");
    }

    // The typed classes, pinned exactly.
    assert!(matches!(
        SearchCheckpoint::from_str(r#"{"format_version": 1}"#),
        Err(StoreError::Missing { .. })
    ));
    assert!(matches!(
        SearchCheckpoint::from_str(r#"{"kind": "mohaq-checkpoint"}"#),
        Err(StoreError::Missing { .. })
    ));
    assert!(matches!(
        SearchCheckpoint::from_str(r#"{"format_version": 99, "kind": "mohaq-checkpoint"}"#),
        Err(StoreError::Version { found: 99, .. })
    ));
    // The kind gates BEFORE the version: a file of the wrong kind reports
    // Kind even when its version is also unsupported (the actionable
    // error is "wrong file", not "wrong version of the wrong file").
    assert!(matches!(
        SearchCheckpoint::from_str(r#"{"format_version": 99, "kind": "mohaq-eval-store"}"#),
        Err(StoreError::Kind { .. })
    ));
    assert!(matches!(
        EvalStoreData::from_str(r#"{"format_version": 1, "kind": "mohaq-checkpoint"}"#),
        Err(StoreError::Kind { .. })
    ));

    let ckpt = valid_checkpoint_text();
    assert!(SearchCheckpoint::from_str(&ckpt).is_ok(), "fixture checkpoint must be valid");
    // Duplicate keys: the JSON object is a BTreeMap, so the LAST value
    // wins — the duplicated bad version is seen and rejected, never
    // silently shadowed by the first occurrence.
    let dup = format!("{},\"format_version\":99}}", &ckpt[..ckpt.len() - 1]);
    assert!(matches!(
        SearchCheckpoint::from_str(&dup),
        Err(StoreError::Version { found: 99, .. })
    ));
    // Unknown fields are typed errors (strict-parse discipline).
    let unknown = format!("{},\"checksum\":\"abc\"}}", &ckpt[..ckpt.len() - 1]);
    assert!(matches!(
        SearchCheckpoint::from_str(&unknown),
        Err(StoreError::UnknownField { .. })
    ));
    // A generation off the migration grid fails checkpoint validation.
    let off_grid = ckpt.replace("\"generation\":2", "\"generation\":3");
    assert!(matches!(SearchCheckpoint::from_str(&off_grid), Err(StoreError::Invalid(_))));

    let store = valid_eval_store_text();
    assert!(EvalStoreData::from_str(&store).is_ok(), "fixture eval store must be valid");
    // An entry carrying both a packed AND a wide key is ambiguous.
    assert!(matches!(
        EvalStoreData::from_str(
            r#"{"format_version":1,"kind":"mohaq-eval-store","param_sets":[],
                "entries":[{"set":0,"pw":"1","pa":"2","w":[4],"a":[4],"value":0.5}]}"#
        ),
        Err(StoreError::Invalid(_))
    ));
    // A set index past the declared param sets.
    assert!(matches!(
        EvalStoreData::from_str(
            r#"{"format_version":1,"kind":"mohaq-eval-store","param_sets":[],
                "entries":[{"set":3,"pw":"1","pa":"2","value":0.5}]}"#
        ),
        Err(StoreError::Invalid(_))
    ));
    // A fractional set index (as_f64 would truncate; the parser must not).
    assert!(matches!(
        EvalStoreData::from_str(
            r#"{"format_version":1,"kind":"mohaq-eval-store","param_sets":[],
                "entries":[{"set":0.5,"pw":"1","pa":"2","value":0.5}]}"#
        ),
        Err(StoreError::Invalid(_))
    ));
    // A tensor value that is not exactly representable as f32 would be
    // silently rounded on load — rejected instead.
    assert!(matches!(
        EvalStoreData::from_str(
            r#"{"format_version":1,"kind":"mohaq-eval-store",
                "param_sets":[{"name":"x","tensors":[[0.1]]}],"entries":[]}"#
        ),
        Err(StoreError::Invalid(_))
    ));

    // Randomized: truncate / splice genuine files at arbitrary points and
    // re-parse through BOTH parsers. Any panic fails check_prop.
    for valid in [ckpt, store] {
        check_prop(
            "store_truncation_robustness",
            150,
            |r| (r.below(valid.len()), r.below(valid.len())),
            |&(a, b)| {
                let cut = |mut i: usize| {
                    while !valid.is_char_boundary(i) {
                        i -= 1;
                    }
                    i
                };
                let (a, b) = (cut(a), cut(b));
                let truncated = &valid[..a];
                let spliced = format!("{}{}", &valid[..a], &valid[b..]);
                for text in [truncated, spliced.as_str()] {
                    let _ = SearchCheckpoint::from_str(text);
                    let _ = EvalStoreData::from_str(text);
                }
                Ok(())
            },
        );
    }
}

/// A failed eval-store load must leave the live cache untouched — the
/// `mohaq serve --store` startup path relies on this: a corrupt store is
/// a hard error, never a partially warm cache.
#[test]
fn failed_eval_store_load_leaves_the_cache_untouched() {
    use mohaq::store::EvalStoreData;

    let s = SearchSession::synthetic().unwrap();
    let n = s.artifacts().layer_names.len();
    let qc = QuantConfig::uniform(n, Bits::B8, Bits::B8);
    let before_val = s.eval().val_error(&qc, 0).unwrap();
    let before_stats = s.eval().stats();
    let before_entries = s.eval().export_entries().unwrap();
    let before_sets = s.eval().snapshot_param_sets().unwrap().len();

    // Parses cleanly but fails in apply(): the param set carries one more
    // tensor than the model has, caught by pre-registration validation.
    let extra = s.artifacts().tensors.len() + 1;
    let tensors = vec!["[0.5]"; extra].join(",");
    let text = format!(
        r#"{{"format_version":1,"kind":"mohaq-eval-store","param_sets":[{{"name":"bad","tensors":[{tensors}]}}],"entries":[{{"set":1,"pw":"9","pa":"9","value":0.25}}]}}"#
    );
    let data = EvalStoreData::from_str(&text).expect("the corruption is apply-time, not parse-time");
    assert!(data.apply(s.eval(), false).is_err(), "a mismatched param set must be rejected");

    // Nothing changed: no phantom sets, no phantom entries, no counters.
    assert_eq!(s.eval().snapshot_param_sets().unwrap().len(), before_sets);
    let after_entries = s.eval().export_entries().unwrap();
    assert_eq!(after_entries.len(), before_entries.len(), "entry count changed");
    for e in &before_entries {
        assert!(after_entries.contains(e), "entry vanished after a failed load");
    }
    let after = s.eval().stats();
    assert_eq!(after.executions, before_stats.executions);
    assert_eq!(after.unique_solutions, before_stats.unique_solutions);
    assert_eq!(s.eval().val_error(&qc, 0).unwrap().to_bits(), before_val.to_bits());
}

#[test]
fn beacon_distance_zero_iff_same_weight_bits() {
    check_prop(
        "beacon_distance_identity",
        200,
        |r| {
            let w: Vec<Bits> = (0..8).map(|_| *r.choose(&Bits::SEARCHABLE)).collect();
            let a1: Vec<Bits> = (0..8).map(|_| *r.choose(&Bits::SEARCHABLE)).collect();
            let a2: Vec<Bits> = (0..8).map(|_| *r.choose(&Bits::SEARCHABLE)).collect();
            (w, a1, a2)
        },
        |(w, a1, a2)| {
            let q1 = QuantConfig { w_bits: w.clone(), a_bits: a1.clone() };
            let q2 = QuantConfig { w_bits: w.clone(), a_bits: a2.clone() };
            if q1.beacon_distance(&q2) != 0.0 {
                return Err("distance ignores activations (paper §4.3)".into());
            }
            Ok(())
        },
    );
}
