//! Cross-layer integration tests: artifacts -> runtime -> eval ->
//! coordinator -> SearchSession, exercised on the real AOT bundle.
//!
//! All tests skip gracefully when the artifact bundle has not been built
//! (unit CI stays hermetic); `make test` runs them against the live
//! bundle.

use std::path::PathBuf;
use std::sync::Arc;

use mohaq::coordinator::{
    baseline_rows, BeaconManager, BeaconPolicy, ExperimentSpec, MohaqProblem, SearchError,
    SearchOutcome, SearchSession, Trainer,
};
use mohaq::eval::EvalService;
use mohaq::moo::Problem;
use mohaq::quant::{Bits, QuantConfig};
use mohaq::runtime::{Artifacts, Runtime};

fn artifacts() -> Option<Arc<Artifacts>> {
    let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = PathBuf::from(dir);
    if !p.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts present");
        return None;
    }
    Some(Arc::new(Artifacts::load(p).unwrap()))
}

#[test]
fn exp1_mini_search_produces_tradeoff_front() {
    let Some(arts) = artifacts() else { return };
    let mut spec = ExperimentSpec::exp1();
    spec.ga.generations = 2;
    spec.ga.initial_pop_size = 12;
    spec.ga.pop_size = 6;
    let session = SearchSession::new(arts.clone()).unwrap();
    let outcome = session.run(&spec).unwrap();
    assert!(!outcome.rows.is_empty());
    // Rows sorted by error; compression must trend the other way across
    // the front (it's a front: no row may dominate another).
    for w in outcome.rows.windows(2) {
        assert!(w[0].wer_v <= w[1].wer_v + 1e-12);
        assert!(
            !(w[1].wer_v >= w[0].wer_v && w[1].size_mb >= w[0].size_mb - 1e-12),
            "dominated row in pareto set: {w:?}"
        );
    }
    // History covers every generation.
    assert_eq!(outcome.history.len(), spec.ga.generations + 1);
}

#[test]
fn search_front_is_identical_for_any_thread_count() {
    let Some(arts) = artifacts() else { return };
    let mut spec = ExperimentSpec::exp3_bitfusion(false);
    spec.ga.generations = 2;
    spec.ga.initial_pop_size = 10;
    spec.ga.pop_size = 6;
    spec.ga.seed = 0xD15C0;

    let front = |threads: usize| {
        let session = SearchSession::new(arts.clone()).unwrap().threads(threads);
        let outcome = session.run(&spec).unwrap();
        outcome
            .rows
            .iter()
            .map(|r| (r.qc.clone(), r.wer_v.to_bits(), r.speedup.map(f64::to_bits)))
            .collect::<Vec<_>>()
    };
    assert_eq!(front(1), front(4), "parallel evaluation changed the front");
}

#[test]
fn island_search_front_is_identical_for_any_thread_count() {
    let Some(arts) = artifacts() else { return };
    let mut spec = ExperimentSpec::exp1();
    spec.ga.generations = 2;
    spec.ga.initial_pop_size = 6;
    spec.ga.pop_size = 6;
    spec.ga.seed = 0x15_1a2d;
    spec.island = Some(mohaq::moo::IslandConfig {
        islands: 3,
        migration_interval: 1,
        topology: mohaq::moo::Topology::Ring,
        migrants: 2,
    });

    let front = |threads: usize| {
        let session = SearchSession::new(arts.clone()).unwrap().threads(threads);
        let outcome = session.run(&spec).unwrap();
        outcome
            .rows
            .iter()
            .map(|r| (r.qc.clone(), r.wer_v.to_bits()))
            .collect::<Vec<_>>()
    };
    let one = front(1);
    assert_eq!(one, front(2), "2 eval threads changed the merged island front");
    assert_eq!(one, front(8), "8 eval threads changed the merged island front");
}

#[test]
fn exp2_silago_respects_platform_restrictions() {
    let Some(arts) = artifacts() else { return };
    let mut spec = ExperimentSpec::exp2_silago();
    spec.ga.generations = 2;
    spec.ga.initial_pop_size = 10;
    spec.ga.pop_size = 6;
    let session = SearchSession::new(arts.clone()).unwrap();
    let outcome = session.run(&spec).unwrap();
    for row in &outcome.rows {
        // Tied W=A, no 2-bit on SiLago, SRAM <= 6 MB.
        assert_eq!(row.qc.w_bits, row.qc.a_bits);
        assert!(row.qc.w_bits.iter().all(|b| *b != Bits::B2), "{:?}", row.qc);
        assert!(row.size_mb <= 6.0 + 1e-9);
        assert!(row.speedup.is_some() && row.energy_uj.is_some());
    }
}

#[test]
fn exp3_constraint_excludes_oversized_models() {
    let Some(arts) = artifacts() else { return };
    let mut spec = ExperimentSpec::exp3_bitfusion(false);
    spec.ga.generations = 2;
    spec.ga.initial_pop_size = 10;
    spec.ga.pop_size = 6;
    let session = SearchSession::new(arts.clone()).unwrap();
    let outcome = session.run(&spec).unwrap();
    let cap_mb = 2.0;
    for row in &outcome.rows {
        assert!(
            row.size_mb <= cap_mb + 1e-9,
            "solution over the SRAM cap: {} MB",
            row.size_mb
        );
    }
}

#[test]
fn beacon_rescues_aggressive_quantization() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let eval = EvalService::new(&rt, arts.clone()).unwrap();
    let mut trainer = Trainer::new(&rt, arts.clone(), 1).unwrap();
    let mut policy =
        BeaconPolicy::paper_defaults(arts.baseline.val_err_16bit, arts.baseline.beacon_lr as f32);
    policy.retrain_steps = 120; // enough to show a clear gain
    let mut mgr = BeaconManager::new(policy);

    let n = arts.layer_names.len();
    let qc = QuantConfig::uniform(n, Bits::B2, Bits::B8);
    let base_err = eval.val_error(&qc, 0).unwrap();
    assert!(base_err > arts.baseline.val_err + 0.10, "2-bit PTQ should be bad");

    let set = mgr
        .select_or_create(&qc, base_err, &eval, &mut trainer)
        .unwrap()
        .expect("should create a beacon");
    assert_eq!(mgr.beacons.len(), 1);
    let beacon_err = eval.val_error(&qc, set).unwrap();
    assert!(
        beacon_err < base_err - 0.05,
        "beacon should rescue: {base_err:.3} -> {beacon_err:.3}"
    );

    // A neighbor inside the threshold reuses the beacon, no new retrain.
    let mut neighbor_bits = vec![Bits::B2; n];
    neighbor_bits[0] = Bits::B4;
    let neighbor = QuantConfig { w_bits: neighbor_bits, a_bits: vec![Bits::B8; n] };
    let d = neighbor.beacon_distance(&qc);
    assert!(d <= mgr.policy.threshold);
    let nb_base = eval.val_error(&neighbor, 0).unwrap();
    let set2 = mgr
        .select_or_create(&neighbor, nb_base, &eval, &mut trainer)
        .unwrap()
        .expect("neighbor should use the existing beacon");
    assert_eq!(set2, set);
    assert_eq!(mgr.beacons.len(), 1, "no second retraining");
}

#[test]
fn cross_platform_search_produces_labeled_joint_front() {
    let Some(arts) = artifacts() else { return };
    let mut spec = ExperimentSpec::cross_platform();
    spec.ga.generations = 2;
    spec.ga.initial_pop_size = 10;
    spec.ga.pop_size = 6;
    spec.ga.seed = 0xC405;

    let run = |threads: usize| {
        let session = SearchSession::new(arts.clone()).unwrap().threads(threads);
        session.run(&spec).unwrap()
    };
    let one = run(1);
    // One front, objective names labeled per platform binding.
    assert_eq!(one.objective_names, ["WER_V", "-speedup@silago", "-speedup@bitfusion"]);
    assert!(!one.rows.is_empty());
    for row in &one.rows {
        // Joint restrictions: tied W=A and no 2-bit (SiLago), and the
        // tighter of the two SRAM caps (Bitfusion's 2 MB).
        assert_eq!(row.qc.w_bits, row.qc.a_bits);
        assert!(row.qc.w_bits.iter().all(|b| *b != Bits::B2), "{:?}", row.qc);
        assert!(row.size_mb <= 2.0 + 1e-9, "over the bitfusion cap: {} MB", row.size_mb);
        // Per-platform metrics in binding-table order.
        assert_eq!(row.hw.len(), 2);
        assert_eq!(row.hw[0].platform, "silago");
        assert_eq!(row.hw[1].platform, "bitfusion");
        assert!(row.hw[0].energy_uj.is_some(), "silago has an energy model");
        assert!(row.hw[1].energy_uj.is_none(), "bitfusion has none");
    }

    let key = |o: &SearchOutcome| {
        o.rows
            .iter()
            .map(|r| {
                let hw: Vec<u64> = r.hw.iter().map(|h| h.speedup.to_bits()).collect();
                (r.qc.clone(), r.wer_v.to_bits(), hw)
            })
            .collect::<Vec<_>>()
    };
    // Seed-deterministic run to run...
    assert_eq!(key(&one), key(&run(1)), "same seed changed the joint front");
    // ...and thread-count-invariant.
    assert_eq!(key(&one), key(&run(4)), "eval threads changed the joint front");
}

#[test]
fn failing_eval_trips_the_fuse_not_a_panic() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let eval = Arc::new(EvalService::new(&rt, arts.clone()).unwrap());
    let spec = ExperimentSpec::exp1();
    let (objectives, bindings) = spec.resolve_objectives().unwrap();
    let mut problem = MohaqProblem {
        arts: arts.clone(),
        eval,
        trainer: None,
        beacons: None,
        bindings,
        objectives,
        tied: false,
        err_limit: 1.0,
        gene_min: 1,
        evaluator: mohaq::coordinator::EvalStrategy::Threads(2),
        cancel: mohaq::coordinator::CancelToken::new(),
        records: Vec::new(),
        failure: None,
    };

    // A malformed genome (gene 99 maps to no precision) used to panic
    // inside the worker pool; now it trips the problem's fuse: the batch
    // returns infeasible sentinels and the typed error is stored for the
    // session boundary.
    let n = arts.layer_names.len();
    let evals = problem.evaluate_batch(&[vec![99i64; 2 * n]]);
    assert_eq!(evals.len(), 1);
    assert!(!evals[0].feasible(), "sentinel must be infeasible");
    let err = problem.failure.take().expect("fuse should hold the typed error");
    assert!(matches!(err, SearchError::Eval(_)), "{err:?}");
    assert!(err.to_string().contains("invalid genome"), "{err}");

    // Once tripped, later batches short-circuit: sentinels, no records.
    problem.failure = Some(SearchError::Eval("tripped".into()));
    let evals = problem.evaluate_batch(&[vec![3i64; 2 * n]]);
    assert!(!evals[0].feasible());
    assert!(problem.records.is_empty(), "no evaluation happens after the fuse");
}

#[test]
fn baseline_rows_match_manifest() {
    let Some(arts) = artifacts() else { return };
    let rows = baseline_rows(&arts);
    assert_eq!(rows.len(), 2);
    assert!((rows[0].cp_r - 1.0).abs() < 1e-12);
    assert!((rows[1].cp_r - 2.0).abs() < 0.01);
    assert_eq!(rows[1].speedup, Some(1.0));
}

#[test]
fn eval_service_val_matches_16bit_manifest_value() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let eval = EvalService::new(&rt, arts.clone()).unwrap();
    let n = arts.layer_names.len();
    let qc16 = QuantConfig::uniform(n, Bits::B16, Bits::B16);
    let err = eval.val_error(&qc16, 0).unwrap();
    // Python computed this through the ref path; Rust runs the Pallas
    // path. pytest proves kernel==ref, so these must agree closely.
    assert!(
        (err - arts.baseline.val_err_16bit).abs() < 0.01,
        "rust {err} vs python {}",
        arts.baseline.val_err_16bit
    );
}

#[test]
fn genome_decode_matches_eval_layers() {
    let Some(arts) = artifacts() else { return };
    let n = arts.layer_names.len();
    let genome: Vec<i64> = (0..2 * n).map(|i| 1 + (i as i64 % 4)).collect();
    let qc = QuantConfig::from_genome_wa(&genome).unwrap();
    assert_eq!(qc.num_layers(), n);
    assert_eq!(qc.to_genome_wa(), genome);
}
