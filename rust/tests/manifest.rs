//! Platform-manifest integration tests — the tentpole acceptance
//! criterion lives here: a search bound to the checked-in
//! SiLago-equivalent manifest (`platforms/silago_lut.json`) produces a
//! front BITWISE-identical to the built-in `silago` platform at the same
//! seed/thread/island configuration. Same for the Bitfusion pair
//! (untied genome). Everything runs on the hermetic surrogate evaluator,
//! so the suite needs no artifact bundle — the `manifest-smoke` CI job
//! re-checks the SiLago equivalence end to end through the release
//! binary.

use mohaq::coordinator::{ExperimentSpec, ScoredObjective, SearchSession, SolutionRow};
use mohaq::hw::registry;
use mohaq::hw::PlatformManifest;
use mohaq::util::json::Json;

fn manifest_path(file: &str) -> String {
    format!("{}/platforms/{file}", env!("CARGO_MANIFEST_DIR"))
}

/// Load and globally register both checked-in manifests (idempotent:
/// `register_manifest` accepts identical re-registration, so every test
/// in this binary can call this).
fn register_checked_in() {
    for file in ["silago_lut.json", "bitfusion_lut.json"] {
        let m = PlatformManifest::load_file(manifest_path(file)).unwrap();
        registry::register_manifest(&m).unwrap();
    }
}

/// The acceptance spec shape: island-model GA with energy + speedup
/// objectives, parameterized only by the platform name. The widened
/// feasibility area keeps the surrogate front non-empty at this seed;
/// `sram_mb` (when given) exercises the spec-level override on BOTH the
/// builtin factory and the manifest-backed one.
fn spec(platform: &str, energy: bool, sram_mb: Option<f64>) -> ExperimentSpec {
    let mut b = ExperimentSpec::builder()
        .name(format!("manifest-accept-{platform}"))
        .platform(platform)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(16)
        .initial_pop_size(32)
        .generations(8)
        .seed(0x10_117)
        .islands(2)
        .migration_interval(2)
        .err_feasible_pp(30.0);
    if let Some(mb) = sram_mb {
        b = b.sram_mb(mb);
    }
    if energy {
        b = b.objective(ScoredObjective::energy_uj());
    }
    b.build().unwrap()
}

/// Bitwise front equality, ignoring the platform LABELS (the manifest
/// platform has a different name, so `hw[i].platform` legitimately
/// differs; every number must not).
fn assert_fronts_bitwise_equal(lut: &[SolutionRow], builtin: &[SolutionRow]) {
    assert!(!lut.is_empty(), "manifest-platform front is empty");
    assert_eq!(lut.len(), builtin.len(), "front sizes diverged");
    for (a, b) in lut.iter().zip(builtin) {
        assert_eq!(a.qc.display_wa(), b.qc.display_wa(), "genomes diverged");
        assert_eq!(a.wer_v.to_bits(), b.wer_v.to_bits(), "wer_v diverged");
        assert_eq!(a.wer_t.to_bits(), b.wer_t.to_bits(), "wer_t diverged");
        assert_eq!(a.size_mb.to_bits(), b.size_mb.to_bits(), "size diverged");
        assert_eq!(a.hw.len(), b.hw.len());
        for (ha, hb) in a.hw.iter().zip(&b.hw) {
            assert_eq!(ha.speedup.to_bits(), hb.speedup.to_bits(), "speedup diverged");
            match (ha.energy_uj, hb.energy_uj) {
                (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits(), "energy diverged"),
                (x, y) => assert_eq!(x.is_some(), y.is_some(), "energy presence diverged"),
            }
        }
    }
}

fn run(spec: &ExperimentSpec) -> Vec<SolutionRow> {
    SearchSession::synthetic().unwrap().threads(2).run(spec).unwrap().rows
}

/// THE acceptance test: checked-in SiLago-equivalent manifest == builtin
/// silago, bit for bit, through the full island search.
#[test]
fn silago_manifest_search_front_is_bitwise_identical_to_builtin() {
    register_checked_in();
    let lut = run(&spec("silago_lut", true, None));
    let builtin = run(&spec("silago", true, None));
    assert_fronts_bitwise_equal(&lut, &builtin);
}

/// Same for the untied Bitfusion pair (no energy model: the full W×A
/// table is exercised instead).
#[test]
fn bitfusion_manifest_search_front_is_bitwise_identical_to_builtin() {
    register_checked_in();
    let lut = run(&spec("bitfusion_lut", false, Some(8.0)));
    let builtin = run(&spec("bitfusion", false, Some(8.0)));
    assert_fronts_bitwise_equal(&lut, &builtin);
}

/// Spec-inlined manifests: a platform entry carrying its own manifest
/// resolves WITHOUT any prior registration, and the search it drives
/// matches the builtin bitwise too.
#[test]
fn inline_manifest_spec_matches_builtin_without_registration() {
    // Build the inline spec as raw JSON: take the builtin spec, rename
    // its platform to a name that exists nowhere in the registry, and
    // attach the manifest (renamed to match) to the platform entry.
    let name = "silago-inline-accept";
    assert!(registry::source_of(name).is_none(), "test name must start unregistered");
    let text = std::fs::read_to_string(manifest_path("silago_lut.json")).unwrap();
    let mut manifest = PlatformManifest::from_json_str(&text).unwrap();
    manifest.name = name.to_string();

    let base = spec("silago", true, None).to_json().to_string();
    let patched = base.replace("silago", name);
    let mut spec_json = Json::parse(&patched).unwrap();
    let Json::Obj(top) = &mut spec_json else { panic!("spec JSON is not an object") };
    let Some(Json::Arr(platforms)) = top.get_mut("platforms") else {
        panic!("spec JSON has no platforms array");
    };
    match &mut platforms[0] {
        Json::Obj(entry) => {
            entry.insert("manifest".into(), manifest.to_json());
        }
        other => panic!("platform entry is not an object: {other:?}"),
    }
    let inline_spec = ExperimentSpec::from_json(&spec_json).unwrap();
    let lut = run(&inline_spec);
    let builtin = run(&spec("silago", true, None));
    assert_fronts_bitwise_equal(&lut, &builtin);
    // Resolution stayed spec-local: the registry never learned the name.
    assert!(registry::source_of(name).is_none(), "inline resolution leaked into the registry");
}

/// The checked-in manifests survive a lossless JSON round trip through
/// the public API (what `mohaq platform lint` relies on).
#[test]
fn checked_in_manifests_round_trip_losslessly() {
    for file in ["silago_lut.json", "bitfusion_lut.json"] {
        let m = PlatformManifest::load_file(manifest_path(file)).unwrap();
        let reparsed = PlatformManifest::from_json_str(&m.to_json_string()).unwrap();
        assert_eq!(m, reparsed, "{file} did not round-trip");
    }
}
