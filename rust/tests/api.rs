//! Hermetic tests of the public search API (no artifacts needed): the
//! platform registry, the ExperimentSpec builder + serde round-trip, the
//! typed error boundary, and the SearchSession parallel-evaluation
//! plumbing on a tiny ZDT problem.

use std::sync::Arc;

use mohaq::coordinator::{ExperimentSpec, ScoredObjective, SearchError, SearchSession};
use mohaq::eval::ResultCache;
use mohaq::hw::registry::{self, PlatformSpec};
use mohaq::hw::Platform;
use mohaq::model::ModelDesc;
use mohaq::moo::island::{IslandConfig, Topology};
use mohaq::moo::problems::{Zdt, ZdtVariant};
use mohaq::moo::Nsga2Config;
use mohaq::quant::{Bits, QuantConfig};

// ----------------------------------------------------------------- registry

#[test]
fn registry_rejects_unknown_platform_with_helpful_error() {
    let err = registry::resolve(&PlatformSpec::new("npu-9000")).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("npu-9000"), "{msg}");
    assert!(msg.contains("silago"), "should list known platforms: {msg}");
    assert!(msg.contains("bitfusion"), "should list known platforms: {msg}");

    // Same failure through the builder becomes the typed SearchError.
    let err = ExperimentSpec::builder()
        .platform("npu-9000")
        .objective(ScoredObjective::error())
        .build()
        .unwrap_err();
    match err {
        SearchError::UnknownPlatform { name, known } => {
            assert_eq!(name, "npu-9000");
            assert!(known.contains(&"silago".to_string()));
        }
        other => panic!("expected UnknownPlatform, got {other:?}"),
    }
}

#[test]
fn custom_platform_registers_and_drives_spec_validation() {
    /// A platform with no energy model and untied W/A.
    struct Toy;
    impl Platform for Toy {
        fn name(&self) -> &str {
            "toy"
        }
        fn supported_bits(&self) -> &[Bits] {
            &Bits::SEARCHABLE
        }
        fn tied_wa(&self) -> bool {
            false
        }
        fn speedup(&self, m: &ModelDesc, qc: &QuantConfig) -> f64 {
            mohaq::hw::eq4_speedup(m, qc, |_, _| 3.0)
        }
        fn energy_pj(&self, _: &ModelDesc, _: &QuantConfig) -> Option<f64> {
            None
        }
        fn sram_bytes(&self) -> Option<f64> {
            None
        }
    }
    registry::register("toy", |_| Ok(Arc::new(Toy)));

    // Speedup objective on the custom platform validates...
    let spec = ExperimentSpec::builder()
        .platform("toy")
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .build()
        .unwrap();
    assert_eq!(spec.platforms[0].name, "toy");
    // The lone platform binds the hardware objective explicitly, and the
    // resolved binding carries the live handle.
    assert_eq!(spec.objectives[1].id(), "neg_speedup@toy");
    let (bound, bindings) = spec.resolve_objectives().unwrap();
    assert_eq!(bindings[0].platform.name(), "toy");
    assert_eq!(bound[1].label, "-speedup@toy");

    // ...but the energy objective is rejected: no energy model.
    let err = ExperimentSpec::builder()
        .platform("toy")
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::energy_uj())
        .build()
        .unwrap_err();
    assert!(matches!(err, SearchError::InvalidSpec(_)), "{err}");

    // A cross-platform spec can mix the custom backend with a built-in.
    let spec = ExperimentSpec::builder()
        .objective(ScoredObjective::error())
        .platform_objective("toy", ScoredObjective::neg_speedup())
        .platform_objective("bitfusion", ScoredObjective::neg_speedup())
        .build()
        .unwrap();
    let (bound, bindings) = spec.resolve_objectives().unwrap();
    assert_eq!(bindings.len(), 2);
    let labels: Vec<&str> = bound.iter().map(|o| o.label.as_str()).collect();
    assert_eq!(labels, ["WER_V", "-speedup@toy", "-speedup@bitfusion"]);
}

#[test]
fn empty_bits_platform_is_rejected_before_any_search() {
    // Regression: a custom registry platform with an empty supported-bits
    // list used to pass spec validation and panic mid-search when the
    // session derived the genome lower bound (min().unwrap() at
    // coordinator/session.rs). The registry now rejects it at resolve
    // time, so spec build returns a typed SearchError instead.
    struct Hollow;
    impl Platform for Hollow {
        fn name(&self) -> &str {
            "hollow"
        }
        fn supported_bits(&self) -> &[Bits] {
            &[]
        }
        fn tied_wa(&self) -> bool {
            false
        }
        fn speedup(&self, m: &ModelDesc, qc: &QuantConfig) -> f64 {
            mohaq::hw::eq4_speedup(m, qc, |_, _| 1.0)
        }
        fn energy_pj(&self, _: &ModelDesc, _: &QuantConfig) -> Option<f64> {
            None
        }
        fn sram_bytes(&self) -> Option<f64> {
            None
        }
    }
    registry::register("hollow", |_| Ok(Arc::new(Hollow)));

    let err = ExperimentSpec::builder()
        .platform("hollow")
        .objective(ScoredObjective::error())
        .build()
        .unwrap_err();
    assert!(matches!(err, SearchError::InvalidSpec(_)), "{err:?}");
    assert!(err.to_string().contains("no supported precisions"), "{err}");

    // Same rejection when the platform sneaks in through an objective
    // binding resolved at session time.
    let mut spec = ExperimentSpec::builder()
        .platform("bitfusion")
        .objective(ScoredObjective::error())
        .build()
        .unwrap();
    spec.platforms[0] = PlatformSpec::new("hollow");
    let err = spec.resolve_objectives().unwrap_err();
    assert!(matches!(err, SearchError::InvalidSpec(_)), "{err:?}");
}

#[test]
fn synthetic_session_reuses_its_cache_across_runs() {
    // The serve-mode building block, exercised offline: one session, two
    // runs of the same spec — the second is served from the shared PTQ
    // cache and reproduces the front bit for bit.
    let spec = ExperimentSpec::builder()
        .name("hermetic-reuse")
        .platform("bitfusion")
        // Generous SRAM: keeps the surrogate's feasible region wide so
        // the front is non-empty at any seed.
        .sram_mb(8.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(8)
        .initial_pop_size(12)
        .generations(4)
        .seed(0xCAFE)
        .err_feasible_pp(35.0)
        .build()
        .unwrap();
    let session = SearchSession::synthetic().unwrap();
    let first = session.run(&spec).unwrap();
    assert!(!first.rows.is_empty(), "hermetic front is empty");
    assert!(first.exec_calls > 0);

    let second = session.run(&spec).unwrap();
    assert!(second.cache_hits > 0, "second run must hit the shared cache");
    assert!(
        second.exec_calls <= second.rows.len(),
        "search phase re-executed: {} exec calls for {} rows",
        second.exec_calls,
        second.rows.len()
    );
    assert_eq!(first.rows.len(), second.rows.len());
    for (a, b) in first.rows.iter().zip(&second.rows) {
        assert_eq!(a.qc, b.qc);
        assert_eq!(a.wer_v.to_bits(), b.wer_v.to_bits());
    }
    // Cumulative service stats accrete across runs; per-run numbers are
    // deltas.
    assert_eq!(
        session.eval().stats().executions,
        first.eval_stats.executions + second.exec_calls
    );
}

#[test]
fn cancelled_token_aborts_before_any_evaluation() {
    use mohaq::coordinator::CancelToken;
    let session = SearchSession::synthetic().unwrap();
    let token = CancelToken::new();
    token.cancel();
    let err = session
        .run_with_cancel(&ExperimentSpec::exp1(), |_| {}, &token)
        .unwrap_err();
    assert!(matches!(err, SearchError::Cancelled), "{err:?}");
    assert_eq!(err.kind(), "cancelled");
    assert_eq!(session.eval().stats().executions, 0, "no work after cancel");
}

// ------------------------------------------------------------ spec builder

#[test]
fn builder_output_survives_json_roundtrip_for_all_presets() {
    for spec in [
        ExperimentSpec::exp1(),
        ExperimentSpec::exp2_silago(),
        ExperimentSpec::exp3_bitfusion(false),
        ExperimentSpec::exp3_bitfusion(true),
        ExperimentSpec::cross_platform(),
    ] {
        let json = spec.to_json_string();
        let back = ExperimentSpec::from_json_str(&json).unwrap();
        assert_eq!(spec, back, "json roundtrip changed '{}':\n{json}", spec.name);
    }
}

#[test]
fn builder_chain_matches_issue_example() {
    use mohaq::coordinator::BeaconPolicyOverrides;
    let spec = ExperimentSpec::builder()
        .platform("silago")
        .sram_mb(6.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .beacon(BeaconPolicyOverrides::default())
        .build()
        .unwrap();
    assert_eq!(spec.platforms[0].f64("sram_mb"), Some(6.0));
    assert!(spec.beacon.is_some());
    // SiLago ties W=A: the session will search the halved genome.
    let (_, bindings) = spec.resolve_objectives().unwrap();
    assert!(bindings[0].platform.tied_wa());
}

#[test]
fn builder_enforces_tied_wa_for_silago() {
    let err = ExperimentSpec::builder()
        .platform("silago")
        .objective(ScoredObjective::error())
        .tied(false)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("ties weight and activation"), "{err}");

    // Explicitly tying an untied platform is allowed (halves the genome).
    let spec = ExperimentSpec::builder()
        .platform("bitfusion")
        .objective(ScoredObjective::error())
        .tied(true)
        .build()
        .unwrap();
    assert_eq!(spec.tied, Some(true));
}

#[test]
fn cross_platform_spec_round_trips_and_rebinds() {
    // The acceptance shape: platform-bound objectives with per-platform
    // parameters survive JSON, and the resolved labels carry bindings.
    let spec = ExperimentSpec::builder()
        .name("joint")
        .platform("silago")
        .sram_mb(6.0)
        .platform("bitfusion")
        .sram_mb(2.0)
        .objective(ScoredObjective::error())
        .platform_objective("silago", ScoredObjective::neg_speedup())
        .platform_objective("silago", ScoredObjective::energy_uj())
        .platform_objective("bitfusion", ScoredObjective::neg_speedup())
        .build()
        .unwrap();
    let json = spec.to_json_string();
    let back = ExperimentSpec::from_json_str(&json).unwrap();
    assert_eq!(spec, back, "cross-platform spec changed in roundtrip:\n{json}");

    let (bound, bindings) = back.resolve_objectives().unwrap();
    let labels: Vec<&str> = bound.iter().map(|o| o.label.as_str()).collect();
    assert_eq!(labels, ["WER_V", "-speedup@silago", "energy_uJ@silago", "-speedup@bitfusion"]);
    assert_eq!(bindings[0].spec.f64("sram_mb"), Some(6.0));
    assert_eq!(bindings[1].spec.f64("sram_mb"), Some(2.0));
}

#[test]
fn config_json_covers_the_presets() {
    // A config file reproducing the exp2 preset parses to the same spec
    // (field-for-field), proving `--config` parity with `--exp`.
    let preset = ExperimentSpec::exp2_silago();
    let config = r#"{
        "name": "exp2-silago",
        "platform": {"name": "silago", "params": {"sram_mb": 6.0}},
        "objectives": ["error", "neg_speedup", "energy_uj"],
        "ga": {"pop_size": 10, "initial_pop_size": 40, "generations": 15,
               "crossover_prob": 0.9, "seed": 24301},
        "err_feasible_pp": 8.0
    }"#;
    let parsed = mohaq::config::spec_from_json(config).unwrap();
    assert_eq!(parsed, preset);
}

// --------------------------------------------------------- session plumbing

#[test]
fn zdt_smoke_front_is_identical_for_one_and_many_threads() {
    let problem = Zdt::new(ZdtVariant::Zdt1, 8, 32);
    let ga = Nsga2Config {
        pop_size: 12,
        initial_pop_size: 24,
        generations: 12,
        seed: 0xF17ED,
        ..Default::default()
    };
    let one = SearchSession::run_generic(&problem, ga.clone(), 1);
    let many = SearchSession::run_generic(&problem, ga, 8);
    assert!(!one.is_empty());
    assert_eq!(one.len(), many.len(), "front sizes diverged");
    for (a, b) in one.iter().zip(&many) {
        assert_eq!(a.genome, b.genome);
        let ao: Vec<u64> = a.objectives.iter().map(|v| v.to_bits()).collect();
        let bo: Vec<u64> = b.objectives.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ao, bo, "objectives not bitwise identical");
    }
}

#[test]
fn island_session_smoke_merges_a_front() {
    let problem = Zdt::new(ZdtVariant::Zdt1, 8, 32);
    let ga = Nsga2Config {
        pop_size: 10,
        initial_pop_size: 12,
        generations: 10,
        seed: 0xF17ED,
        ..Default::default()
    };
    let cfg = IslandConfig {
        islands: 3,
        migration_interval: 2,
        topology: Topology::FullyConnected,
        migrants: 2,
    };
    let front = SearchSession::run_generic_islands(&problem, ga, cfg, 4);
    assert!(!front.is_empty());
    // The merge deduplicates: genomes are unique.
    let mut genomes: Vec<&Vec<i64>> = front.iter().map(|i| &i.genome).collect();
    genomes.sort();
    genomes.dedup();
    assert_eq!(genomes.len(), front.len());
}

#[test]
fn poisoned_eval_cache_surfaces_typed_error_not_panic() {
    // Regression: a worker that panicked while holding the EvalService
    // cache lock used to make every OTHER worker panic too ("cache
    // poisoned" .expect), killing the pool. The cache now returns a typed
    // error which the session boundary maps to SearchError::Poisoned.
    let cache: ResultCache<u32, f64> = ResultCache::new();
    cache.insert(1, 0.5).unwrap();
    assert_eq!(cache.len(), Some(1));
    assert!(!cache.poisoned());
    cache.poison_for_test();

    let err = cache.get(&1).unwrap_err();
    assert!(err.to_string().contains("poisoned"), "{err}");
    assert!(cache.insert(2, 1.0).is_err(), "insert must fail once poisoned");
    // Post-incident stats must say "poisoned", not "0 unique solutions":
    // a silent zero made EvalStats lie after a worker crash.
    assert_eq!(cache.len(), None, "poisoned cache must not report a count");
    assert!(cache.poisoned(), "the poisoned marker must be set");

    // The exact payload MohaqProblem produces from that error classifies
    // as Poisoned at the session boundary (not a generic Eval failure).
    let payload = format!("candidate evaluation failed: {err:#}");
    match SearchError::from_panic(payload) {
        SearchError::Poisoned(msg) => {
            assert!(msg.contains("eval cache poisoned"), "{msg}")
        }
        other => panic!("expected SearchError::Poisoned, got {other:?}"),
    }
    // Unrelated panics still map to the evaluation-failure variant.
    assert!(matches!(
        SearchError::from_panic("candidate evaluation failed: device lost".into()),
        SearchError::Eval(_)
    ));
}

#[test]
fn session_surfaces_eval_errors_as_typed_variants() {
    // Artifacts::load on a bogus dir fails before a session exists; the
    // session constructor itself only fails on runtime creation. Exercise
    // the typed boundary through spec validation instead, plus Display.
    let err = ExperimentSpec::builder().build().unwrap_err();
    assert!(matches!(err, SearchError::InvalidSpec(_)));
    assert!(err.to_string().starts_with("invalid experiment spec:"));
    // SearchError converts into anyhow::Error at `?` boundaries.
    fn through_anyhow(e: SearchError) -> anyhow::Error {
        e.into()
    }
    let msg = format!("{}", through_anyhow(err));
    assert!(msg.contains("at least one objective"), "{msg}");
}
