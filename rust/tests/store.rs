//! Durable-state integration tests — hermetic (surrogate evaluator, no
//! artifacts): real checkpoint files and eval stores on disk, real
//! resume runs, real serve processes warm-starting from a store.
//!
//! Covers the acceptance contracts of the store tentpole:
//!   * checkpoint files round-trip losslessly (RNG words as decimal
//!     strings, populations bit for bit) and save/load/save is
//!     byte-identical;
//!   * a search resumed from a mid-run checkpoint finishes with a front
//!     BITWISE-identical to the uninterrupted run — single-process and
//!     distributed (simulated coordinator crash included);
//!   * the eval store snapshots the PTQ memo + beacon param sets and a
//!     fresh session (or a restarted serve server) answers repeated
//!     configs from cache — no re-executions, bitwise-equal values;
//!   * beacon runs checkpoint their beacons (config + parameter-set
//!     name): a resume restores them through the eval store and matches
//!     the uninterrupted run bitwise, and a resume WITHOUT the store is
//!     a typed rejection naming the missing set — never a silent
//!     re-retrain.

use mohaq::coordinator::{BeaconPolicyOverrides, BeaconSnapshot};

use std::net::SocketAddr;
use std::path::PathBuf;
use std::time::Duration;

use mohaq::coordinator::{
    CancelToken, ExperimentSpec, ScoredObjective, SearchError, SearchOutcome, SearchSession,
};
use mohaq::dist::DistConfig;
use mohaq::eval::CacheKey;
use mohaq::moo::{IslandConfig, IslandSnapshot, Topology};
use mohaq::quant::{Bits, QuantConfig};
use mohaq::serve::{ServeClient, ServeState, Server};
use mohaq::store::{eval_store, SearchCheckpoint};

/// A scratch file under a per-process temp directory (tests in one
/// binary may run concurrently, so every caller picks a distinct name).
fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mohaq-store-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The shared fixture: 4 islands, migration every 2 of 6 generations —
/// boundaries at generation 2 and 4, so a checkpoint always exists
/// strictly mid-run. Same shape as the dist test fixture.
fn island_spec(topology: Topology) -> ExperimentSpec {
    let mut spec = ExperimentSpec::builder()
        .name("store-silago")
        .platform("silago")
        .sram_mb(6.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(8)
        .initial_pop_size(16)
        .generations(6)
        .seed(0x570CA)
        .err_feasible_pp(25.0)
        .build()
        .unwrap();
    spec.island = Some(IslandConfig {
        islands: 4,
        migration_interval: 2,
        topology,
        migrants: 2,
    });
    spec
}

/// The determinism contract, at full strength: same front, bit for bit.
fn assert_fronts_bitwise_equal(resumed: &SearchOutcome, reference: &SearchOutcome) {
    assert_eq!(resumed.objective_names, reference.objective_names, "objective labels diverged");
    assert_eq!(resumed.evaluations, reference.evaluations, "evaluation totals diverged");
    assert_eq!(resumed.rows.len(), reference.rows.len(), "front size diverged");
    for (r, l) in resumed.rows.iter().zip(&reference.rows) {
        assert_eq!(r.qc.display_wa(), l.qc.display_wa(), "genomes diverged");
        assert_eq!(r.wer_v.to_bits(), l.wer_v.to_bits(), "wer_v not bitwise equal");
        assert_eq!(r.wer_t.to_bits(), l.wer_t.to_bits(), "wer_t not bitwise equal");
        assert_eq!(r.size_mb.to_bits(), l.size_mb.to_bits());
        assert_eq!(r.hw.len(), l.hw.len());
        for (rh, lh) in r.hw.iter().zip(&l.hw) {
            assert_eq!(rh.platform, lh.platform);
            assert_eq!(rh.speedup.to_bits(), lh.speedup.to_bits());
        }
    }
    match (resumed.front_hypervolume, reference.front_hypervolume) {
        (Some(a), Some(b)) => assert_eq!(a.to_bits(), b.to_bits(), "hypervolume diverged"),
        (a, b) => assert_eq!(a.is_some(), b.is_some(), "hypervolume presence diverged"),
    }
}

/// Harvest the FIRST migration-boundary checkpoint from a full run of
/// `spec`; also returns the run's outcome (the bitwise reference).
fn first_checkpoint(spec: &ExperimentSpec) -> ((usize, Vec<IslandSnapshot>), SearchOutcome) {
    let mut first: Option<(usize, Vec<IslandSnapshot>)> = None;
    let mut sink = |gen: usize, snaps: &[IslandSnapshot], _beacons: &[BeaconSnapshot]| {
        if first.is_none() {
            first = Some((gen, snaps.to_vec()));
        }
    };
    let sink_opt: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])> =
        Some(&mut sink);
    let outcome = SearchSession::synthetic()
        .unwrap()
        .run_checkpointed(spec, |_| {}, sink_opt, &CancelToken::new())
        .unwrap();
    (first.expect("a 4-island 6-generation run must hit a boundary"), outcome)
}

#[test]
fn checkpoint_files_round_trip_losslessly_and_deterministically() {
    let spec = island_spec(Topology::Ring);
    let ((gen, mut snaps), _) = first_checkpoint(&spec);

    // Push the codec to its edges: RNG words that do not survive an f64
    // round-trip (why they travel as decimal strings) and an evaluation
    // count beyond 2^53.
    snaps[0].rng = [u64::MAX, 0, 1, 0x8000_0000_0000_0001];
    snaps[1].evaluations = (1u64 << 60) as usize;

    let ckpt = SearchCheckpoint::new(spec.clone(), gen, snaps, Vec::new()).unwrap();
    let text = ckpt.to_json().to_string();
    let back = SearchCheckpoint::from_str(&text).unwrap();
    assert_eq!(back.generation, ckpt.generation);
    assert_eq!(back.snapshots, ckpt.snapshots, "snapshots did not round-trip bit for bit");
    assert_eq!(
        back.spec.to_json().to_string(),
        ckpt.spec.to_json().to_string(),
        "spec did not round-trip"
    );

    // save -> load -> save is byte-identical (atomic_write + a canonical
    // serialization = checkpoint files diff cleanly across interrupts).
    let path_a = temp_path("roundtrip_a.json");
    let path_b = temp_path("roundtrip_b.json");
    ckpt.save(&path_a).unwrap();
    let loaded = SearchCheckpoint::load(&path_a).unwrap();
    loaded.save(&path_b).unwrap();
    assert_eq!(
        std::fs::read(&path_a).unwrap(),
        std::fs::read(&path_b).unwrap(),
        "re-saving a loaded checkpoint changed the bytes"
    );
}

#[test]
fn resumed_search_matches_the_uninterrupted_run_bitwise() {
    for topology in [Topology::Ring, Topology::FullyConnected] {
        let spec = island_spec(topology);
        // Reference: the plain uninterrupted run; run_checkpointed with a
        // sink must not perturb it.
        let reference = SearchSession::synthetic().unwrap().run(&spec).unwrap();
        assert!(!reference.rows.is_empty(), "reference front is empty (bad fixture)");
        let ((gen, snaps), full) = first_checkpoint(&spec);
        assert_fronts_bitwise_equal(&full, &reference);
        assert!(gen > 0 && gen < spec.ga.generations, "checkpoint not strictly mid-run");

        // Through the real file format, into a FRESH session (cold cache:
        // proves the front depends on the checkpoint, not leftover state).
        let path = temp_path(&format!("resume_{topology:?}.json"));
        SearchCheckpoint::new(spec.clone(), gen, snaps, Vec::new()).unwrap().save(&path).unwrap();
        let ckpt = SearchCheckpoint::load(&path).unwrap();
        let resumed = SearchSession::synthetic()
            .unwrap()
            .run_resumed(
                &ckpt.spec,
                ckpt.generation,
                ckpt.snapshots,
                ckpt.beacons,
                |_| {},
                None,
                &CancelToken::new(),
            )
            .unwrap();
        assert_fronts_bitwise_equal(&resumed, &reference);
    }
}

/// Start a hermetic worker server on an ephemeral port (dist test idiom).
fn spawn_worker() -> (SocketAddr, std::thread::JoinHandle<std::io::Result<()>>) {
    let state = ServeState::worker(SearchSession::synthetic().unwrap(), 2);
    let server = Server::bind("127.0.0.1:0", state).unwrap();
    let addr = server.local_addr().unwrap();
    (addr, std::thread::spawn(move || server.run()))
}

fn stop_worker(addr: SocketAddr) {
    use std::io::{BufRead, BufReader, Write};
    if let Ok(mut s) = std::net::TcpStream::connect(addr) {
        let _ = s.write_all(b"{\"op\":\"shutdown\"}\n");
        let _ = s.flush();
        let mut line = String::new();
        let _ = BufReader::new(s).read_line(&mut line);
    }
}

#[test]
fn distributed_resume_after_coordinator_crash_matches_bitwise() {
    let spec = island_spec(Topology::Ring);
    let reference = SearchSession::synthetic().unwrap().run(&spec).unwrap();

    let workers: Vec<_> = (0..2).map(|_| spawn_worker()).collect();
    let addrs: Vec<String> = workers.iter().map(|(a, _)| a.to_string()).collect();

    // "Crash" the coordinator right after its first durable boundary: the
    // checkpoint sink records the state, then cancels the run — the
    // worker processes keep running (they hold no cross-search state).
    let cancel = CancelToken::new();
    let trigger = cancel.clone();
    let mut recorded: Option<(usize, Vec<IslandSnapshot>)> = None;
    let mut sink = |gen: usize, snaps: &[IslandSnapshot], _beacons: &[BeaconSnapshot]| {
        if recorded.is_none() {
            recorded = Some((gen, snaps.to_vec()));
            trigger.cancel();
        }
    };
    let sink_opt: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])> =
        Some(&mut sink);
    let err = SearchSession::synthetic()
        .unwrap()
        .run_distributed_resumable(
            &spec,
            &addrs,
            &DistConfig::default(),
            None,
            sink_opt,
            |_| {},
            &cancel,
        )
        .expect_err("the interrupted coordinator must not finish");
    assert!(matches!(err, SearchError::Cancelled), "expected Cancelled, got {err:?}");
    let (gen, snaps) = recorded.expect("the sink never fired");
    assert!(gen < spec.ga.generations, "checkpoint not strictly mid-run");

    // A brand-new coordinator process-equivalent (fresh session, fresh
    // connections) resumes from the written file against the SAME still-
    // running workers and lands on the identical front.
    let path = temp_path("dist_resume.json");
    SearchCheckpoint::new(spec.clone(), gen, snaps, Vec::new()).unwrap().save(&path).unwrap();
    let ckpt = SearchCheckpoint::load(&path).unwrap();
    let resumed = SearchSession::synthetic()
        .unwrap()
        .run_distributed_resumable(
            &ckpt.spec,
            &addrs,
            &DistConfig::default(),
            Some((ckpt.generation, ckpt.snapshots, ckpt.beacons)),
            None,
            |_| {},
            &CancelToken::new(),
        )
        .expect("resume against the surviving workers");
    assert_fronts_bitwise_equal(&resumed, &reference);

    for (addr, handle) in workers {
        stop_worker(addr);
        handle.join().unwrap().unwrap();
    }
}

#[test]
fn eval_store_round_trips_the_memo_and_warm_starts_a_fresh_session() {
    // Session A: populate the memo — two executed configs on the
    // baseline set, plus a registered param set with an imported entry
    // (standing in for a beacon's retrained parameters).
    let a = SearchSession::synthetic().unwrap();
    let n = a.artifacts().layer_names.len();
    let qc4 = QuantConfig::uniform(n, Bits::from_bits(4).unwrap(), Bits::from_bits(4).unwrap());
    let qc8 = QuantConfig::uniform(n, Bits::from_bits(8).unwrap(), Bits::from_bits(8).unwrap());
    let e4 = a.eval().val_error(&qc4, 0).unwrap();
    let e8 = a.eval().val_error(&qc8, 0).unwrap();
    let host: Vec<Vec<f32>> = a
        .artifacts()
        .tensors
        .iter()
        .map(|t| vec![0.25f32; t.shape.iter().product()])
        .collect();
    let warm_idx = a.eval().add_param_set("warm-beacon", host).unwrap();
    a.eval().import_entries(vec![(CacheKey::new(warm_idx, &qc4), 0.123)]).unwrap();

    let path = temp_path("eval_store.json");
    eval_store::save(&path, a.eval()).unwrap();

    // Session B: reload everything, byte-deterministically.
    let b = SearchSession::synthetic().unwrap();
    let report = eval_store::load(&path, b.eval(), false).unwrap();
    assert_eq!(report.param_sets_registered, 1);
    assert_eq!(report.param_sets_skipped, 0);
    assert_eq!(report.entries_loaded, 3);
    assert_eq!(report.entries_dropped, 0);
    let resaved = temp_path("eval_store_resaved.json");
    eval_store::save(&resaved, b.eval()).unwrap();
    assert_eq!(
        std::fs::read(&path).unwrap(),
        std::fs::read(&resaved).unwrap(),
        "save -> load -> save changed the bytes"
    );

    // Warm start: repeated configs are pure cache hits — no executions,
    // values bitwise equal to what session A computed.
    let stats0 = b.eval().stats();
    assert_eq!(b.eval().val_error(&qc4, 0).unwrap().to_bits(), e4.to_bits());
    assert_eq!(b.eval().val_error(&qc8, 0).unwrap().to_bits(), e8.to_bits());
    let stats1 = b.eval().stats();
    assert_eq!(stats1.executions, stats0.executions, "warm start re-executed");
    assert_eq!(stats1.cache_hits, stats0.cache_hits + 2);
    // The imported beacon entry landed under B's live index for the set.
    let warm_b = b
        .eval()
        .snapshot_param_sets()
        .unwrap()
        .into_iter()
        .find(|(_, ps)| ps.name == "warm-beacon")
        .map(|(idx, _)| idx)
        .expect("the beacon set was not re-registered");
    assert!(
        b.eval()
            .export_entries()
            .unwrap()
            .contains(&(CacheKey::new(warm_b, &qc4), 0.123)),
        "the beacon memo entry did not survive the reload"
    );

    // Session C honors --evict-beacons on load: baseline entries only,
    // the beacon set and its entry reported as skipped/dropped.
    let c = SearchSession::synthetic().unwrap();
    let report = eval_store::load(&path, c.eval(), true).unwrap();
    assert_eq!(report.param_sets_registered, 0);
    assert_eq!(report.param_sets_skipped, 1);
    assert_eq!(report.entries_loaded, 2);
    assert_eq!(report.entries_dropped, 1);
    let stats0 = c.eval().stats();
    assert_eq!(c.eval().val_error(&qc4, 0).unwrap().to_bits(), e4.to_bits());
    assert_eq!(c.eval().stats().executions, stats0.executions);
}

/// Serve quickstart spec (serve test idiom): wide feasibility so the
/// front is never empty.
fn serve_spec() -> ExperimentSpec {
    ExperimentSpec::builder()
        .name("store-tenant")
        .platform("silago")
        .sram_mb(6.0)
        .objective(ScoredObjective::error())
        .objective(ScoredObjective::neg_speedup())
        .pop_size(8)
        .initial_pop_size(16)
        .generations(6)
        .seed(0x5708E)
        .err_feasible_pp(25.0)
        .build()
        .unwrap()
}

/// Start a hermetic serve server, keeping a handle on its shared state
/// (what `mohaq serve --store DIR` uses to save/reload the eval store).
fn spawn_server_with_state(
) -> (SocketAddr, std::thread::JoinHandle<std::io::Result<()>>, std::sync::Arc<ServeState>) {
    let state = ServeState::new(SearchSession::synthetic().unwrap(), 2);
    let keep = state.clone();
    let server = Server::bind("127.0.0.1:0", state).unwrap();
    let addr = server.local_addr().unwrap();
    (addr, std::thread::spawn(move || server.run()), keep)
}

#[test]
fn restarted_server_warm_starts_from_the_eval_store() {
    let path = temp_path("serve_store.json");

    // First server lifetime: run a search, save the store at shutdown.
    let (addr, handle, state) = spawn_server_with_state();
    let mut client = ServeClient::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap();
    let cold = client.search(&serve_spec()).unwrap();
    assert!(!cold.rows.is_empty(), "cold front is empty");
    let stats = client.stats().unwrap();
    assert!(stats.unique_solutions > 0);
    assert_eq!(stats.evictions, 0);
    assert_eq!(stats.param_sets_evicted, 0);
    client.shutdown().unwrap();
    handle.join().unwrap().unwrap();
    eval_store::save(&path, state.session().eval()).unwrap();

    // Second server lifetime: reload the store, then answer the SAME
    // spec — hits on the very first post-restart request, search-phase
    // executions at most the final report's per-row test scoring, and a
    // bitwise-identical front.
    let (addr, handle, state) = spawn_server_with_state();
    let report = eval_store::load(&path, state.session().eval(), false).unwrap();
    assert!(report.entries_loaded > 0, "the store carried no memo entries");
    let mut client = ServeClient::connect_retry(&addr.to_string(), Duration::from_secs(10)).unwrap();
    let warm = client.search(&serve_spec()).unwrap();
    assert!(warm.cache_hits > 0, "first post-restart request must hit the reloaded cache");
    assert!(
        warm.exec_calls <= warm.rows.len(),
        "warm request re-executed {} times for {} rows",
        warm.exec_calls,
        warm.rows.len()
    );
    assert_eq!(warm.rows.len(), cold.rows.len());
    for (w, c) in warm.rows.iter().zip(&cold.rows) {
        assert_eq!(w.config, c.config);
        assert_eq!(w.wer_v.to_bits(), c.wer_v.to_bits());
    }
    // Server-level counters agree with the per-request view.
    let stats = client.stats().unwrap();
    assert!(stats.cache_hits >= warm.cache_hits);
    client.shutdown().unwrap();
    handle.join().unwrap().unwrap();
}

/// The island fixture with a beacon policy sized for the surrogate:
/// cheap retrains, two beacons max (same shape as the dist beacon test).
fn beacon_island_spec() -> ExperimentSpec {
    let mut spec = island_spec(Topology::Ring);
    spec.name = "store-silago-beacon".into();
    spec.beacon = Some(BeaconPolicyOverrides {
        threshold: None,
        retrain_steps: Some(6),
        max_beacons: Some(2),
    });
    spec
}

#[test]
fn beacon_checkpoints_round_trip_and_validate_strictly() {
    let spec = beacon_island_spec();
    let ((gen, snaps), _) = first_checkpoint(&spec);
    let beacons = vec![BeaconSnapshot {
        qc: QuantConfig::uniform(8, Bits::from_bits(4).unwrap(), Bits::from_bits(4).unwrap()),
        set_name: "beacon0[w4 a4]".into(),
    }];

    // Round trip: the beacon payload (config + set name) survives the
    // file format exactly.
    let ckpt = SearchCheckpoint::new(spec.clone(), gen, snaps.clone(), beacons.clone()).unwrap();
    let back = SearchCheckpoint::from_str(&ckpt.to_json().to_string()).unwrap();
    assert_eq!(back.beacons, beacons, "beacons did not round-trip");

    // Beacons without a beacon policy in the spec: typed rejection (this
    // pins the old bug of serializing `beacons: Vec::new()` — a payload
    // the spec cannot explain must never load silently).
    let plain = island_spec(Topology::Ring);
    let err = SearchCheckpoint::new(plain, gen, snaps, beacons).unwrap_err();
    assert!(err.to_string().contains("beacon policy"), "{err}");

    // Strictness: an unknown key inside a beacon entry is rejected.
    let mut text = ckpt.to_json().to_string();
    text = text.replace("\"set_name\"", "\"extra\":1,\"set_name\"");
    assert!(SearchCheckpoint::from_str(&text).is_err(), "unknown beacon key accepted");
}

#[test]
fn beacon_resume_restores_through_the_eval_store_and_rejects_without_it() {
    let spec = beacon_island_spec();

    // Reference run; at every boundary capture the checkpoint payload
    // AND the eval store as it stood at that instant (what `mohaq search
    // --store --checkpoint --stop-after-checkpoints` persists together —
    // the store must hold exactly the sets the checkpoint references).
    let session = SearchSession::synthetic().unwrap();
    let eval = session.eval().clone();
    let mut grabs: Vec<(usize, Vec<IslandSnapshot>, Vec<BeaconSnapshot>, PathBuf)> = Vec::new();
    let mut sink = |gen: usize, snaps: &[IslandSnapshot], beacons: &[BeaconSnapshot]| {
        let p = temp_path(&format!("beacon_resume_store_{gen}.json"));
        eval_store::save(&p, &eval).unwrap();
        grabs.push((gen, snaps.to_vec(), beacons.to_vec(), p));
    };
    let sink_opt: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])> =
        Some(&mut sink);
    let reference = session.run_checkpointed(&spec, |_| {}, sink_opt, &CancelToken::new()).unwrap();
    assert!(!reference.rows.is_empty(), "reference front is empty (bad fixture)");
    assert!(!reference.beacons.is_empty(), "reference run created no beacons (bad fixture)");

    // Resume from the first boundary that had finalized beacons.
    let (gen, snaps, beacons, store_path) = grabs
        .into_iter()
        .find(|(_, _, b, _)| !b.is_empty())
        .expect("no migration boundary saw a finalized beacon");
    let path = temp_path("beacon_resume.json");
    SearchCheckpoint::new(spec.clone(), gen, snaps, beacons.clone()).unwrap().save(&path).unwrap();
    let ckpt = SearchCheckpoint::load(&path).unwrap();
    assert_eq!(ckpt.beacons, beacons, "beacon payload did not survive the file");

    // A fresh session WITHOUT the eval store: typed rejection naming the
    // missing parameter set — never a silent re-retrain.
    let err = SearchSession::synthetic()
        .unwrap()
        .run_resumed(
            &ckpt.spec,
            ckpt.generation,
            ckpt.snapshots.clone(),
            ckpt.beacons.clone(),
            |_| {},
            None,
            &CancelToken::new(),
        )
        .expect_err("resume without the eval store must be rejected");
    assert!(err.to_string().contains(&beacons[0].set_name), "{err}");

    // With the store reloaded first (set names resolve back to the same
    // indices), the resumed run matches the uninterrupted one bitwise —
    // beacons included.
    let fresh = SearchSession::synthetic().unwrap();
    let report = eval_store::load(&store_path, fresh.eval(), false).unwrap();
    assert!(report.param_sets_registered >= 1, "the boundary store carried no beacon sets");
    let resumed = fresh
        .run_resumed(
            &ckpt.spec,
            ckpt.generation,
            ckpt.snapshots,
            ckpt.beacons,
            |_| {},
            None,
            &CancelToken::new(),
        )
        .expect("resume with the eval store loaded");
    assert_eq!(resumed.beacons, reference.beacons, "beacon outcomes diverged across resume");
    assert_fronts_bitwise_equal(&resumed, &reference);
}
