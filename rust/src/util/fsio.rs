//! Crash-safe filesystem helpers.
//!
//! [`atomic_write`] is the one write primitive every durable-state file
//! in the repo goes through (the `store` subsystem, `bench-gate
//! --write-baseline`): readers either see the complete previous file or
//! the complete new one, never a torn prefix.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Per-process counter so concurrent writers in one process never race
/// on the same temp name (the pid alone distinguishes processes).
static TMP_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Write `bytes` to `path` atomically: the data lands in a temp file in
/// the SAME directory (rename across filesystems is not atomic), is
/// fsync'd, and is renamed over the target in one step. On any failure
/// the temp file is removed and the previous contents of `path` are
/// untouched. The directory entry is fsync'd best-effort afterwards so
/// the rename itself survives a crash on journaling filesystems.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    let base = path
        .file_name()
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("{} has no file name", path.display()),
            )
        })?;
    let tmp = dir.join(format!(
        ".{}.tmp.{}.{}",
        base.to_string_lossy(),
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let write_and_rename = || -> std::io::Result<()> {
        let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&tmp)?;
        f.write_all(bytes)?;
        // Data must be on disk BEFORE the rename makes it visible — a
        // rename of unsynced data can survive a crash as an empty file.
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)
    };
    if let Err(e) = write_and_rename() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    // Persist the directory entry too; failure here does not un-write
    // the file, so it is advisory.
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mohaq_fsio_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn atomic_write_replaces_contents_and_leaves_no_temp_files() {
        let dir = tmp_dir("replace");
        let path = dir.join("state.json");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second version").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second version");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files leaked: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_write_preserves_the_previous_file() {
        let dir = tmp_dir("preserve");
        let path = dir.join("state.json");
        atomic_write(&path, b"durable").unwrap();
        // Writing THROUGH a missing parent directory must fail cleanly...
        let bad = dir.join("no_such_subdir").join("state.json");
        assert!(atomic_write(&bad, b"x").is_err());
        // ...and a directory path (no file name) is a typed error.
        assert!(atomic_write(Path::new("/"), b"x").is_err());
        assert_eq!(std::fs::read(&path).unwrap(), b"durable");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
