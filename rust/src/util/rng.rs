//! Deterministic PRNG (SplitMix64 + xoshiro256**) — no external crates.
//!
//! Used by the genetic algorithm and the property-test harness. Everything
//! downstream of a seed is reproducible across runs and platforms, which is
//! what makes the experiment drivers in examples/ regenerate the same
//! Pareto sets.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Derive an independent stream (for per-thread / per-island RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw xoshiro state — lets a suspended computation (an island
    /// shard shipped to another process) resume its stream exactly where
    /// it stopped.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Fork `k` independent child streams in one call (tags 1..=k) — one
    /// per island of an archipelago. Consumes k draws from this stream,
    /// so the children are a pure function of (seed, k, position).
    pub fn split(&mut self, k: usize) -> Vec<Rng> {
        (1..=k).map(|tag| self.fork(tag as u64)).collect()
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our n << 2^64 use cases.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_range_uniformly() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            // Each bucket should be ~10k; allow generous slack.
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(20, 8);
        assert_eq!(idx.len(), 8);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn split_streams_are_distinct_and_reproducible() {
        let streams = |seed: u64| {
            let mut base = Rng::new(seed);
            base.split(4)
                .into_iter()
                .map(|mut r| (0..64).map(|_| r.next_u64()).collect::<Vec<_>>())
                .collect::<Vec<_>>()
        };
        let a = streams(7);
        let b = streams(7);
        assert_eq!(a, b, "split must be a pure function of the seed");
        for i in 0..a.len() {
            for j in 0..i {
                assert_ne!(a[i], a[j], "streams {i} and {j} coincide");
            }
        }
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::new(0xC0FFEE);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
