//! Minimal JSON codec (no external crates are available offline — see
//! Cargo.toml). Covers the full JSON grammar we produce and consume:
//! manifest.json, calibration.json, experiment configs and reports.
//!
//! Numbers are kept as f64 (the artifacts only contain ints that fit
//! exactly and f64 floats, both emitted by Python's json module).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error mentioning the key — for required
    /// manifest fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// f64 array helper (shapes, clip tables).
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }

    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    // ------------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----------------------------------------------------------- emitting

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_nan() {
                    // Non-finite spellings match our parser (and Python's
                    // json module): Rust's Display would emit "inf"/"NaN"
                    // forms the grammar rejects, breaking round-trips of
                    // e.g. a generation log with no feasible solution yet.
                    out.push_str("NaN");
                } else if n.is_infinite() {
                    out.push_str(if *n > 0.0 { "Infinity" } else { "-Infinity" });
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting ceiling: recursive descent on untrusted input (serve-mode
/// frames arrive over TCP) must error out long before the thread stack
/// overflows — a stack overflow aborts the whole process, not just the
/// connection.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(text.as_bytes()) {
            self.i += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: only BMP escapes appear in our
                            // artifacts; map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// Convenience constructors used by the report/config writers.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from (key, value) pairs: `obj([("a", 1.0.into())])`.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn parses_scientific_and_negative() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5e-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::parse(r#"{"x": [1.5, "s"], "y": {"z": true}}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(4.0).to_string(), "4");
        assert_eq!(Json::Num(4.5).to_string(), "4.5");
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing_the_stack() {
        // Serve mode feeds this parser untrusted TCP input; a recursion
        // bomb must be a parse error, not a process abort.
        let bomb = "[".repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{err}");
        // Reasonable nesting still parses.
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn non_finite_numbers_round_trip() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "Infinity");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "-Infinity");
        assert_eq!(Json::Num(f64::NAN).to_string(), "NaN");
        assert_eq!(Json::parse("Infinity").unwrap().as_f64(), Some(f64::INFINITY));
        assert_eq!(Json::parse("-Infinity").unwrap().as_f64(), Some(f64::NEG_INFINITY));
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
    }

    #[test]
    fn accessor_helpers() {
        let v = Json::parse(r#"{"shape": [2, 3, 4]}"#).unwrap();
        assert_eq!(v.get("shape").unwrap().usize_vec().unwrap(), vec![2, 3, 4]);
        assert!(v.req("missing").is_err());
        assert!(v.req("shape").is_ok());
    }
}
