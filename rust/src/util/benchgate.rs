//! Bench-regression gate: diff a fresh perf report (the JSON artifact the
//! benches accrete via `Bencher::emit_json`) against the committed
//! baseline `BENCH_baseline.json`, failing on throughput regressions.
//!
//! Raw items/s cannot be compared across machines (the CI runner draw
//! alone swings >25%), so both reports carry a CALIBRATION section — a
//! fixed integer spin measured like any other bench — and every
//! throughput is normalized by its own file's calibration throughput
//! before the comparison. The gate therefore measures "eval throughput
//! relative to how fast this machine spins", which is stable across
//! runner generations.
//!
//! Bootstrap: a baseline with a top-level `"provisional": true` marker
//! (committed before any CI run could measure real numbers) reports the
//! comparison but never fails — the first green bench-smoke run's
//! artifact is the intended replacement.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Section/bench the calibration spin reports under (see
/// `benches/bench_runtime.rs`).
pub const CALIBRATION_SECTION: &str = "calibration";
pub const CALIBRATION_NAME: &str = "calibration spin";

/// One (section, bench) pair present in both reports.
#[derive(Debug, Clone)]
pub struct Comparison {
    pub section: String,
    pub name: String,
    /// Calibration-normalized throughput scores (dimensionless).
    pub baseline: f64,
    pub current: f64,
    /// current/baseline - 1 in percent; negative is a slowdown.
    pub delta_pct: f64,
}

#[derive(Debug, Default)]
pub struct GateOutcome {
    pub checked: Vec<Comparison>,
    /// Human-readable failure lines; empty means the gate passes.
    pub failures: Vec<String>,
    /// Non-fatal observations (new benches, missing calibration, ...).
    pub notes: Vec<String>,
}

impl GateOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Every throughput-carrying bench in a report, keyed (section, name).
/// Sections are the top-level keys whose value is an array of bench
/// objects; top-level markers (`provisional`, notes) are skipped.
fn throughputs(report: &Json) -> BTreeMap<(String, String), f64> {
    let mut out = BTreeMap::new();
    let Some(root) = report.as_obj() else {
        return out;
    };
    for (section, value) in root {
        let Some(benches) = value.as_arr() else {
            continue;
        };
        for bench in benches {
            let (Some(name), Some(tp)) = (
                bench.get("name").and_then(Json::as_str),
                bench.get("throughput").and_then(Json::as_f64),
            ) else {
                continue;
            };
            if tp > 0.0 {
                out.insert((section.clone(), name.to_string()), tp);
            }
        }
    }
    out
}

/// Compare `current` against `baseline`, failing any bench whose
/// calibration-normalized throughput dropped more than `max_regress_pct`
/// percent. Benches present in only one report are noted, never failed
/// (new benches must not brick CI; removed ones show up in review).
pub fn gate(baseline: &Json, current: &Json, max_regress_pct: f64) -> GateOutcome {
    let mut out = GateOutcome::default();
    let provisional = baseline.get("provisional").and_then(Json::as_bool).unwrap_or(false);
    if provisional {
        out.notes.push(
            "baseline is PROVISIONAL (committed without a measuring toolchain): \
             reporting deltas only — replace BENCH_baseline.json with a real \
             bench-smoke artifact to arm the gate"
                .to_string(),
        );
    }

    let base_tp = throughputs(baseline);
    let cur_tp = throughputs(current);
    let cal_key = (CALIBRATION_SECTION.to_string(), CALIBRATION_NAME.to_string());
    // Per-file normalization; missing calibration on either side falls
    // back to raw throughput (with a note — raw cross-machine numbers
    // are indicative, not load-bearing).
    let cal = match (base_tp.get(&cal_key), cur_tp.get(&cal_key)) {
        (Some(&b), Some(&c)) => Some((b, c)),
        _ => None,
    };
    if cal.is_none() && !base_tp.is_empty() && !cur_tp.is_empty() {
        out.notes.push(
            "no calibration spin in one of the reports; comparing RAW throughput".to_string(),
        );
    }

    for (key, &base) in &base_tp {
        if key == &cal_key {
            continue;
        }
        let Some(&cur) = cur_tp.get(key) else {
            out.notes.push(format!("bench '{}::{}' missing from current run", key.0, key.1));
            continue;
        };
        let (bn, cn) = match cal {
            Some((bc, cc)) => (base / bc, cur / cc),
            None => (base, cur),
        };
        let delta_pct = (cn / bn - 1.0) * 100.0;
        if delta_pct < -max_regress_pct && !provisional {
            out.failures.push(format!(
                "'{}::{}' regressed {:.1}% (normalized {:.4} -> {:.4}, limit {:.0}%)",
                key.0, key.1, -delta_pct, bn, cn, max_regress_pct
            ));
        }
        out.checked.push(Comparison {
            section: key.0.clone(),
            name: key.1.clone(),
            baseline: bn,
            current: cn,
            delta_pct,
        });
    }
    for key in cur_tp.keys() {
        if key != &cal_key && !base_tp.contains_key(key) {
            out.notes.push(format!("new bench '{}::{}' (no baseline yet)", key.0, key.1));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(cal: f64, evals: f64) -> Json {
        Json::parse(&format!(
            r#"{{
                "{CALIBRATION_SECTION}": [
                    {{"name": "{CALIBRATION_NAME}", "throughput": {cal}}}
                ],
                "eval_throughput": [
                    {{"name": "val_error_batch x64", "throughput": {evals}}}
                ]
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn same_normalized_score_passes_across_machine_speeds() {
        // The "current" machine is 3x slower across the board: raw
        // throughput drops 66%, normalized score is unchanged — pass.
        let baseline = report(3000.0, 600.0);
        let current = report(1000.0, 200.0);
        let out = gate(&baseline, &current, 25.0);
        assert!(out.passed(), "{:?}", out.failures);
        assert_eq!(out.checked.len(), 1);
        assert!(out.checked[0].delta_pct.abs() < 1e-9);
    }

    #[test]
    fn genuine_regression_fails_even_on_a_faster_machine() {
        // Machine is 2x faster, but the eval bench only kept pace 1.2x:
        // normalized score dropped 40% — fail at the 25% limit.
        let baseline = report(1000.0, 500.0);
        let current = report(2000.0, 600.0);
        let out = gate(&baseline, &current, 25.0);
        assert!(!out.passed());
        assert!(out.failures[0].contains("val_error_batch"), "{:?}", out.failures);
        // The same drop passes a slacker limit.
        assert!(gate(&baseline, &current, 45.0).passed());
    }

    #[test]
    fn provisional_baseline_reports_but_never_fails() {
        let mut b = report(1000.0, 500.0);
        if let Json::Obj(m) = &mut b {
            m.insert("provisional".into(), Json::Bool(true));
        }
        let current = report(1000.0, 100.0); // 80% regression
        let out = gate(&b, &current, 25.0);
        assert!(out.passed());
        assert_eq!(out.checked.len(), 1, "deltas still reported");
        assert!(out.notes.iter().any(|n| n.contains("PROVISIONAL")), "{:?}", out.notes);
    }

    #[test]
    fn missing_and_new_benches_are_notes_not_failures() {
        let baseline = Json::parse(
            r#"{"calibration": [{"name": "calibration spin", "throughput": 1000.0}],
                "old_section": [{"name": "gone", "throughput": 50.0}]}"#,
        )
        .unwrap();
        let current = Json::parse(
            r#"{"calibration": [{"name": "calibration spin", "throughput": 1000.0}],
                "new_section": [{"name": "fresh", "throughput": 70.0}]}"#,
        )
        .unwrap();
        let out = gate(&baseline, &current, 25.0);
        assert!(out.passed());
        assert!(out.notes.iter().any(|n| n.contains("missing from current")), "{:?}", out.notes);
        assert!(out.notes.iter().any(|n| n.contains("no baseline yet")), "{:?}", out.notes);
    }

    #[test]
    fn falls_back_to_raw_comparison_without_calibration() {
        let baseline =
            Json::parse(r#"{"s": [{"name": "b", "throughput": 100.0}]}"#).unwrap();
        let current = Json::parse(r#"{"s": [{"name": "b", "throughput": 60.0}]}"#).unwrap();
        let out = gate(&baseline, &current, 25.0);
        assert!(!out.passed(), "raw 40% drop must still fail");
        assert!(out.notes.iter().any(|n| n.contains("RAW")), "{:?}", out.notes);
    }

    #[test]
    fn benches_without_throughput_are_ignored() {
        // mean_ns-only rows (latency benches) are not gated — wall-time
        // noise on shared runners is not a correctness signal.
        let j = Json::parse(
            r#"{"s": [{"name": "lat", "mean_ns": 5.0, "throughput": null}]}"#,
        )
        .unwrap();
        assert!(throughputs(&j).is_empty());
    }
}
