//! Micro-benchmark harness (criterion is not available offline).
//!
//! Criterion-like protocol: warm-up, then timed iterations until a target
//! wall budget or max iteration count is reached; reports mean / median /
//! p95 and optional throughput. Used by every file in benches/ and by the
//! §Perf pass in EXPERIMENTS.md.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// Optional items/second derived from `throughput_items`.
    pub throughput: Option<f64>,
}

pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// True when `MOHAQ_BENCH_SMOKE` requests the reduced-iteration mode the
/// CI bench-smoke job uses: every bench still runs (so regressions that
/// ERROR are caught), but with tiny warmup/budget caps.
pub fn smoke_mode() -> bool {
    std::env::var("MOHAQ_BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

impl Bencher {
    pub fn new(warmup_ms: u64, budget_ms: u64, max_iters: usize) -> Self {
        let (warmup_ms, budget_ms, max_iters) = if smoke_mode() {
            (warmup_ms.min(5), budget_ms.min(50), max_iters.min(30))
        } else {
            (warmup_ms, budget_ms, max_iters)
        };
        Bencher {
            warmup: Duration::from_millis(warmup_ms),
            budget: Duration::from_millis(budget_ms),
            max_iters,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which should return something observable to prevent
    /// the optimizer deleting the work (use `std::hint::black_box` inside).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.bench_with_items(name, None, move || {
            std::hint::black_box(f());
        })
    }

    /// Benchmark with a throughput denominator (items processed per call).
    pub fn bench_items<T>(
        &mut self,
        name: &str,
        items: u64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items), move || {
            std::hint::black_box(f());
        })
    }

    fn bench_with_items(
        &mut self,
        name: &str,
        items: Option<u64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        // Warm-up.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Timed runs.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len().max(1);
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let median = samples_ns[n / 2];
        let p95 = samples_ns[(n as f64 * 0.95) as usize % n];
        let min = samples_ns.first().copied().unwrap_or(0.0);
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: median,
            p95_ns: p95,
            min_ns: min,
            throughput: items.map(|k| k as f64 / (mean / 1e9)),
        };
        println!(
            "{:<48} {:>10}/iter  median {:>10}  p95 {:>10}  ({} iters{})",
            result.name,
            fmt_ns(mean),
            fmt_ns(median),
            fmt_ns(p95),
            n,
            result
                .throughput
                .map(|t| format!(", {:.0} items/s", t))
                .unwrap_or_default()
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Emit results as a JSON report (consumed by EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    obj(vec![
                        ("name", r.name.as_str().into()),
                        ("iters", r.iters.into()),
                        ("mean_ns", r.mean_ns.into()),
                        ("median_ns", r.median_ns.into()),
                        ("p95_ns", r.p95_ns.into()),
                        (
                            "throughput",
                            r.throughput.map(Json::Num).unwrap_or(Json::Null),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// Merge this bencher's results into the JSON perf report named by
    /// `MOHAQ_BENCH_JSON` under `section` (no-op when the variable is
    /// unset). Existing sections are preserved, so several bench binaries
    /// accrete one artifact (CI's `BENCH_ci.json`).
    pub fn emit_json(&self, section: &str) -> std::io::Result<()> {
        let Ok(path) = std::env::var("MOHAQ_BENCH_JSON") else {
            return Ok(());
        };
        use crate::util::json::Json;
        let mut root: std::collections::BTreeMap<String, Json> =
            std::fs::read_to_string(&path)
                .ok()
                .and_then(|text| Json::parse(&text).ok())
                .and_then(|j| match j {
                    Json::Obj(m) => Some(m),
                    _ => None,
                })
                .unwrap_or_default();
        root.insert(section.to_string(), self.to_json());
        std::fs::write(&path, Json::Obj(root).to_string_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::new(5, 50, 1000);
        let r = b.bench("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert!(r.median_ns <= r.p95_ns);
    }

    #[test]
    fn throughput_is_populated() {
        let mut b = Bencher::new(1, 20, 100);
        let r = b.bench_items("items", 100, || 42u64);
        assert!(r.throughput.unwrap() > 0.0);
    }

    #[test]
    fn emit_json_accretes_sections() {
        let path = std::env::temp_dir().join(format!("mohaq_bench_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        std::env::set_var("MOHAQ_BENCH_JSON", &path);

        let mut a = Bencher::new(1, 10, 10);
        a.bench("alpha", || 1u64);
        a.emit_json("section_a").unwrap();
        let mut b = Bencher::new(1, 10, 10);
        b.bench("beta", || 2u64);
        b.emit_json("section_b").unwrap();

        std::env::remove_var("MOHAQ_BENCH_JSON");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let root = crate::util::json::Json::parse(&text).unwrap();
        assert!(root.get("section_a").is_some(), "first section lost: {text}");
        assert!(root.get("section_b").is_some(), "second section lost: {text}");
    }

    #[test]
    fn formats_times() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2_500.0), "2.50 µs");
        assert_eq!(fmt_ns(3_000_000.0), "3.00 ms");
        assert_eq!(fmt_ns(1.5e9), "1.500 s");
    }
}
