//! Tiny property-testing harness (proptest is not available offline).
//!
//! A property is a generator (`Fn(&mut Rng) -> T`) plus a checker
//! (`Fn(&T) -> Result<(), String>`). `check_prop` runs `iters` random
//! cases from a seed derived deterministically from the property name, so
//! failures are reproducible; the failing case is printed via Debug. No
//! shrinking — generators here produce small cases by construction.

use super::rng::Rng;

/// FNV-1a, used to derive a stable seed from the property name.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub fn check_prop<T: std::fmt::Debug>(
    name: &str,
    iters: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(fnv1a(name));
    for i in 0..iters {
        let case = gen(&mut rng);
        if let Err(msg) = check(&case) {
            panic!(
                "property '{name}' failed at iteration {i}: {msg}\ncase: {case:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check_prop("trivial", 100, |r| r.below(10), |x| {
            if *x < 10 {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing' failed")]
    fn reports_failing_case() {
        check_prop("failing", 100, |r| r.below(10), |x| {
            if *x < 5 {
                Ok(())
            } else {
                Err("too big".to_string())
            }
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check_prop("det", 10, |r| r.next_u64(), |x| {
            first.push(*x);
            Ok(())
        });
        let mut second = Vec::new();
        check_prop("det", 10, |r| r.next_u64(), |x| {
            second.push(*x);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
