//! Minimal CLI argument parsing (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Each binary declares its options by querying the parsed map.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — skips argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().peekable();
        let mut args = Args::default();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.flags.insert(body.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse_from(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = parse(&["--gens", "60", "--pop=10"]);
        assert_eq!(a.get_usize("gens", 0), 60);
        assert_eq!(a.get_usize("pop", 0), 10);
    }

    #[test]
    fn parses_bool_flags_and_positional() {
        let a = parse(&["run", "--verbose", "--mode", "beacon", "extra"]);
        assert!(a.has("verbose"));
        assert_eq!(a.get("mode"), Some("beacon"));
        assert_eq!(a.positional, vec!["run", "extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_or("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.get_f64("threshold", 6.0), 6.0);
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--x", "--y", "2"]);
        assert_eq!(a.get("x"), Some("true"));
        assert_eq!(a.get_usize("y", 0), 2);
    }
}
