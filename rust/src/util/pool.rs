//! Scoped thread-pool fan-out (rayon is not available offline).
//!
//! `map_parallel` evaluates a function over a slice on N worker threads and
//! returns results in input order, so callers observe exactly the same
//! result vector regardless of thread count — the property the coordinator
//! relies on for seed-deterministic parallel population evaluation.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Default worker count: one per available core, or the `MOHAQ_THREADS`
/// override (handy for CI runners and for pinning bench comparisons).
pub fn default_threads() -> usize {
    if let Some(n) = std::env::var("MOHAQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item on up to `threads` workers; results come back in
/// input order. `threads <= 1` runs inline (no spawn overhead). Worker
/// panics propagate to the caller.
pub fn map_parallel<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    // Work-stealing by atomic index: threads drain the slice
                    // without any per-item locking.
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        for w in workers {
            match w.join() {
                // Re-raise with the original payload so the root cause
                // (e.g. "candidate evaluation failed: ...") survives to
                // whoever catches the panic.
                Err(payload) => std::panic::resume_unwind(payload),
                Ok(local) => {
                    for (i, r) in local {
                        slots[i] = Some(r);
                    }
                }
            }
        }
    });
    slots.into_iter().map(|s| s.expect("worker skipped an item")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        for threads in [1, 2, 8] {
            let out = map_parallel(threads, &items, |i, &x| {
                assert_eq!(i, x);
                x * 2
            });
            assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn handles_empty_and_tiny_inputs() {
        let empty: Vec<u32> = vec![];
        assert!(map_parallel(4, &empty, |_, &x| x).is_empty());
        assert_eq!(map_parallel(8, &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let items: Vec<u64> = (0..257).collect();
        let f = |_: usize, &x: &u64| x.wrapping_mul(0x9E3779B97F4A7C15) >> 7;
        let seq = map_parallel(1, &items, f);
        let par = map_parallel(default_threads().max(2), &items, f);
        assert_eq!(seq, par);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panics_propagate_with_original_payload() {
        let items: Vec<u32> = (0..32).collect();
        map_parallel(4, &items, |_, &x| {
            if x == 13 {
                panic!("boom");
            }
            x
        });
    }
}
