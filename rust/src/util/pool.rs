//! Thread-pool fan-out (rayon is not available offline).
//!
//! Two substrates:
//!   * `map_parallel` — scoped fan-out of one slice over N ephemeral
//!     workers; results come back in input order, so callers observe
//!     exactly the same result vector regardless of thread count — the
//!     property the coordinator relies on for seed-deterministic parallel
//!     population evaluation.
//!   * `WorkQueue` — a long-lived pool with one shared job stream.
//!     Several threads can submit batches concurrently (serve mode:
//!     candidate evaluations from every in-flight search interleave
//!     across the same workers); each `run_batch` call still returns its
//!     own results in input order. Workers survive panicking jobs — the
//!     panic is captured and re-raised in the submitting thread, never in
//!     the pool.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};

/// Default worker count: one per available core, or the `MOHAQ_THREADS`
/// override (handy for CI runners and for pinning bench comparisons).
pub fn default_threads() -> usize {
    if let Some(n) = std::env::var("MOHAQ_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        return n;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every item on up to `threads` workers; results come back in
/// input order. `threads <= 1` runs inline (no spawn overhead). Worker
/// panics propagate to the caller.
pub fn map_parallel<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = threads.clamp(1, items.len().max(1));
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    // Work-stealing by atomic index: threads drain the slice
                    // without any per-item locking.
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        for w in workers {
            match w.join() {
                // Re-raise with the original payload so the root cause
                // (e.g. "candidate evaluation failed: ...") survives to
                // whoever catches the panic.
                Err(payload) => std::panic::resume_unwind(payload),
                Ok(local) => {
                    for (i, r) in local {
                        slots[i] = Some(r);
                    }
                }
            }
        }
    });
    slots.into_iter().map(|s| s.expect("worker skipped an item")).collect()
}

/// Fan `items` out as contiguous micro-batches instead of single items:
/// one atomic claim per CHUNK, not per item, so cheap per-item work (a
/// cache probe, a surrogate evaluation) amortizes the fan-out overhead.
/// `f` receives the chunk's starting index and the sub-slice, and must
/// return one result per item; results come back in input order, so the
/// output is bitwise-identical to the unchunked map at any thread count
/// or chunk size.
pub fn map_parallel_chunked<T, R, F>(threads: usize, items: &[T], chunk: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &[T]) -> Vec<R> + Sync,
{
    let chunk = chunk.max(1);
    let chunks: Vec<(usize, &[T])> =
        items.chunks(chunk).enumerate().map(|(k, c)| (k * chunk, c)).collect();
    let nested = map_parallel(threads, &chunks, |_, &(start, c)| {
        let out = f(start, c);
        assert_eq!(out.len(), c.len(), "chunk fn returned {} results for {} items", out.len(), c.len());
        out
    });
    nested.into_iter().flatten().collect()
}

/// The `FnOnce` counterpart of [`map_parallel`], for jobs that consume
/// owned state (e.g. a forked `Trainer` in the parallel beacon-retraining
/// fan-out): run every job on up to `threads` workers; results in input
/// order; worker panics re-raise here with their original payload.
pub fn run_once_parallel<R, F>(threads: usize, jobs: Vec<F>) -> Vec<R>
where
    R: Send,
    F: FnOnce() -> R + Send,
{
    let slots: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    map_parallel(threads, &slots, |_, slot| {
        let f = relock(slot).take().expect("job claimed twice");
        f()
    })
}

/// Lock helper that shrugs off poisoning: bookkeeping state (queue slots,
/// serve-mode connection maps) stays usable even after a job panicked —
/// the panic itself is reported separately, through [`panic_message`] or
/// a typed error.
pub fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Render a caught panic payload as a message (the two payload types
/// `panic!` produces, with a fallback). Single source of truth for the
/// pool, the session boundary and the serve layer.
pub fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "worker panicked".into())
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One result slot: empty until the job ran; `Err` carries a panic
/// message to re-raise in the submitting thread.
type Slot<R> = Option<Result<R, String>>;

/// Per-batch rendezvous: result slots + a countdown the submitter waits on.
struct Batch<R> {
    slots: Mutex<Vec<Slot<R>>>,
    remaining: Mutex<usize>,
    done: Condvar,
}

/// A long-lived worker pool with a single shared job stream. Built once
/// (e.g. per server), then any number of threads call [`WorkQueue::run_batch`]
/// concurrently; their jobs interleave across the same workers. Dropping
/// the queue closes the stream and joins the workers.
pub struct WorkQueue {
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    threads: usize,
}

impl WorkQueue {
    /// Spawn a pool of `threads` workers (0 = one per core).
    pub fn new(threads: usize) -> WorkQueue {
        let threads = if threads == 0 { default_threads() } else { threads };
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || loop {
                    // Hold the receiver lock only for the dequeue, not the
                    // job itself, so workers drain the stream concurrently.
                    let job = match relock(&rx).recv() {
                        Ok(job) => job,
                        Err(_) => break, // stream closed: pool shutting down
                    };
                    job();
                })
            })
            .collect();
        WorkQueue { tx: Mutex::new(Some(tx)), workers: Mutex::new(workers), threads }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a batch of jobs on the pool and block until all complete;
    /// results come back in input order. Safe to call from many threads at
    /// once — that is the point: concurrent batches share one job stream.
    /// A panicking job does NOT kill its worker; the panic message is
    /// re-raised here, in the submitting thread.
    pub fn run_batch<R, F>(&self, jobs: Vec<F>) -> Vec<R>
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let batch = Arc::new(Batch::<R> {
            slots: Mutex::new((0..n).map(|_| None).collect()),
            remaining: Mutex::new(n),
            done: Condvar::new(),
        });
        {
            let tx = relock(&self.tx);
            let tx = tx.as_ref().expect("work queue already shut down");
            for (i, job) in jobs.into_iter().enumerate() {
                let b = batch.clone();
                let wrapped: Job = Box::new(move || {
                    // Capture the panic INSIDE the pool so the worker
                    // survives; re-raise it in the submitting thread below.
                    let out = catch_unwind(AssertUnwindSafe(job)).map_err(panic_message);
                    relock(&b.slots)[i] = Some(out);
                    let mut rem = relock(&b.remaining);
                    *rem -= 1;
                    if *rem == 0 {
                        b.done.notify_all();
                    }
                });
                tx.send(wrapped).expect("work queue workers gone");
            }
        }
        let mut rem = relock(&batch.remaining);
        while *rem > 0 {
            rem = batch.done.wait(rem).unwrap_or_else(|e| e.into_inner());
        }
        drop(rem);
        relock(&batch.slots)
            .drain(..)
            .map(|slot| match slot.expect("worker skipped a job") {
                Ok(r) => r,
                Err(msg) => panic!("{msg}"),
            })
            .collect()
    }
}

impl Drop for WorkQueue {
    fn drop(&mut self) {
        // Close the stream, then join: workers exit when recv() fails.
        relock(&self.tx).take();
        for w in relock(&self.workers).drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        for threads in [1, 2, 8] {
            let out = map_parallel(threads, &items, |i, &x| {
                assert_eq!(i, x);
                x * 2
            });
            assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn handles_empty_and_tiny_inputs() {
        let empty: Vec<u32> = vec![];
        assert!(map_parallel(4, &empty, |_, &x| x).is_empty());
        assert_eq!(map_parallel(8, &[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let items: Vec<u64> = (0..257).collect();
        let f = |_: usize, &x: &u64| x.wrapping_mul(0x9E3779B97F4A7C15) >> 7;
        let seq = map_parallel(1, &items, f);
        let par = map_parallel(default_threads().max(2), &items, f);
        assert_eq!(seq, par);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panics_propagate_with_original_payload() {
        let items: Vec<u32> = (0..32).collect();
        map_parallel(4, &items, |_, &x| {
            if x == 13 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn chunked_map_matches_unchunked_at_any_chunk_size() {
        let items: Vec<u64> = (0..103).collect();
        let f = |x: u64| x.wrapping_mul(0x2545F4914F6CDD1D) >> 9;
        let want: Vec<u64> = items.iter().map(|&x| f(x)).collect();
        for threads in [1, 4] {
            for chunk in [1, 7, 50, 103, 500] {
                let got = map_parallel_chunked(threads, &items, chunk, |start, c| {
                    c.iter().enumerate().map(|(j, &x)| {
                        assert_eq!(x, (start + j) as u64, "chunk start index is absolute");
                        f(x)
                    }).collect()
                });
                assert_eq!(got, want, "threads={threads} chunk={chunk}");
            }
        }
        assert!(map_parallel_chunked(4, &[] as &[u64], 8, |_, c| c.to_vec()).is_empty());
    }

    #[test]
    fn run_once_parallel_consumes_owned_jobs_in_order() {
        // Jobs move owned (non-Clone, non-Sync-shared) state — the exact
        // shape of a forked-Trainer retraining fan-out.
        struct Owned(u64);
        let jobs: Vec<_> = (0..37u64)
            .map(|i| {
                let state = Owned(i);
                move || state.0 * 10 + 1
            })
            .collect();
        let out = run_once_parallel(4, jobs);
        assert_eq!(out, (0..37).map(|i| i * 10 + 1).collect::<Vec<u64>>());
    }

    #[test]
    fn work_queue_returns_batch_results_in_order() {
        let q = WorkQueue::new(4);
        let out = q.run_batch((0..64u64).map(|x| move || x * 3).collect());
        assert_eq!(out, (0..64).map(|x| x * 3).collect::<Vec<_>>());
        assert!(q.run_batch::<u64, fn() -> u64>(Vec::new()).is_empty());
    }

    #[test]
    fn work_queue_interleaves_concurrent_batches() {
        // Several submitting threads share one job stream; each still gets
        // its own results back, in its own input order.
        let q = Arc::new(WorkQueue::new(3));
        let outs: Vec<Vec<usize>> = std::thread::scope(|scope| {
            (0..4usize)
                .map(|t| {
                    let q = q.clone();
                    scope.spawn(move || {
                        q.run_batch((0..50usize).map(|i| move || t * 1000 + i).collect())
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for (t, out) in outs.iter().enumerate() {
            assert_eq!(*out, (0..50).map(|i| t * 1000 + i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn work_queue_survives_panicking_jobs() {
        let q = WorkQueue::new(2);
        // A panicking batch re-raises in the SUBMITTING thread...
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            q.run_batch(vec![
                Box::new(|| 1u32) as Box<dyn FnOnce() -> u32 + Send>,
                Box::new(|| panic!("job exploded")),
            ]);
        }));
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("job exploded"), "{msg}");
        // ...and the workers stay alive for the next batch.
        assert_eq!(q.run_batch(vec![|| 7u32]), vec![7]);
    }
}
