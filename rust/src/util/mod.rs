//! In-tree substrates replacing unavailable external crates (offline
//! environment, see Cargo.toml): JSON codec, deterministic PRNG,
//! property-test harness, micro-bench harness, CLI parsing.

pub mod bench;
pub mod benchgate;
pub mod cli;
pub mod fsio;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
