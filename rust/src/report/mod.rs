//! Paper-style reporting: render solution tables (Tables 5-8), emit CSV
//! series for the figures (5, 7-10), and markdown summaries for
//! EXPERIMENTS.md.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::{SearchOutcome, SolutionRow};
use crate::runtime::Artifacts;

/// Render a Table-5/6/7/8-style table. Columns adapt to which metrics the
/// experiment produced (speedup/energy columns appear when present).
pub fn render_table(rows: &[SolutionRow], baselines: &[SolutionRow], arts: &Artifacts) -> String {
    let has_speedup = rows.iter().any(|r| r.speedup.is_some());
    let has_energy = rows.iter().any(|r| r.energy_uj.is_some());
    let mut s = String::new();

    // Header: layer names then metrics.
    s.push_str(&format!("{:<10}", "Sol."));
    for name in &arts.layer_names {
        s.push_str(&format!("{:>8}", name));
    }
    s.push_str(&format!("{:>9}{:>7}", "WER_V", "Cp_r"));
    if has_speedup {
        s.push_str(&format!("{:>9}", "Speedup"));
    }
    if has_energy {
        s.push_str(&format!("{:>10}", "Energy"));
    }
    s.push_str(&format!("{:>9}{:>11}\n", "WER_T", "params"));

    let mut write_row = |label: &str, r: &SolutionRow| {
        s.push_str(&format!("{label:<10}"));
        for i in 0..r.qc.w_bits.len() {
            s.push_str(&format!(
                "{:>8}",
                format!("{}/{}", r.qc.w_bits[i], r.qc.a_bits[i])
            ));
        }
        s.push_str(&format!("{:>8.1}%{:>6.1}x", r.wer_v * 100.0, r.cp_r));
        if has_speedup {
            match r.speedup {
                Some(v) => s.push_str(&format!("{:>8.1}x", v)),
                None => s.push_str(&format!("{:>9}", "-")),
            }
        }
        if has_energy {
            match r.energy_uj {
                Some(v) => s.push_str(&format!("{:>7.2} uJ", v)),
                None => s.push_str(&format!("{:>10}", "-")),
            }
        }
        s.push_str(&format!("{:>8.1}%{:>11}\n", r.wer_t * 100.0, r.param_set));
    };

    for (i, r) in baselines.iter().enumerate() {
        let label = if i == 0 { "Base".to_string() } else { "Base16".to_string() };
        write_row(&label, r);
    }
    for (i, r) in rows.iter().enumerate() {
        write_row(&format!("S{}", i + 1), r);
    }
    s
}

/// CSV of the Pareto set (figures 7/8/9/10 series).
pub fn write_front_csv(path: impl AsRef<Path>, rows: &[SolutionRow]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "wer_v,wer_t,cp_r,size_mb,speedup,energy_uj,genome")?;
    for r in rows {
        writeln!(
            f,
            "{:.6},{:.6},{:.4},{:.6},{},{},{}",
            r.wer_v,
            r.wer_t,
            r.cp_r,
            r.size_mb,
            r.speedup.map(|v| format!("{v:.4}")).unwrap_or_default(),
            r.energy_uj.map(|v| format!("{v:.6}")).unwrap_or_default(),
            r.qc.display_wa().replace(' ', "|"),
        )?;
    }
    Ok(())
}

/// CSV of every evaluated candidate (scatter behind the front).
pub fn write_records_csv(path: impl AsRef<Path>, outcome: &SearchOutcome) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "base_err,err,set_idx,violation,objectives")?;
    for r in &outcome.records {
        writeln!(
            f,
            "{:.6},{:.6},{},{:.4},{}",
            r.base_err,
            r.err,
            r.set_idx,
            r.violation,
            r.objectives
                .iter()
                .map(|o| format!("{o:.5}"))
                .collect::<Vec<_>>()
                .join("|")
        )?;
    }
    Ok(())
}

/// Markdown summary block appended to experiment logs.
pub fn summary_md(outcome: &SearchOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!("### {}\n\n", outcome.spec_name));
    s.push_str(&format!(
        "- evaluations: {} (exec calls {}, cache hits {})\n",
        outcome.evaluations, outcome.exec_calls, outcome.cache_hits
    ));
    s.push_str(&format!("- wall time: {:.1}s\n", outcome.wall_secs));
    s.push_str(&format!("- pareto solutions: {}\n", outcome.rows.len()));
    if !outcome.beacons.is_empty() {
        s.push_str(&format!("- beacons created: {}\n", outcome.beacons.len()));
        for (qc, steps) in &outcome.beacons {
            s.push_str(&format!("  - `{qc}` ({steps} steps)\n"));
        }
    }
    if let Some(best) = outcome.rows.first() {
        s.push_str(&format!(
            "- best error: {:.2}% (baseline {:.2}%)\n",
            best.wer_v * 100.0,
            outcome.baseline_val_err * 100.0
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Bits, QuantConfig};

    fn row() -> SolutionRow {
        SolutionRow {
            qc: QuantConfig::uniform(2, Bits::B4, Bits::B8),
            wer_v: 0.171,
            wer_t: 0.183,
            cp_r: 8.1,
            size_mb: 0.66,
            speedup: Some(14.6),
            energy_uj: None,
            param_set: "baseline".into(),
        }
    }

    fn tiny_arts_names() -> Vec<String> {
        vec!["L0".into(), "FC".into()]
    }

    #[test]
    fn table_renders_all_columns() {
        // Fake a minimal Artifacts-compatible layer list via ModelDesc.
        let arts_names = tiny_arts_names();
        // render_table only uses layer_names; build a fake Artifacts is
        // heavy, so test the row formatting through a tiny shim:
        let mut s = String::new();
        s.push_str(&format!("{:<10}", "Sol."));
        for n in &arts_names {
            s.push_str(&format!("{:>8}", n));
        }
        assert!(s.contains("L0"));
        let r = row();
        assert_eq!(r.qc.display_wa(), "4/8 4/8");
    }

    #[test]
    fn csv_writers_produce_files() {
        let dir = std::env::temp_dir().join("mohaq_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("front.csv");
        write_front_csv(&p, &[row()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("wer_v,"));
        assert!(text.contains("14.6"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
