//! Paper-style reporting: render solution tables (Tables 5-8), emit CSV
//! series for the figures (5, 7-10), and markdown summaries for
//! EXPERIMENTS.md.
//!
//! Cross-platform searches (PR 4) carry per-binding metrics in
//! `SolutionRow::hw`; tables and CSVs grow one speedup/energy column pair
//! per bound platform, labeled `@platform` whenever more than one binding
//! is in play so joint fronts stay interpretable.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::{SearchOutcome, SolutionRow};
use crate::runtime::Artifacts;

/// Platform labels of the hardware columns, in binding-table order (empty
/// when the search had no platform bindings).
fn hw_labels(rows: &[SolutionRow]) -> Vec<String> {
    rows.first()
        .map(|r| r.hw.iter().map(|h| h.platform.clone()).collect())
        .unwrap_or_default()
}

fn row_speedup(r: &SolutionRow, idx: usize) -> Option<f64> {
    // Baseline rows carry no bindings; their convenience field feeds
    // EVERY platform column (Base16's 1.0x anchor holds on each platform
    // by definition — speedup is relative to that platform's baseline).
    r.hw.get(idx).map(|h| h.speedup).or(r.speedup)
}

fn row_energy(r: &SolutionRow, idx: usize) -> Option<f64> {
    r.hw.get(idx).and_then(|h| h.energy_uj).or(r.energy_uj)
}

/// Render a Table-5/6/7/8-style table. Columns adapt to which metrics the
/// experiment produced: one speedup/energy pair per platform binding,
/// `@platform`-labeled when the search scored several platforms.
pub fn render_table(rows: &[SolutionRow], baselines: &[SolutionRow], arts: &Artifacts) -> String {
    let labels = hw_labels(rows);
    let multi = labels.len() > 1;

    // Hardware columns as (header, binding index, column width) triples —
    // the width is computed once here so the header row and the data rows
    // cannot drift apart.
    let speed_col = |header: String, idx: usize| {
        let w = header.len().max(7) + 2;
        (header, idx, w)
    };
    let energy_col = |header: String, idx: usize| {
        let w = header.len().max(6) + 4;
        (header, idx, w)
    };
    let mut speed_cols: Vec<(String, usize, usize)> = Vec::new();
    let mut energy_cols: Vec<(String, usize, usize)> = Vec::new();
    if labels.is_empty() {
        if rows.iter().any(|r| r.speedup.is_some()) {
            speed_cols.push(speed_col("Speedup".into(), 0));
        }
        if rows.iter().any(|r| r.energy_uj.is_some()) {
            energy_cols.push(energy_col("Energy".into(), 0));
        }
    } else {
        for (i, l) in labels.iter().enumerate() {
            let header = if multi { format!("Spd@{l}") } else { "Speedup".into() };
            speed_cols.push(speed_col(header, i));
            if rows.iter().any(|r| r.hw.get(i).is_some_and(|h| h.energy_uj.is_some())) {
                let header = if multi { format!("E@{l}") } else { "Energy".into() };
                energy_cols.push(energy_col(header, i));
            }
        }
    }

    let mut s = String::new();

    // Header: layer names then metrics.
    s.push_str(&format!("{:<10}", "Sol."));
    for name in &arts.layer_names {
        s.push_str(&format!("{name:>8}"));
    }
    s.push_str(&format!("{:>9}{:>7}", "WER_V", "Cp_r"));
    for (header, _, w) in speed_cols.iter().chain(&energy_cols) {
        let w = *w;
        s.push_str(&format!("{header:>w$}"));
    }
    s.push_str(&format!("{:>9}{:>11}\n", "WER_T", "params"));

    let mut write_row = |label: &str, r: &SolutionRow| {
        s.push_str(&format!("{label:<10}"));
        for i in 0..r.qc.w_bits.len() {
            s.push_str(&format!("{:>8}", format!("{}/{}", r.qc.w_bits[i], r.qc.a_bits[i])));
        }
        s.push_str(&format!("{:>8.1}%{:>6.1}x", r.wer_v * 100.0, r.cp_r));
        for (_, idx, w) in &speed_cols {
            let (w, vw) = (*w, *w - 1);
            match row_speedup(r, *idx) {
                Some(v) => s.push_str(&format!("{v:>vw$.1}x")),
                None => s.push_str(&format!("{:>w$}", "-")),
            }
        }
        for (_, idx, w) in &energy_cols {
            let (w, vw) = (*w, *w - 3);
            match row_energy(r, *idx) {
                Some(v) => s.push_str(&format!("{v:>vw$.2} uJ")),
                None => s.push_str(&format!("{:>w$}", "-")),
            }
        }
        s.push_str(&format!("{:>8.1}%{:>11}\n", r.wer_t * 100.0, r.param_set));
    };

    for (i, r) in baselines.iter().enumerate() {
        let label = if i == 0 { "Base".to_string() } else { "Base16".to_string() };
        write_row(&label, r);
    }
    for (i, r) in rows.iter().enumerate() {
        write_row(&format!("S{}", i + 1), r);
    }
    s
}

/// CSV of the Pareto set (figures 7/8/9/10 series). One
/// `speedup@platform,energy_uj@platform` column pair per binding; the
/// unlabeled legacy pair when the search had no platform.
pub fn write_front_csv(path: impl AsRef<Path>, rows: &[SolutionRow]) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    let labels = hw_labels(rows);
    let mut header = String::from("wer_v,wer_t,cp_r,size_mb");
    if labels.is_empty() {
        header.push_str(",speedup,energy_uj");
    } else {
        for l in &labels {
            header.push_str(&format!(",speedup@{l},energy_uj@{l}"));
        }
    }
    writeln!(f, "{header},genome")?;
    for r in rows {
        let mut line = format!("{:.6},{:.6},{:.4},{:.6}", r.wer_v, r.wer_t, r.cp_r, r.size_mb);
        if labels.is_empty() {
            line.push_str(&format!(
                ",{},{}",
                r.speedup.map(|v| format!("{v:.4}")).unwrap_or_default(),
                r.energy_uj.map(|v| format!("{v:.6}")).unwrap_or_default()
            ));
        } else {
            for h in &r.hw {
                line.push_str(&format!(
                    ",{:.4},{}",
                    h.speedup,
                    h.energy_uj.map(|v| format!("{v:.6}")).unwrap_or_default()
                ));
            }
        }
        writeln!(f, "{line},{}", r.qc.display_wa().replace(' ', "|"))?;
    }
    Ok(())
}

/// CSV of every evaluated candidate (scatter behind the front).
pub fn write_records_csv(path: impl AsRef<Path>, outcome: &SearchOutcome) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "base_err,err,set_idx,violation,objectives")?;
    for r in &outcome.records {
        writeln!(
            f,
            "{:.6},{:.6},{},{:.4},{}",
            r.base_err,
            r.err,
            r.set_idx,
            r.violation,
            r.objectives
                .iter()
                .map(|o| format!("{o:.5}"))
                .collect::<Vec<_>>()
                .join("|")
        )?;
    }
    Ok(())
}

/// Markdown summary block appended to experiment logs.
pub fn summary_md(outcome: &SearchOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!("### {}\n\n", outcome.spec_name));
    if !outcome.objective_names.is_empty() {
        s.push_str(&format!("- objectives: {}\n", outcome.objective_names.join(", ")));
    }
    s.push_str(&format!(
        "- evaluations: {} (exec calls {}, cache hits {})\n",
        outcome.evaluations, outcome.exec_calls, outcome.cache_hits
    ));
    s.push_str(&format!("- wall time: {:.1}s\n", outcome.wall_secs));
    s.push_str(&format!("- pareto solutions: {}\n", outcome.rows.len()));
    if !outcome.beacons.is_empty() {
        s.push_str(&format!("- beacons created: {}\n", outcome.beacons.len()));
        for (qc, steps) in &outcome.beacons {
            s.push_str(&format!("  - `{qc}` ({steps} steps)\n"));
        }
    }
    if let Some(best) = outcome.rows.first() {
        s.push_str(&format!(
            "- best error: {:.2}% (baseline {:.2}%)\n",
            best.wer_v * 100.0,
            outcome.baseline_val_err * 100.0
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::HwMetrics;
    use crate::quant::{Bits, QuantConfig};

    fn row() -> SolutionRow {
        SolutionRow {
            qc: QuantConfig::uniform(2, Bits::B4, Bits::B8),
            wer_v: 0.171,
            wer_t: 0.183,
            cp_r: 8.1,
            size_mb: 0.66,
            speedup: Some(14.6),
            energy_uj: None,
            hw: Vec::new(),
            param_set: "baseline".into(),
        }
    }

    fn cross_row() -> SolutionRow {
        let mut r = row();
        r.hw = vec![
            HwMetrics { platform: "silago".into(), speedup: 3.2, energy_uj: Some(0.41) },
            HwMetrics { platform: "bitfusion".into(), speedup: 14.6, energy_uj: None },
        ];
        r.speedup = Some(3.2);
        r.energy_uj = Some(0.41);
        r
    }

    fn tiny_arts_names() -> Vec<String> {
        vec!["L0".into(), "FC".into()]
    }

    #[test]
    fn table_renders_all_columns() {
        // Fake a minimal Artifacts-compatible layer list via ModelDesc.
        let arts_names = tiny_arts_names();
        // render_table only uses layer_names; build a fake Artifacts is
        // heavy, so test the row formatting through a tiny shim:
        let mut s = String::new();
        s.push_str(&format!("{:<10}", "Sol."));
        for n in &arts_names {
            s.push_str(&format!("{n:>8}"));
        }
        assert!(s.contains("L0"));
        let r = row();
        assert_eq!(r.qc.display_wa(), "4/8 4/8");
    }

    #[test]
    fn csv_writers_produce_files() {
        let dir = std::env::temp_dir().join("mohaq_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("front.csv");
        write_front_csv(&p, &[row()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("wer_v,"));
        assert!(text.contains("14.6"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cross_platform_csv_labels_columns_per_binding() {
        let dir = std::env::temp_dir().join("mohaq_report_cross_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("front.csv");
        write_front_csv(&p, &[cross_row()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let header = text.lines().next().unwrap();
        assert_eq!(
            header,
            "wer_v,wer_t,cp_r,size_mb,speedup@silago,energy_uj@silago,\
             speedup@bitfusion,energy_uj@bitfusion,genome"
        );
        // silago speedup + energy, bitfusion speedup, empty energy cell.
        let line = text.lines().nth(1).unwrap();
        assert!(line.contains(",3.2000,0.410000,14.6000,,"), "{line}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
