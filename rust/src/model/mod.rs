//! Model cost descriptor: the paper's Table 1 operation/parameter formulas
//! and the Table 4 breakdown, plus memory-size / compression math used by
//! the hardware objectives and the SRAM constraint.
//!
//! The descriptor is built either from the artifact manifest (runtime) or
//! from explicit dims (tests reproduce the published Table 4 exactly).

use crate::quant::Bits;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Bidirectional SRU (paper Table 1 row 3).
    BiSru,
    /// Projection layer (plain MxV, no bias).
    Projection,
    /// Final fully-connected layer (MxV + bias).
    FullyConnected,
}

#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub name: String,
    pub kind: LayerKind,
    /// MxV input size (paper's m).
    pub m: usize,
    /// Hidden cells per direction (SRU) or output size (Proj/FC).
    pub n: usize,
}

impl LayerDesc {
    /// MAC operations (Table 1): Bi-SRU 6nm, Proj/FC nm.
    pub fn mac_ops(&self) -> u64 {
        let (m, n) = (self.m as u64, self.n as u64);
        match self.kind {
            LayerKind::BiSru => 6 * n * m,
            LayerKind::Projection | LayerKind::FullyConnected => n * m,
        }
    }

    /// Element-wise operations (Table 1): Bi-SRU 28n.
    pub fn elementwise_ops(&self) -> u64 {
        match self.kind {
            LayerKind::BiSru => 28 * self.n as u64,
            _ => 0,
        }
    }

    /// Non-linear function applications (Table 1): Bi-SRU 4n; FC applies
    /// softmax over n outputs (Table 4 counts 1904 for FC).
    pub fn nonlinear_ops(&self) -> u64 {
        match self.kind {
            LayerKind::BiSru => 4 * self.n as u64,
            LayerKind::FullyConnected => self.n as u64,
            LayerKind::Projection => 0,
        }
    }

    /// Weights in MxV matrices — the int-quantizable parameters (§4.1).
    pub fn matrix_weights(&self) -> u64 {
        self.mac_ops() // one weight per MAC in all three layer kinds
    }

    /// Recurrent vectors + biases — always 16-bit fixed (Table 1: Bi-SRU
    /// 4n vector weights + 4n biases). The FC bias is also counted here
    /// (the paper's Table 4 omits it; it is n values — negligible, but we
    /// account for it since our artifact stores it).
    pub fn vector_weights(&self) -> u64 {
        match self.kind {
            LayerKind::BiSru => 8 * self.n as u64,
            LayerKind::FullyConnected => self.n as u64,
            LayerKind::Projection => 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ModelDesc {
    pub layers: Vec<LayerDesc>,
}

/// Bits used for the never-searched parameters (recurrent vectors, biases).
pub const VECTOR_BITS: u64 = 16;
/// The float baseline precision compression is measured against (Cp_r).
pub const BASELINE_BITS: u64 = 32;

impl ModelDesc {
    /// Build from (name, m, n) triples as stored in the artifact manifest.
    pub fn from_dims(dims: &[(String, usize, usize)]) -> ModelDesc {
        let layers = dims
            .iter()
            .map(|(name, m, n)| {
                let kind = if name.starts_with("Pr") {
                    LayerKind::Projection
                } else if name == "FC" {
                    LayerKind::FullyConnected
                } else {
                    LayerKind::BiSru
                };
                LayerDesc { name: name.clone(), kind, m: *m, n: *n }
            })
            .collect();
        ModelDesc { layers }
    }

    /// The published model (Table 4): 23 features, n=550, p=256, 1904
    /// classes. Used by the hw-model tests that check paper table cells.
    pub fn paper() -> ModelDesc {
        ModelDesc::from_dims(&[
            ("L0".into(), 23, 550),
            ("Pr1".into(), 1100, 256),
            ("L1".into(), 256, 550),
            ("Pr2".into(), 1100, 256),
            ("L2".into(), 256, 550),
            ("Pr3".into(), 1100, 256),
            ("L3".into(), 256, 550),
            ("FC".into(), 1100, 1904),
        ])
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.mac_ops()).sum()
    }

    pub fn total_elementwise(&self) -> u64 {
        self.layers.iter().map(|l| l.elementwise_ops()).sum()
    }

    pub fn total_nonlinear(&self) -> u64 {
        self.layers.iter().map(|l| l.nonlinear_ops()).sum()
    }

    pub fn total_matrix_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.matrix_weights()).sum()
    }

    pub fn total_vector_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.vector_weights()).sum()
    }

    /// Model size in BITS for per-layer weight precisions (vectors always
    /// 16-bit; §4.1). `w_bits.len()` must equal `num_layers()`.
    pub fn size_bits(&self, w_bits: &[Bits]) -> u64 {
        assert_eq!(w_bits.len(), self.layers.len());
        let matrix: u64 = self
            .layers
            .iter()
            .zip(w_bits)
            .map(|(l, b)| l.matrix_weights() * b.bits() as u64)
            .sum();
        matrix + self.total_vector_weights() * VECTOR_BITS
    }

    pub fn size_bytes(&self, w_bits: &[Bits]) -> f64 {
        self.size_bits(w_bits) as f64 / 8.0
    }

    /// Size of the float (32-bit) baseline in bits.
    pub fn baseline_size_bits(&self) -> u64 {
        (self.total_matrix_weights() + self.total_vector_weights()) * BASELINE_BITS
    }

    /// The paper's Cp_r column: 32-bit size / quantized size.
    pub fn compression_ratio(&self, w_bits: &[Bits]) -> f64 {
        self.baseline_size_bits() as f64 / self.size_bits(w_bits) as f64
    }

    /// Render the Table 4 breakdown (ops and params per layer).
    pub fn table4(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "{:<6} {:>8} {:>8} {:>12} {:>10} {:>8} {:>12} {:>9}\n",
            "layer", "m", "n", "MAC", "elemwise", "nonlin", "mat.weights", "vec.wts"
        ));
        for l in &self.layers {
            s.push_str(&format!(
                "{:<6} {:>8} {:>8} {:>12} {:>10} {:>8} {:>12} {:>9}\n",
                l.name,
                l.m,
                l.n,
                l.mac_ops(),
                l.elementwise_ops(),
                l.nonlinear_ops(),
                l.matrix_weights(),
                l.vector_weights()
            ));
        }
        s.push_str(&format!(
            "{:<6} {:>8} {:>8} {:>12} {:>10} {:>8} {:>12} {:>9}\n",
            "total",
            "",
            "",
            self.total_macs(),
            self.total_elementwise(),
            self.total_nonlinear(),
            self.total_matrix_weights(),
            self.total_vector_weights()
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Bits;

    #[test]
    fn table1_formulas_bisru() {
        let l = LayerDesc { name: "L".into(), kind: LayerKind::BiSru, m: 256, n: 550 };
        assert_eq!(l.mac_ops(), 6 * 550 * 256);
        assert_eq!(l.elementwise_ops(), 28 * 550);
        assert_eq!(l.nonlinear_ops(), 4 * 550);
        assert_eq!(l.vector_weights(), 8 * 550);
    }

    #[test]
    fn table4_totals_match_paper() {
        let m = ModelDesc::paper();
        assert_eq!(m.total_macs(), 5_549_500);
        assert_eq!(m.total_matrix_weights(), 5_549_500);
        // Paper Table 4: element-wise total printed as 88000 (rows show
        // 15400 per Bi-SRU layer = 28n; the total row aggregates the
        // bidirectional count). Our per-layer formula is 28n:
        assert_eq!(m.total_elementwise(), 4 * 28 * 550);
        // Vector weights: 4 Bi-SRU layers x 8n = 17600 (paper: 17600).
        assert_eq!(m.total_vector_weights(), 4 * 8 * 550 + 1904);
    }

    #[test]
    fn per_layer_macs_match_table4() {
        let m = ModelDesc::paper();
        let macs: Vec<u64> = m.layers.iter().map(|l| l.mac_ops()).collect();
        assert_eq!(
            macs,
            vec![75_900, 281_600, 844_800, 281_600, 844_800, 281_600, 844_800, 2_094_400]
        );
    }

    #[test]
    fn compression_ratio_matches_table5_s15() {
        // S15: all weights 2-bit -> paper reports 15.6x.
        let m = ModelDesc::paper();
        let bits = vec![Bits::B2; 8];
        let cp = m.compression_ratio(&bits);
        assert!((cp - 15.6).abs() < 0.15, "cp={cp}");
    }

    #[test]
    fn compression_ratio_matches_table5_s1() {
        // S1 weights: 8,4,4,2,4,4,4,4 -> paper reports 8.1x.
        let m = ModelDesc::paper();
        let bits = vec![
            Bits::B8,
            Bits::B4,
            Bits::B4,
            Bits::B2,
            Bits::B4,
            Bits::B4,
            Bits::B4,
            Bits::B4,
        ];
        let cp = m.compression_ratio(&bits);
        assert!((cp - 8.1).abs() < 0.15, "cp={cp}");
    }

    #[test]
    fn all_16bit_is_2x() {
        let m = ModelDesc::paper();
        let cp = m.compression_ratio(&vec![Bits::B16; 8]);
        assert!((cp - 2.0).abs() < 0.01, "cp={cp}");
    }

    #[test]
    fn table4_renders() {
        let t = ModelDesc::paper().table4();
        assert!(t.contains("5549500"));
        assert!(t.contains("FC"));
    }
}
