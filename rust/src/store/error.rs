//! Typed failure classes for the durable-state files — the same
//! discipline as [`hw::manifest::ManifestError`](crate::hw::manifest::ManifestError):
//! feeding arbitrary bytes into a store loader must land in exactly one
//! of these variants, never a panic and never a silent partial load.

use std::fmt;

use crate::util::json::JsonError;

/// The store format version this build reads and writes (checkpoints
/// and eval stores share the version counter; their `kind` field keeps
/// the two file species apart).
pub const STORE_VERSION: u64 = 1;

/// Typed store failure.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    /// The text is not valid JSON (position details in the message).
    Parse(String),
    /// `format_version` is missing or not one this build understands.
    Version { found: u64, supported: u64 },
    /// The file's `kind` discriminator names the other store species (or
    /// something else entirely) — loading a checkpoint as an eval store
    /// must not half-succeed.
    Kind { found: String, expected: &'static str },
    /// A required field is absent.
    Missing { field: String },
    /// A field this schema does not define (strict rejection — a typo'd
    /// field must not silently drop state).
    UnknownField { context: String, field: String },
    /// A field is present but its value is out of contract.
    Invalid(String),
    /// Filesystem failure while loading or saving (path in the message).
    Io(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Parse(msg) => write!(f, "store file is not valid JSON: {msg}"),
            StoreError::Version { found, supported } => write!(
                f,
                "store format_version {found} is not supported (this build reads \
                 version {supported})"
            ),
            StoreError::Kind { found, expected } => {
                write!(f, "store file kind '{found}' is not '{expected}'")
            }
            StoreError::Missing { field } => write!(f, "store file is missing '{field}'"),
            StoreError::UnknownField { context, field } => write!(
                f,
                "unknown field '{field}' in {context} (the store schema is strict; \
                 see DESIGN.md \"Durable state\")"
            ),
            StoreError::Invalid(msg) => write!(f, "invalid store file: {msg}"),
            StoreError::Io(msg) => write!(f, "store io error: {msg}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<JsonError> for StoreError {
    fn from(e: JsonError) -> StoreError {
        StoreError::Parse(e.to_string())
    }
}
