//! The on-disk eval store: the PTQ eval memo ([`eval::ResultCache`])
//! plus the beacon param-set index, persisted so `mohaq serve --store
//! DIR` warm-starts with a hot cache instead of re-running every
//! evaluation after a restart.
//!
//! Layout (v1): `{"format_version":1, "kind":"mohaq-eval-store",
//! "param_sets":[{"name":..., "tensors":[[...], ...]}, ...],
//! "entries":[{"set":S, ...key..., "value":E}, ...]}`.
//!
//! * `param_sets` holds the retrained beacon sets only. Set index 0 —
//!   the baseline — is always re-derived from the artifacts on load, so
//!   a store can never smuggle a different baseline under index 0.
//!   Store-local indices are therefore 1-based positions in the
//!   `param_sets` array; `apply` remaps them to whatever live indices
//!   registration assigns.
//! * `entries` carry [`CacheKey`]s in their two runtime shapes: packed
//!   keys as `{"pw": "<u64>", "pa": "<u64>"}` decimal STRINGS (f64
//!   would drop low bits, silently corrupting keys past 2^53) and wide
//!   keys as explicit per-layer bit-width arrays `{"w":[...],
//!   "a":[...]}`. Wide entries whose genomes turn out packable are
//!   canonicalized to packed form on load, so a stored key always
//!   compares equal to the key the live service builds for the same
//!   genome.
//! * f32 tensor values travel as JSON numbers — every f32 is exactly
//!   representable as f64 and the codec prints shortest-round-trip
//!   decimals, so the round trip is lossless.
//! * The entry array is sorted by its serialized form before writing,
//!   so the same cache state always produces byte-identical files
//!   (HashMap iteration order is not deterministic).
//!
//! Execution/hit counters are NOT persisted: they are process-lifetime
//! observability, not state — a warm-started process starts at zero and
//! its first requests show up as cache hits (which is exactly the
//! signal the `resume-smoke` CI job asserts on).
//!
//! Loading is two-phase so a failed load can never leave the service
//! half-updated: [`EvalStoreData::from_json`] parses and validates the
//! whole file into a staging value without touching the service;
//! [`EvalStoreData::apply`] then validates every tensor shape up front
//! and only afterwards registers sets and bulk-inserts memo entries.

use std::collections::HashMap;
use std::path::Path;

use crate::eval::{CacheKey, EvalService};
use crate::quant::{Bits, QuantConfig};
use crate::util::fsio::atomic_write;
use crate::util::json::{obj, Json};

use super::error::{StoreError, STORE_VERSION};
use super::{check_keys, gate_header, read_text};

/// `kind` discriminator of an eval-store file.
pub const EVAL_STORE_KIND: &str = "mohaq-eval-store";

/// What a load actually did — surfaced on the serve console so
/// operators can see warm-start coverage at a glance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LoadReport {
    /// Beacon param sets registered into the live service.
    pub param_sets_registered: usize,
    /// Beacon param sets skipped because `--evict-beacons` is active.
    pub param_sets_skipped: usize,
    /// Memo entries inserted into the live cache.
    pub entries_loaded: usize,
    /// Memo entries dropped because their param set was skipped.
    pub entries_dropped: usize,
}

/// A fully parsed, fully validated eval store — no live state touched
/// yet. Entry keys use STORE-LOCAL set indices (0 = baseline, i >= 1 =
/// `param_sets[i-1]`); [`EvalStoreData::apply`] remaps them to live
/// indices.
#[derive(Debug, Clone)]
pub struct EvalStoreData {
    pub param_sets: Vec<(String, Vec<Vec<f32>>)>,
    pub entries: Vec<(CacheKey, f64)>,
}

impl EvalStoreData {
    pub fn from_str(text: &str) -> Result<EvalStoreData, StoreError> {
        EvalStoreData::from_json(&Json::parse(text)?)
    }

    pub fn from_json(j: &Json) -> Result<EvalStoreData, StoreError> {
        gate_header(j, EVAL_STORE_KIND)?;
        check_keys(j, "eval store", &["format_version", "kind", "param_sets", "entries"])?;
        let sets_json = j
            .get("param_sets")
            .and_then(Json::as_arr)
            .ok_or(StoreError::Missing { field: "param_sets".into() })?;
        let mut param_sets = Vec::with_capacity(sets_json.len());
        for (i, s) in sets_json.iter().enumerate() {
            let context = format!("param set {i}");
            check_keys(s, &context, &["name", "tensors"])?;
            let name = s
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| StoreError::Missing { field: format!("param_sets[{i}].name") })?;
            let tensors_json = s.get("tensors").and_then(Json::as_arr).ok_or_else(|| {
                StoreError::Missing { field: format!("param_sets[{i}].tensors") }
            })?;
            let mut tensors = Vec::with_capacity(tensors_json.len());
            for (t, tj) in tensors_json.iter().enumerate() {
                let vals = tj.as_arr().ok_or_else(|| {
                    StoreError::Invalid(format!(
                        "param_sets[{i}].tensors[{t}] must be an array of numbers"
                    ))
                })?;
                let mut data = Vec::with_capacity(vals.len());
                for (k, vj) in vals.iter().enumerate() {
                    let v = vj.as_f64().ok_or_else(|| {
                        StoreError::Invalid(format!(
                            "param_sets[{i}].tensors[{t}][{k}] must be a number"
                        ))
                    })?;
                    // Every f32 round-trips exactly through f64; anything
                    // a cast would alter was not written by us.
                    let f = v as f32;
                    if f64::from(f).to_bits() != v.to_bits() {
                        return Err(StoreError::Invalid(format!(
                            "param_sets[{i}].tensors[{t}][{k}] = {v} is not an f32 value"
                        )));
                    }
                    data.push(f);
                }
                tensors.push(data);
            }
            param_sets.push((name.to_string(), tensors));
        }
        let entries_json = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or(StoreError::Missing { field: "entries".into() })?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for (i, e) in entries_json.iter().enumerate() {
            entries.push(entry_from_json(e, i, param_sets.len())?);
        }
        Ok(EvalStoreData { param_sets, entries })
    }

    /// Apply a parsed store to a live service: register the beacon param
    /// sets (unless `evict_beacons` trims them) and bulk-insert the memo
    /// entries under their live set indices. All shape validation runs
    /// BEFORE the first registration, so a bad store leaves the service
    /// untouched; `--cache-cap` keeps bounding residency through normal
    /// rotation.
    pub fn apply(
        self,
        svc: &EvalService,
        evict_beacons: bool,
    ) -> Result<LoadReport, StoreError> {
        let expect: Vec<usize> =
            svc.arts.tensors.iter().map(|t| t.shape.iter().product()).collect();
        if !evict_beacons {
            for (i, (name, tensors)) in self.param_sets.iter().enumerate() {
                if tensors.len() != expect.len() {
                    return Err(StoreError::Invalid(format!(
                        "param set {i} ('{name}') has {} tensors, artifact expects {}",
                        tensors.len(),
                        expect.len()
                    )));
                }
                for (t, (data, want)) in tensors.iter().zip(&expect).enumerate() {
                    if data.len() != *want {
                        return Err(StoreError::Invalid(format!(
                            "param set {i} ('{name}') tensor {t} has {} values, \
                             artifact expects {want}",
                            data.len()
                        )));
                    }
                }
            }
        }
        let mut report = LoadReport::default();
        // Store-local set index -> live index. 0 is always the baseline.
        let mut remap: HashMap<usize, usize> = HashMap::from([(0, 0)]);
        if evict_beacons {
            report.param_sets_skipped = self.param_sets.len();
        } else {
            for (i, (name, tensors)) in self.param_sets.into_iter().enumerate() {
                let live = svc
                    .add_param_set(&name, tensors)
                    .map_err(|e| StoreError::Invalid(format!("registering '{name}': {e}")))?;
                remap.insert(i + 1, live);
                report.param_sets_registered += 1;
            }
        }
        let mut batch = Vec::with_capacity(self.entries.len());
        for (key, value) in self.entries {
            match remap.get(&key.set()) {
                Some(&live) => batch.push((rekey(key, live), value)),
                None => report.entries_dropped += 1,
            }
        }
        report.entries_loaded = batch.len();
        svc.import_entries(batch)
            .map_err(|e| StoreError::Invalid(format!("inserting memo entries: {e}")))?;
        Ok(report)
    }
}

/// Serialize a live service's durable state. Counters are not included
/// (process-lifetime observability, not state).
pub fn to_json(svc: &EvalService) -> Result<Json, StoreError> {
    let sets = svc
        .snapshot_param_sets()
        .map_err(|e| StoreError::Invalid(format!("eval service: {e}")))?;
    // Live index -> store-local index; evicted sets are already absent
    // (their memo entries were purged at eviction, but stay defensive).
    let mut remap: HashMap<usize, usize> = HashMap::new();
    let mut sets_json = Vec::new();
    for (live, set) in &sets {
        if *live == 0 {
            remap.insert(0, 0);
            continue;
        }
        remap.insert(*live, sets_json.len() + 1);
        sets_json.push(obj(vec![
            ("name", set.name.as_str().into()),
            (
                "tensors",
                Json::Arr(
                    set.host
                        .iter()
                        .map(|t| Json::Arr(t.iter().map(|&v| Json::from(f64::from(v))).collect()))
                        .collect(),
                ),
            ),
        ]));
    }
    let entries = svc
        .export_entries()
        .map_err(|e| StoreError::Invalid(format!("eval service: {e}")))?;
    let mut entry_rows: Vec<(String, Json)> = Vec::with_capacity(entries.len());
    for (key, value) in entries {
        let Some(&local) = remap.get(&key.set()) else { continue };
        let row = entry_to_json(rekey(key, local), value);
        entry_rows.push((row.to_string(), row));
    }
    // HashMap iteration order is nondeterministic; the file must not be.
    entry_rows.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(obj(vec![
        ("format_version", (STORE_VERSION as usize).into()),
        ("kind", EVAL_STORE_KIND.into()),
        ("param_sets", Json::Arr(sets_json)),
        ("entries", Json::Arr(entry_rows.into_iter().map(|(_, j)| j).collect())),
    ]))
}

/// Crash-safe save (temp file + fsync + atomic rename).
pub fn save(path: &Path, svc: &EvalService) -> Result<(), StoreError> {
    atomic_write(path, to_json(svc)?.to_string().as_bytes())
        .map_err(|e| StoreError::Io(format!("writing {}: {e}", path.display())))
}

/// Load a store file into a live service; see [`EvalStoreData::apply`]
/// for the untouched-on-failure contract.
pub fn load(
    path: &Path,
    svc: &EvalService,
    evict_beacons: bool,
) -> Result<LoadReport, StoreError> {
    EvalStoreData::from_str(&read_text(path)?)?.apply(svc, evict_beacons)
}

/// Rewrite a key's set index, preserving the genome encoding bitwise.
fn rekey(key: CacheKey, set: usize) -> CacheKey {
    match key {
        CacheKey::Packed(_, pw, pa) => CacheKey::Packed(set, pw, pa),
        CacheKey::Wide(_, w, a) => CacheKey::Wide(set, w, a),
    }
}

fn entry_to_json(key: CacheKey, value: f64) -> Json {
    match key {
        CacheKey::Packed(set, pw, pa) => obj(vec![
            ("set", set.into()),
            ("pw", pw.to_string().into()),
            ("pa", pa.to_string().into()),
            ("value", value.into()),
        ]),
        CacheKey::Wide(set, w, a) => obj(vec![
            ("set", set.into()),
            ("w", Json::Arr(w.iter().map(|b| Json::from(b.bits() as usize)).collect())),
            ("a", Json::Arr(a.iter().map(|b| Json::from(b.bits() as usize)).collect())),
            ("value", value.into()),
        ]),
    }
}

fn entry_from_json(e: &Json, i: usize, num_sets: usize) -> Result<(CacheKey, f64), StoreError> {
    let context = format!("entry {i}");
    check_keys(e, &context, &["set", "pw", "pa", "w", "a", "value"])?;
    let set = e
        .get("set")
        .and_then(Json::as_f64)
        .filter(|n| n.fract() == 0.0 && *n >= 0.0)
        .map(|n| n as usize)
        .ok_or_else(|| StoreError::Missing { field: format!("entries[{i}].set") })?;
    if set > num_sets {
        return Err(StoreError::Invalid(format!(
            "entries[{i}].set = {set} but the store declares {num_sets} param set(s)"
        )));
    }
    let value = e
        .get("value")
        .and_then(Json::as_f64)
        .filter(|v| v.is_finite())
        .ok_or_else(|| {
            StoreError::Invalid(format!("entries[{i}].value must be a finite number"))
        })?;
    let packed = (e.get("pw"), e.get("pa"));
    let wide = (e.get("w"), e.get("a"));
    let key = match (packed, wide) {
        ((Some(pw), Some(pa)), (None, None)) => {
            let parse_word = |side: &str, v: &Json| -> Result<u64, StoreError> {
                v.as_str().and_then(|s| s.parse::<u64>().ok()).ok_or_else(|| {
                    StoreError::Invalid(format!(
                        "entries[{i}].{side} must be a u64 encoded as a decimal string"
                    ))
                })
            };
            CacheKey::Packed(set, parse_word("pw", pw)?, parse_word("pa", pa)?)
        }
        ((None, None), (Some(w), Some(a))) => {
            let parse_bits = |side: &str, v: &Json| -> Result<Vec<Bits>, StoreError> {
                let nums = v.as_arr().ok_or_else(|| {
                    StoreError::Invalid(format!(
                        "entries[{i}].{side} must be an array of bit widths"
                    ))
                })?;
                nums.iter()
                    .map(|n| {
                        n.as_f64()
                            .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= u32::MAX as f64)
                            .and_then(|x| Bits::from_bits(x as u32))
                            .ok_or_else(|| {
                                StoreError::Invalid(format!(
                                    "entries[{i}].{side}: {n:?} is not a supported bit width"
                                ))
                            })
                    })
                    .collect()
            };
            let (w_bits, a_bits) = (parse_bits("w", w)?, parse_bits("a", a)?);
            if w_bits.len() != a_bits.len() {
                return Err(StoreError::Invalid(format!(
                    "entries[{i}]: 'w' has {} genes, 'a' has {}",
                    w_bits.len(),
                    a_bits.len()
                )));
            }
            // Canonicalize: a packable genome stored wide must compare
            // equal to the packed key the live service builds for it.
            CacheKey::new(set, &QuantConfig { w_bits, a_bits })
        }
        _ => {
            return Err(StoreError::Invalid(format!(
                "entries[{i}] must carry either a packed key (pw + pa) or a wide key (w + a)"
            )))
        }
    };
    Ok((key, value))
}
