//! Durable search state.
//!
//! Two file species, one discipline (see DESIGN.md "Durable state"):
//!
//! * **Search checkpoints** ([`checkpoint::SearchCheckpoint`]) — the
//!   migration-boundary island state; `mohaq search --resume CKPT`
//!   continues to a merged front bitwise-identical to the uninterrupted
//!   run, single-process or distributed.
//! * **Eval stores** ([`eval_store`]) — the PTQ eval memo and beacon
//!   param-set index; `mohaq serve --store DIR` warm-starts with a hot
//!   cache instead of recomputing evaluations across restarts.
//!
//! Both are versioned JSON written only through
//! [`util::fsio::atomic_write`](crate::util::fsio::atomic_write)
//! (temp file + fsync + atomic rename), gated on an exact
//! `format_version`, strict about unknown fields, and fail only with a
//! typed [`StoreError`] — never a panic and never a silent partial
//! load. A failed load leaves all in-memory state untouched.

use std::path::Path;

use crate::util::json::Json;

pub mod checkpoint;
pub mod error;
pub mod eval_store;

pub use checkpoint::{SearchCheckpoint, CHECKPOINT_KIND};
pub use error::{StoreError, STORE_VERSION};
pub use eval_store::{EvalStoreData, LoadReport, EVAL_STORE_KIND};

/// Read a store file to text, mapping filesystem failures to the typed
/// error (path included — store errors surface on operator terminals).
pub(crate) fn read_text(path: &Path) -> Result<String, StoreError> {
    std::fs::read_to_string(path)
        .map_err(|e| StoreError::Io(format!("reading {}: {e}", path.display())))
}

/// Gate the shared header of every store file: top level must be an
/// object, `format_version` must be exactly [`STORE_VERSION`], and the
/// `kind` discriminator must name the expected file species. The kind
/// check runs before the version check so "you handed the eval-store
/// loader a checkpoint" is reported as such even across future version
/// bumps.
pub(crate) fn gate_header(j: &Json, expected_kind: &'static str) -> Result<(), StoreError> {
    if j.as_obj().is_none() {
        return Err(StoreError::Invalid("top level must be a JSON object".into()));
    }
    match j.get("kind") {
        None => return Err(StoreError::Missing { field: "kind".into() }),
        Some(k) => match k.as_str() {
            None => return Err(StoreError::Invalid("'kind' must be a string".into())),
            Some(s) if s != expected_kind => {
                return Err(StoreError::Kind { found: s.to_string(), expected: expected_kind })
            }
            Some(_) => {}
        },
    }
    let version = j
        .get("format_version")
        .ok_or(StoreError::Missing { field: "format_version".into() })?;
    let found = version
        .as_f64()
        .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= u64::MAX as f64)
        .map(|n| n as u64)
        .ok_or_else(|| {
            StoreError::Invalid("'format_version' must be a non-negative integer".into())
        })?;
    if found != STORE_VERSION {
        return Err(StoreError::Version { found, supported: STORE_VERSION });
    }
    Ok(())
}

/// Strict-schema guard: every key of `j` must be in `allowed`, anything
/// else is a typed [`StoreError::UnknownField`]. A typo'd field in a
/// hand-edited store file must fail loudly, not silently drop state.
pub(crate) fn check_keys(j: &Json, context: &str, allowed: &[&str]) -> Result<(), StoreError> {
    let map = j.as_obj().ok_or_else(|| {
        StoreError::Invalid(format!("{context} must be a JSON object"))
    })?;
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(StoreError::UnknownField {
                context: context.to_string(),
                field: key.clone(),
            });
        }
    }
    Ok(())
}
