//! Search checkpoints: the exact migration-boundary state the
//! distributed re-shard already replays, persisted to disk.
//!
//! A checkpoint is written at a migration boundary and holds the full
//! experiment spec plus one post-migration [`IslandSnapshot`] per global
//! island — RNG position, cumulative evaluation count, ranked
//! population. Because island RNG streams are pure functions of
//! (seed, K, island index) and the restore is exact,
//! `mohaq search --resume CKPT` continues to a merged front
//! bitwise-identical to the uninterrupted run (pinned by
//! `rust/tests/store.rs` and the `resume-smoke` CI job) — whether the
//! original run was single-process or a distributed coordinator that
//! crashed mid-fleet.
//!
//! The snapshot payload rides the SAME lossless codec the dist wire
//! protocol uses (`serve::protocol`): u64 RNG words as decimal strings
//! (f64 would drop low bits), shortest-round-trip floats, `usize::MAX`
//! rank via the saturating cast. On top of that codec this module is
//! strict the way `hw::manifest` is: unknown fields are rejected at the
//! levels it owns, `format_version` is gated exactly, and every failure
//! is a typed [`StoreError`].
//!
//! Beacon runs additionally carry one [`BeaconSnapshot`] per finalized
//! beacon: its quantization config (the wire's bit-width codec) plus the
//! NAME of its retrained parameter set. Names — not process-local
//! indices — are the durable identity; a resume re-resolves each name
//! against the eval store and rejects the checkpoint if a referenced
//! set is missing, instead of silently restarting retraining.

use std::path::Path;

use crate::coordinator::beacon::BeaconSnapshot;
use crate::coordinator::ExperimentSpec;
use crate::moo::IslandSnapshot;
use crate::serve::protocol::{qc_from_json, qc_to_json, snapshot_from_json, snapshot_to_json};
use crate::util::fsio::atomic_write;
use crate::util::json::{obj, Json};

use super::error::{StoreError, STORE_VERSION};
use super::{check_keys, gate_header, read_text};

/// `kind` discriminator of a checkpoint file.
pub const CHECKPOINT_KIND: &str = "mohaq-checkpoint";

/// Exactly the keys a v1 snapshot object may carry (strict rejection —
/// a typo'd `"evaluations"` must not silently zero a counter).
const SNAPSHOT_KEYS: [&str; 4] = ["island", "rng", "evaluations", "pop"];

/// Exactly the keys a beacon entry may carry.
const BEACON_KEYS: [&str; 2] = ["set_name", "qc"];

/// One resumable search: the spec that produced it, the boundary
/// generation the snapshots were taken at, one post-migration snapshot
/// per global island (ascending island order), and — for beacon runs —
/// the beacons finalized so far, in creation order.
#[derive(Debug, Clone)]
pub struct SearchCheckpoint {
    pub spec: ExperimentSpec,
    pub generation: usize,
    pub snapshots: Vec<IslandSnapshot>,
    pub beacons: Vec<BeaconSnapshot>,
}

impl SearchCheckpoint {
    /// Build a validated checkpoint. The same validation runs on load,
    /// so an unloadable checkpoint can never be written.
    pub fn new(
        spec: ExperimentSpec,
        generation: usize,
        snapshots: Vec<IslandSnapshot>,
        beacons: Vec<BeaconSnapshot>,
    ) -> Result<SearchCheckpoint, StoreError> {
        let ckpt = SearchCheckpoint { spec, generation, snapshots, beacons };
        ckpt.validate()?;
        Ok(ckpt)
    }

    /// Number of global islands this checkpoint covers.
    pub fn islands(&self) -> usize {
        self.snapshots.len()
    }

    fn validate(&self) -> Result<(), StoreError> {
        let cfg = self.spec.island.as_ref().ok_or_else(|| {
            StoreError::Invalid("checkpoint spec has no island config (checkpoints exist only \
                                 at migration boundaries, which need >= 2 islands)".into())
        })?;
        if cfg.islands < 2 {
            return Err(StoreError::Invalid(format!(
                "checkpoint spec declares {} island(s); migration boundaries need >= 2",
                cfg.islands
            )));
        }
        if self.generation == 0
            || self.generation > self.spec.ga.generations
            || self.generation % cfg.migration_interval != 0
        {
            return Err(StoreError::Invalid(format!(
                "generation {} is not a migration boundary of this spec \
                 (interval {}, {} generations)",
                self.generation, cfg.migration_interval, self.spec.ga.generations
            )));
        }
        if self.snapshots.len() != cfg.islands {
            return Err(StoreError::Invalid(format!(
                "checkpoint has {} snapshot(s) for {} island(s)",
                self.snapshots.len(),
                cfg.islands
            )));
        }
        for (i, s) in self.snapshots.iter().enumerate() {
            if s.island != i {
                return Err(StoreError::Invalid(format!(
                    "snapshot {i} is for island {} (snapshots must cover islands 0..{} \
                     in ascending order)",
                    s.island,
                    cfg.islands
                )));
            }
            if s.pop.is_empty() {
                return Err(StoreError::Invalid(format!(
                    "snapshot for island {i} has an empty population"
                )));
            }
        }
        if !self.beacons.is_empty() && self.spec.beacon.is_none() {
            return Err(StoreError::Invalid(format!(
                "checkpoint carries {} beacon(s) but its spec has no beacon policy",
                self.beacons.len()
            )));
        }
        for (i, b) in self.beacons.iter().enumerate() {
            if b.set_name.is_empty() {
                return Err(StoreError::Invalid(format!(
                    "beacon {i} has an empty parameter-set name"
                )));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("format_version", (STORE_VERSION as usize).into()),
            ("kind", CHECKPOINT_KIND.into()),
            ("generation", self.generation.into()),
            ("spec", self.spec.to_json()),
            ("islands", Json::Arr(self.snapshots.iter().map(snapshot_to_json).collect())),
        ];
        if !self.beacons.is_empty() {
            let arr = self
                .beacons
                .iter()
                .map(|b| {
                    obj(vec![("set_name", b.set_name.as_str().into()), ("qc", qc_to_json(&b.qc))])
                })
                .collect();
            fields.push(("beacons", Json::Arr(arr)));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<SearchCheckpoint, StoreError> {
        gate_header(j, CHECKPOINT_KIND)?;
        check_keys(
            j,
            "checkpoint",
            &["format_version", "kind", "generation", "spec", "islands", "beacons"],
        )?;
        let generation = j
            .get("generation")
            .and_then(Json::as_f64)
            .filter(|n| n.fract() == 0.0 && *n >= 0.0)
            .map(|n| n as usize)
            .ok_or_else(|| StoreError::Missing { field: "generation".into() })?;
        let spec_json = j.get("spec").ok_or(StoreError::Missing { field: "spec".into() })?;
        let spec = ExperimentSpec::from_json(spec_json)
            .map_err(|e| StoreError::Invalid(format!("checkpoint spec: {e}")))?;
        let islands = j
            .get("islands")
            .and_then(Json::as_arr)
            .ok_or(StoreError::Missing { field: "islands".into() })?;
        let mut snapshots = Vec::with_capacity(islands.len());
        for (i, s) in islands.iter().enumerate() {
            check_keys(s, &format!("snapshot {i}"), &SNAPSHOT_KEYS)?;
            for key in SNAPSHOT_KEYS {
                if s.get(key).is_none() {
                    return Err(StoreError::Missing { field: format!("islands[{i}].{key}") });
                }
            }
            snapshots.push(snapshot_from_json(s).map_err(|e| {
                StoreError::Invalid(format!("snapshot {i}: {}", e.message))
            })?);
        }
        let mut beacons = Vec::new();
        if let Some(entries) = j.get("beacons") {
            let entries = entries
                .as_arr()
                .ok_or_else(|| StoreError::Invalid("'beacons' must be an array".into()))?;
            for (i, b) in entries.iter().enumerate() {
                check_keys(b, &format!("beacon {i}"), &BEACON_KEYS)?;
                let set_name = b
                    .get("set_name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| StoreError::Missing { field: format!("beacons[{i}].set_name") })?
                    .to_string();
                let qc = qc_from_json(b.get("qc")).map_err(|e| {
                    StoreError::Invalid(format!("beacon {i}: {}", e.message))
                })?;
                beacons.push(BeaconSnapshot { qc, set_name });
            }
        }
        SearchCheckpoint::new(spec, generation, snapshots, beacons)
    }

    pub fn from_str(text: &str) -> Result<SearchCheckpoint, StoreError> {
        SearchCheckpoint::from_json(&Json::parse(text)?)
    }

    /// Crash-safe write: temp file + fsync + atomic rename, so a reader
    /// (or a resume after a crash mid-write) sees either the previous
    /// checkpoint or this one, never a torn prefix.
    pub fn save(&self, path: &Path) -> Result<(), StoreError> {
        self.validate()?;
        atomic_write(path, self.to_json().to_string().as_bytes())
            .map_err(|e| StoreError::Io(format!("writing {}: {e}", path.display())))
    }

    pub fn load(path: &Path) -> Result<SearchCheckpoint, StoreError> {
        SearchCheckpoint::from_str(&read_text(path)?)
    }
}
