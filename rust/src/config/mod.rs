//! JSON experiment configs: a file-driven way to define searches beyond
//! the three paper presets (used by `mohaq search --config FILE`). This is
//! a thin file-IO wrapper over `ExperimentSpec::from_json`, so a config
//! file can express everything the builder can — and goes through the
//! exact same validation. The same JSON shape is the serve-mode wire
//! format: a `{"op":"search","spec":{...}}` frame carries exactly a
//! config-file body (per-tenant platform table included), validated
//! server-side into typed error frames (see `serve::protocol`).
//!
//! Example:
//! ```json
//! {
//!   "name": "custom-bitfusion",
//!   "platform": {"name": "bitfusion", "params": {"sram_mb": 1.5}},
//!   "objectives": ["error", "neg_speedup"],
//!   "ga": {"pop_size": 10, "initial_pop_size": 40, "generations": 30, "seed": 7},
//!   "beacon": {"threshold": 5.0, "retrain_steps": 200, "max_beacons": 3},
//!   "err_feasible_pp": 8.0
//! }
//! ```
//!
//! The legacy flat platform shape `{"kind": "bitfusion", "sram_mb": 1.5}`
//! is still accepted (see `hw::registry::PlatformSpec::from_json`), as is
//! the singular `"platform"` key — the canonical form is a `"platforms"`
//! table plus platform-bound objectives:
//!
//! ```json
//! {
//!   "name": "joint",
//!   "platforms": [{"name": "silago", "params": {"sram_mb": 6.0}},
//!                 {"name": "bitfusion", "params": {"sram_mb": 2.0}}],
//!   "objectives": ["error", "neg_speedup@silago", "neg_speedup@bitfusion"]
//! }
//! ```

use crate::coordinator::{ExperimentSpec, SearchError};

/// Parse an ExperimentSpec from JSON text.
pub fn spec_from_json(text: &str) -> Result<ExperimentSpec, SearchError> {
    ExperimentSpec::from_json_str(text)
}

pub fn spec_from_file(path: &str) -> Result<ExperimentSpec, SearchError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| SearchError::Config(format!("reading {path}: {e}")))?;
    spec_from_json(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let spec = spec_from_json(
            r#"{
              "name": "custom",
              "platform": {"name": "bitfusion", "params": {"sram_mb": 1.5}},
              "objectives": ["error", "neg_speedup"],
              "ga": {"pop_size": 12, "generations": 30, "seed": 7},
              "beacon": {"threshold": 5.0, "retrain_steps": 200},
              "err_feasible_pp": 10.0
            }"#,
        )
        .unwrap();
        assert_eq!(spec.name, "custom");
        let platform = &spec.platforms[0];
        assert_eq!(platform.name, "bitfusion");
        assert_eq!(platform.f64("sram_mb"), Some(1.5));
        assert_eq!(spec.objectives.len(), 2);
        // The lone platform binds the hardware objective explicitly.
        assert_eq!(spec.objectives[1].id(), "neg_speedup@bitfusion");
        assert_eq!(spec.ga.pop_size, 12);
        assert_eq!(spec.ga.generations, 30);
        assert_eq!(spec.beacon.as_ref().unwrap().threshold, Some(5.0));
        assert_eq!(spec.err_feasible_pp, 10.0);
    }

    #[test]
    fn accepts_legacy_platform_shape() {
        let spec = spec_from_json(
            r#"{
              "name": "legacy",
              "platform": {"kind": "silago", "sram_mb": 4.0},
              "objectives": ["error", "speedup"]
            }"#,
        )
        .unwrap();
        let platform = &spec.platforms[0];
        assert_eq!(platform.name, "silago");
        assert_eq!(platform.f64("sram_mb"), Some(4.0));
        assert_eq!(spec.objectives[1].id(), "neg_speedup@silago");
    }

    #[test]
    fn parses_cross_platform_config() {
        let spec = spec_from_json(
            r#"{
              "name": "joint",
              "platforms": [{"name": "silago", "params": {"sram_mb": 6.0}},
                            {"name": "bitfusion", "params": {"sram_mb": 2.0}}],
              "objectives": ["error", "neg_speedup@silago", "neg_speedup@bitfusion"]
            }"#,
        )
        .unwrap();
        assert_eq!(spec.platforms.len(), 2);
        assert_eq!(spec.objectives[1].platform(), Some("silago"));
        assert_eq!(spec.objectives[2].platform(), Some("bitfusion"));
        // An unbound hardware objective with several platforms is
        // rejected as ambiguous.
        let err = spec_from_json(
            r#"{
              "name": "joint",
              "platforms": [{"name": "silago"}, {"name": "bitfusion"}],
              "objectives": ["error", "neg_speedup"]
            }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("ambiguous"), "{err}");
    }

    #[test]
    fn defaults_without_platform_or_beacon() {
        let spec = spec_from_json(
            r#"{"name": "plain", "objectives": ["error", "size"]}"#,
        )
        .unwrap();
        assert!(spec.platforms.is_empty());
        assert!(spec.beacon.is_none());
        assert_eq!(spec.ga.pop_size, 10);
        assert_eq!(spec.err_feasible_pp, 8.0);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(spec_from_json("{").is_err());
        assert!(spec_from_json(r#"{"name": "x", "objectives": []}"#).is_err());
        assert!(spec_from_json(r#"{"name": "x", "objectives": ["bogus"]}"#).is_err());
        // Unknown platform -> typed error naming the registered platforms.
        let err = spec_from_json(
            r#"{"name": "x", "objectives": ["error"], "platform": {"kind": "tpu"}}"#,
        )
        .unwrap_err();
        assert!(matches!(err, SearchError::UnknownPlatform { .. }), "{err}");
        // Hardware objective without a platform.
        assert!(spec_from_json(r#"{"name": "x", "objectives": ["neg_speedup"]}"#).is_err());
    }
}
