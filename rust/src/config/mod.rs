//! JSON experiment configs: a file-driven way to define searches beyond
//! the three paper presets (used by `mohaq search --config FILE`).
//!
//! Example:
//! ```json
//! {
//!   "name": "custom-bitfusion",
//!   "platform": {"kind": "bitfusion", "sram_mb": 1.5},
//!   "objectives": ["error", "neg_speedup"],
//!   "ga": {"pop_size": 10, "initial_pop_size": 40, "generations": 30, "seed": 7},
//!   "beacon": {"threshold": 5.0, "retrain_steps": 200, "max_beacons": 3},
//!   "err_feasible_pp": 8.0
//! }
//! ```

use anyhow::{Context, Result};

use crate::coordinator::{BeaconPolicyOverrides, ExperimentSpec, ObjectiveKind, PlatformChoice};
use crate::moo::Nsga2Config;
use crate::util::json::Json;

fn parse_objective(name: &str) -> Result<ObjectiveKind> {
    Ok(match name {
        "error" | "wer" => ObjectiveKind::Error,
        "size" | "size_mb" => ObjectiveKind::SizeMb,
        "neg_speedup" | "speedup" => ObjectiveKind::NegSpeedup,
        "energy" | "energy_uj" => ObjectiveKind::EnergyUj,
        other => anyhow::bail!("unknown objective '{other}'"),
    })
}

fn parse_platform(j: Option<&Json>) -> Result<PlatformChoice> {
    let Some(j) = j else { return Ok(PlatformChoice::None) };
    let kind = j.req("kind")?.as_str().context("platform.kind")?;
    let sram_mb = j.get("sram_mb").and_then(|v| v.as_f64());
    Ok(match kind {
        "none" => PlatformChoice::None,
        "silago" => PlatformChoice::SiLago { sram_mb: sram_mb.unwrap_or(6.0) },
        "bitfusion" => PlatformChoice::Bitfusion { sram_mb: sram_mb.unwrap_or(2.0) },
        other => anyhow::bail!("unknown platform '{other}'"),
    })
}

/// Parse an ExperimentSpec from JSON text.
pub fn spec_from_json(text: &str) -> Result<ExperimentSpec> {
    let j = Json::parse(text).map_err(|e| anyhow::anyhow!("config: {e}"))?;
    let name = j.req("name")?.as_str().context("name")?.to_string();
    let platform = parse_platform(j.get("platform"))?;
    let objectives = j
        .req("objectives")?
        .as_arr()
        .context("objectives")?
        .iter()
        .map(|v| parse_objective(v.as_str().unwrap_or("")))
        .collect::<Result<Vec<_>>>()?;
    anyhow::ensure!(!objectives.is_empty(), "at least one objective required");

    let mut ga = Nsga2Config::default();
    if let Some(g) = j.get("ga") {
        if let Some(v) = g.get("pop_size").and_then(Json::as_usize) {
            ga.pop_size = v;
        }
        if let Some(v) = g.get("initial_pop_size").and_then(Json::as_usize) {
            ga.initial_pop_size = v;
        }
        if let Some(v) = g.get("generations").and_then(Json::as_usize) {
            ga.generations = v;
        }
        if let Some(v) = g.get("seed").and_then(Json::as_i64) {
            ga.seed = v as u64;
        }
        if let Some(v) = g.get("crossover_prob").and_then(Json::as_f64) {
            ga.crossover_prob = v;
        }
        if let Some(v) = g.get("mutation_prob").and_then(Json::as_f64) {
            ga.mutation_prob = Some(v);
        }
    }

    let beacon = j.get("beacon").map(|b| BeaconPolicyOverrides {
        threshold: b.get("threshold").and_then(Json::as_f64),
        retrain_steps: b.get("retrain_steps").and_then(Json::as_usize),
        max_beacons: b.get("max_beacons").and_then(Json::as_usize),
    });

    Ok(ExperimentSpec {
        name,
        platform,
        objectives,
        beacon,
        ga,
        err_feasible_pp: j.get("err_feasible_pp").and_then(Json::as_f64).unwrap_or(8.0),
    })
}

pub fn spec_from_file(path: &str) -> Result<ExperimentSpec> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    spec_from_json(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let spec = spec_from_json(
            r#"{
              "name": "custom",
              "platform": {"kind": "bitfusion", "sram_mb": 1.5},
              "objectives": ["error", "neg_speedup"],
              "ga": {"pop_size": 12, "generations": 30, "seed": 7},
              "beacon": {"threshold": 5.0, "retrain_steps": 200},
              "err_feasible_pp": 10.0
            }"#,
        )
        .unwrap();
        assert_eq!(spec.name, "custom");
        assert!(matches!(spec.platform, PlatformChoice::Bitfusion { sram_mb } if sram_mb == 1.5));
        assert_eq!(spec.objectives.len(), 2);
        assert_eq!(spec.ga.pop_size, 12);
        assert_eq!(spec.ga.generations, 30);
        assert_eq!(spec.beacon.as_ref().unwrap().threshold, Some(5.0));
        assert_eq!(spec.err_feasible_pp, 10.0);
    }

    #[test]
    fn defaults_without_platform_or_beacon() {
        let spec = spec_from_json(
            r#"{"name": "plain", "objectives": ["error", "size"]}"#,
        )
        .unwrap();
        assert!(matches!(spec.platform, PlatformChoice::None));
        assert!(spec.beacon.is_none());
        assert_eq!(spec.ga.pop_size, 10);
        assert_eq!(spec.err_feasible_pp, 8.0);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(spec_from_json("{").is_err());
        assert!(spec_from_json(r#"{"name": "x", "objectives": []}"#).is_err());
        assert!(spec_from_json(r#"{"name": "x", "objectives": ["bogus"]}"#).is_err());
        assert!(spec_from_json(
            r#"{"name": "x", "objectives": ["error"], "platform": {"kind": "tpu"}}"#
        )
        .is_err());
    }
}
