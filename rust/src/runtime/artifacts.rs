//! Artifact bundle loading: manifest.json (single source of truth),
//! weights.bin, calibration tables and the corpus splits emitted by
//! ``python -m compile.aot`` (see python/compile/aot.py for the format).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::ModelDesc;
use crate::quant::{ClipTable, QparamTable};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

/// One corpus split as flat host arrays (sequences x frames x features).
#[derive(Debug, Clone)]
pub struct Split {
    /// (num_seqs, seq_len, feat_dim) row-major f32.
    pub x: Vec<f32>,
    /// (num_seqs, seq_len) row-major i32.
    pub y: Vec<i32>,
    pub num_seqs: usize,
}

impl Split {
    /// Borrow batch `k` of `batch` sequences: (&x, &y) slices.
    pub fn batch(&self, k: usize, batch: usize, seq_len: usize, feat: usize) -> (&[f32], &[i32]) {
        let xs = batch * seq_len * feat;
        let ys = batch * seq_len;
        (&self.x[k * xs..(k + 1) * xs], &self.y[k * ys..(k + 1) * ys])
    }

    pub fn num_batches(&self, batch: usize) -> usize {
        self.num_seqs / batch
    }
}

#[derive(Debug, Clone)]
pub struct BaselineMetrics {
    pub val_err_subsets: Vec<f64>,
    pub val_err: f64,
    pub test_err: f64,
    pub val_err_16bit: f64,
    pub beacon_lr: f64,
}

/// Everything the coordinator needs from `make artifacts`.
pub struct Artifacts {
    pub dir: PathBuf,
    pub manifest: Json,
    /// Quantizable layer names in genome order.
    pub layer_names: Vec<String>,
    pub model: ModelDesc,
    /// Weight tensors in HLO parameter order (name -> data is `tensors`).
    pub tensors: Vec<TensorInfo>,
    pub weights: Vec<Vec<f32>>,
    pub w_clips: ClipTable,
    pub a_clips: ClipTable,
    /// Dense `[layer][bits] -> (Δ,qmin,qmax,en)` rows folded from the clip
    /// tables once at load — the eval/trainer hot paths resolve genomes
    /// through this instead of the string-keyed `ClipTable`s.
    pub qtable: QparamTable,
    pub batch: usize,
    pub seq_len: usize,
    pub feat_dim: usize,
    pub num_classes: usize,
    pub train: Split,
    /// One Split per validation subset (paper §4.2: error = max over 4).
    pub val_subsets: Vec<Split>,
    pub test: Split,
    pub baseline: BaselineMetrics,
}

fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    anyhow::ensure!(raw.len() % 4 == 0, "{path:?} not a multiple of 4 bytes");
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    anyhow::ensure!(raw.len() % 4 == 0, "{path:?} not a multiple of 4 bytes");
    Ok(raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn parse_clip_table(j: &Json) -> Result<ClipTable> {
    let mut table = ClipTable::new();
    let obj = j.as_obj().context("clip table is not an object")?;
    for (layer, bits_map) in obj {
        let mut inner = BTreeMap::new();
        for (bits, clip) in bits_map.as_obj().context("clip bits map")? {
            inner.insert(
                bits.parse::<u32>().context("clip bits key")?,
                clip.as_f64().context("clip value")?,
            );
        }
        table.insert(layer.clone(), inner);
    }
    Ok(table)
}

impl Artifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Artifacts> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} — run `make artifacts` first"))?;
        let manifest = Json::parse(&manifest_text)
            .map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;

        let layer_names: Vec<String> = manifest
            .req("quant_layers")?
            .as_arr()
            .context("quant_layers")?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();

        let dims: Vec<(String, usize, usize)> = manifest
            .req("layer_dims")?
            .as_arr()
            .context("layer_dims")?
            .iter()
            .map(|d| {
                Ok((
                    d.req("name")?.as_str().context("name")?.to_string(),
                    d.req("m")?.as_usize().context("m")?,
                    d.req("n")?.as_usize().context("n")?,
                ))
            })
            .collect::<Result<_>>()?;
        let model = ModelDesc::from_dims(&dims);

        // Weights.
        let weights_meta = manifest.req("weights")?;
        let blob = std::fs::read(dir.join(
            weights_meta.req("file")?.as_str().context("weights.file")?,
        ))?;
        let mut tensors = Vec::new();
        let mut weights = Vec::new();
        for t in weights_meta.req("tensors")?.as_arr().context("tensors")? {
            let info = TensorInfo {
                name: t.req("name")?.as_str().context("tensor name")?.to_string(),
                shape: t.req("shape")?.usize_vec().context("tensor shape")?,
                offset: t.req("offset")?.as_usize().context("offset")?,
                bytes: t.req("bytes")?.as_usize().context("bytes")?,
            };
            let raw = &blob[info.offset..info.offset + info.bytes];
            weights.push(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
            tensors.push(info);
        }

        // Calibration.
        let calib_text = std::fs::read_to_string(dir.join("calibration.json"))?;
        let calib = Json::parse(&calib_text)
            .map_err(|e| anyhow::anyhow!("calibration.json: {e}"))?;
        let w_clips = parse_clip_table(calib.req("w_clips")?)?;
        let a_clips = parse_clip_table(calib.req("a_clips")?)?;

        // Data geometry.
        let data = manifest.req("data")?;
        let batch = data.req("batch")?.as_usize().context("batch")?;
        let seq_len = data.req("seq_len")?.as_usize().context("seq_len")?;
        let feat_dim = data.req("feat_dim")?.as_usize().context("feat_dim")?;
        let num_classes = data.req("num_classes")?.as_usize().context("classes")?;

        let load_split = |key: &str| -> Result<(Vec<f32>, Vec<i32>, Vec<usize>)> {
            let meta = data.req(key)?;
            let x = read_f32(&dir.join(meta.req("x")?.as_str().context("x")?))?;
            let y = read_i32(&dir.join(meta.req("y")?.as_str().context("y")?))?;
            let shape = meta.req("shape")?.usize_vec().context("shape")?;
            Ok((x, y, shape))
        };

        let (tx, ty, tshape) = load_split("train")?;
        let train = Split { x: tx, y: ty, num_seqs: tshape[0] };
        let (ex, ey, eshape) = load_split("test")?;
        let test = Split { x: ex, y: ey, num_seqs: eshape[0] };

        // Validation: stored stacked (subsets, seqs, T, F); unstack.
        let (vx, vy, vshape) = load_split("val")?;
        let (n_sub, per_sub) = (vshape[0], vshape[1]);
        let x_stride = per_sub * seq_len * feat_dim;
        let y_stride = per_sub * seq_len;
        let val_subsets: Vec<Split> = (0..n_sub)
            .map(|s| Split {
                x: vx[s * x_stride..(s + 1) * x_stride].to_vec(),
                y: vy[s * y_stride..(s + 1) * y_stride].to_vec(),
                num_seqs: per_sub,
            })
            .collect();

        let b = manifest.req("baseline")?;
        let baseline = BaselineMetrics {
            val_err_subsets: b.req("val_err_subsets")?.f64_vec().context("subsets")?,
            val_err: b.req("val_err")?.as_f64().context("val_err")?,
            test_err: b.req("test_err")?.as_f64().context("test_err")?,
            val_err_16bit: b.req("val_err_16bit")?.as_f64().context("16bit")?,
            beacon_lr: b.req("beacon_lr")?.as_f64().context("beacon_lr")?,
        };

        let qtable = QparamTable::build(&layer_names, &w_clips, &a_clips);
        Ok(Artifacts {
            dir,
            manifest,
            layer_names,
            model,
            tensors,
            weights,
            w_clips,
            a_clips,
            qtable,
            batch,
            seq_len,
            feat_dim,
            num_classes,
            train,
            val_subsets,
            test,
            baseline,
        })
    }

    /// A hermetic in-memory bundle for serve mode and tests: the paper's
    /// model geometry (Table 4 dims) with tiny deterministic weights,
    /// minimal corpus splits, and calibration clips for every searchable
    /// precision. No files are read or written; paired with
    /// `EvalService::surrogate` it lets the full search/serve stack run
    /// without the Python AOT pipeline. `hlo_path` deliberately errors —
    /// there is no executable to load.
    pub fn synthetic() -> Artifacts {
        let model = ModelDesc::paper();
        let layer_names: Vec<String> = model.layers.iter().map(|l| l.name.clone()).collect();

        // One tiny tensor per layer; values from a splitmix-style stream
        // so the bundle is identical on every build.
        let mut state: u64 = 0x5EED_A27_1F4C5;
        let mut next = move || {
            state = state.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            ((z >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        };
        let mut tensors = Vec::new();
        let mut weights = Vec::new();
        let mut offset = 0usize;
        for name in &layer_names {
            let shape = vec![2usize, 2];
            let data: Vec<f32> = (0..4).map(|_| next()).collect();
            tensors.push(TensorInfo {
                name: format!("{name}_w"),
                shape,
                offset,
                bytes: 16,
            });
            offset += 16;
            weights.push(data);
        }

        let clips = || -> ClipTable {
            layer_names
                .iter()
                .map(|name| {
                    (name.clone(), [2u32, 4, 8, 16, 32].iter().map(|&b| (b, 1.0)).collect())
                })
                .collect()
        };
        let w_clips = clips();
        let a_clips = clips();
        let qtable = QparamTable::build(&layer_names, &w_clips, &a_clips);

        let (batch, seq_len, feat_dim) = (2usize, 4usize, 3usize);
        let split = |num_seqs: usize| Split {
            x: vec![0.0; num_seqs * seq_len * feat_dim],
            y: vec![0; num_seqs * seq_len],
            num_seqs,
        };

        Artifacts {
            dir: PathBuf::from("<synthetic>"),
            manifest: Json::Null,
            layer_names,
            model,
            tensors,
            weights,
            w_clips,
            a_clips,
            qtable,
            batch,
            seq_len,
            feat_dim,
            num_classes: 5,
            train: split(2),
            val_subsets: vec![split(2), split(2)],
            test: split(2),
            baseline: BaselineMetrics {
                val_err_subsets: vec![0.154, 0.156],
                val_err: 0.155,
                test_err: 0.158,
                val_err_16bit: 0.16,
                beacon_lr: 1e-3,
            },
        }
    }

    pub fn hlo_path(&self, which: &str) -> Result<PathBuf> {
        let file = self
            .manifest
            .req("hlo")?
            .req(which)?
            .req("file")?
            .as_str()
            .context("hlo file")?
            .to_string();
        Ok(self.dir.join(file))
    }

    /// Number of HLO inputs for an entry (params + wq/aq + data tensors).
    pub fn hlo_input_count(&self, which: &str) -> Result<usize> {
        Ok(self
            .manifest
            .req("hlo")?
            .req(which)?
            .req("inputs")?
            .as_arr()
            .context("inputs")?
            .len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration: load the real artifact bundle when present (built by
    /// `make artifacts`); skipped otherwise so unit CI stays hermetic.
    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let p = PathBuf::from(dir);
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn loads_bundle_consistently() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts present");
            return;
        };
        let a = Artifacts::load(&dir).unwrap();
        // Geometry invariants.
        assert_eq!(a.layer_names.len(), a.model.num_layers());
        assert!(!a.weights.is_empty());
        for (info, data) in a.tensors.iter().zip(&a.weights) {
            let expect: usize = info.shape.iter().product::<usize>().max(1);
            assert_eq!(data.len(), expect, "tensor {}", info.name);
        }
        // Splits shaped as multiples of the lowered batch.
        assert_eq!(a.test.num_seqs % a.batch, 0);
        for s in &a.val_subsets {
            assert_eq!(s.num_seqs % a.batch, 0);
            assert_eq!(s.x.len(), s.num_seqs * a.seq_len * a.feat_dim);
            assert_eq!(s.y.len(), s.num_seqs * a.seq_len);
        }
        // Labels within range.
        assert!(a.test.y.iter().all(|&l| (l as usize) < a.num_classes));
        // Clips exist for every (layer, searchable bits).
        for name in &a.layer_names {
            for bits in [2u32, 4, 8, 16] {
                assert!(a.w_clips[name].contains_key(&bits), "{name}/{bits}");
                assert!(a.a_clips[name].contains_key(&bits), "{name}/{bits}");
            }
        }
        // Baseline sanity.
        assert!(a.baseline.val_err > 0.0 && a.baseline.val_err < 1.0);
    }
}
