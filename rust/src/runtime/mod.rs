//! Runtime layer: PJRT client/executable wrappers and artifact loading.
//! This is the only module that touches the `xla` crate — everything
//! above it (eval, coordinator) speaks in host slices and QuantConfigs.

pub mod artifacts;
pub mod executor;

pub use artifacts::{Artifacts, BaselineMetrics, Split, TensorInfo};
pub use executor::{scalar_f32, vec_f32, DeviceTensor, Executor, Input, Runtime};
