//! PJRT execution wrappers: load an HLO-text artifact, compile once on the
//! CPU client, execute many times from the search hot path.
//!
//! Two input paths:
//!   * `run_literals` — upload everything per call (simple, used by tests);
//!   * `run_mixed` — static inputs (the 20+ weight tensors) are uploaded
//!     ONCE as device buffers; only the per-call inputs (quant params,
//!     data batch) are fresh. This is the L3 hot-path optimization
//!     recorded in EXPERIMENTS.md §Perf.

use std::path::Path;

use anyhow::{Context, Result};

/// Shared PJRT CPU client (one per process).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn load(&self, hlo_path: impl AsRef<Path>) -> Result<Executor> {
        let path = hlo_path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executor { exe, name: path.display().to_string() })
    }
}

/// A compiled executable. jax lowers with return_tuple=True, so every run
/// returns the decomposed tuple elements.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// Host-side tensor handed to the executor.
pub enum Input<'a> {
    F32(&'a [f32], Vec<i64>),
    I32(&'a [i32], Vec<i64>),
    ScalarF32(f32),
}

impl Input<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Input::F32(data, shape) => xla::Literal::vec1(data).reshape(shape)?,
            Input::I32(data, shape) => xla::Literal::vec1(data).reshape(shape)?,
            Input::ScalarF32(v) => xla::Literal::scalar(*v),
        })
    }
}

/// A device-resident input. PJRT's BufferFromHostLiteral is asynchronous:
/// the transfer may still be reading the host literal after the call
/// returns, so the source literal MUST outlive the buffer — we pin it here.
pub struct DeviceTensor {
    _lit: xla::Literal,
    pub buf: xla::PjRtBuffer,
}

impl Executor {
    /// Execute with host literals; returns the output tuple elements.
    pub fn run_literals(&self, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|i| i.to_literal()).collect::<Result<_>>()?;
        let out = self.exe.execute::<xla::Literal>(&lits)?;
        let result = out[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Upload a host input once; reuse across calls via `run_mixed`.
    pub fn upload(&self, input: &Input) -> Result<DeviceTensor> {
        let lit = input.to_literal()?;
        let device = &self.exe.client().devices()[0];
        let buf = self.exe.client().buffer_from_host_literal(Some(device), &lit)?;
        Ok(DeviceTensor { _lit: lit, buf })
    }

    /// Execute with a mix of pre-uploaded device buffers (`static_bufs`,
    /// occupying the FIRST parameter positions) and fresh host inputs.
    pub fn run_mixed(
        &self,
        static_bufs: &[DeviceTensor],
        fresh: &[Input],
    ) -> Result<Vec<xla::Literal>> {
        let device = &self.exe.client().devices()[0];
        // Keep fresh literals alive until execution has synchronized —
        // the host->device copies may still be in flight during execute_b.
        let fresh_lits: Vec<xla::Literal> =
            fresh.iter().map(|i| i.to_literal()).collect::<Result<_>>()?;
        let fresh_bufs: Vec<xla::PjRtBuffer> = fresh_lits
            .iter()
            .map(|lit| {
                Ok(self.exe.client().buffer_from_host_literal(Some(device), lit)?)
            })
            .collect::<Result<Vec<_>>>()?;
        let mut bufs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(static_bufs.len() + fresh.len());
        bufs.extend(static_bufs.iter().map(|d| &d.buf));
        bufs.extend(fresh_bufs.iter());
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&bufs)?;
        // to_literal_sync blocks on the computation, which in turn waits on
        // the input transfers — after this, dropping fresh_lits is safe.
        let result = out[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Execute with EVERY input already device-resident — the batched eval
    /// hot path: weights, data batches and the candidate's qparam rows are
    /// all uploaded once (outside the per-execution loop), so a run here
    /// moves only the scalar outputs across the host boundary.
    pub fn run_device(&self, bufs: &[&DeviceTensor]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().map(|d| &d.buf).collect();
        let out = self.exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        let result = out[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Extract a scalar f32 from a tuple element.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Extract a full f32 vector from a tuple element.
pub fn vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny HLO via the XlaBuilder, round-trip execution through
    /// both input paths. No artifacts needed — hermetic.
    fn add_mul_computation() -> xla::XlaComputation {
        let b = xla::XlaBuilder::new("t");
        let x = b
            .parameter_s(0, &xla::Shape::array::<f32>(vec![2, 2]), "x")
            .unwrap();
        let y = b
            .parameter_s(1, &xla::Shape::array::<f32>(vec![2, 2]), "y")
            .unwrap();
        let sum = x.add_(&y).unwrap();
        let prod = x.mul_(&y).unwrap();
        let t = b.tuple(&[sum, prod]).unwrap();
        t.build().unwrap()
    }

    #[test]
    fn literal_and_buffer_paths_agree() {
        let rt = Runtime::cpu().unwrap();
        let exe = rt.client.compile(&add_mul_computation()).unwrap();
        let exec = Executor { exe, name: "test".into() };

        let x = [1f32, 2.0, 3.0, 4.0];
        let y = [10f32, 20.0, 30.0, 40.0];
        let inputs = [
            Input::F32(&x, vec![2, 2]),
            Input::F32(&y, vec![2, 2]),
        ];
        let out1 = exec.run_literals(&inputs).unwrap();
        assert_eq!(out1.len(), 2);
        assert_eq!(vec_f32(&out1[0]).unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(vec_f32(&out1[1]).unwrap(), vec![10.0, 40.0, 90.0, 160.0]);

        // Buffer path: x static, y fresh.
        let xbuf = exec.upload(&Input::F32(&x, vec![2, 2])).unwrap();
        let out2 = exec
            .run_mixed(std::slice::from_ref(&xbuf), &[Input::F32(&y, vec![2, 2])])
            .unwrap();
        assert_eq!(vec_f32(&out2[0]).unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(vec_f32(&out2[1]).unwrap(), vec![10.0, 40.0, 90.0, 160.0]);

        // All-device path: both inputs pre-uploaded, nothing fresh.
        let ybuf = exec.upload(&Input::F32(&y, vec![2, 2])).unwrap();
        let out3 = exec.run_device(&[&xbuf, &ybuf]).unwrap();
        assert_eq!(vec_f32(&out3[0]).unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(vec_f32(&out3[1]).unwrap(), vec![10.0, 40.0, 90.0, 160.0]);
    }

    #[test]
    fn scalar_extraction() {
        let rt = Runtime::cpu().unwrap();
        let b = xla::XlaBuilder::new("s");
        let x = b
            .parameter_s(0, &xla::Shape::array::<f32>(vec![]), "x")
            .unwrap();
        let two = x.add_(&x).unwrap();
        let t = b.tuple(&[two]).unwrap();
        let exe = rt.client.compile(&t.build().unwrap()).unwrap();
        let exec = Executor { exe, name: "s".into() };
        let out = exec.run_literals(&[Input::ScalarF32(21.0)]).unwrap();
        assert_eq!(scalar_f32(&out[0]).unwrap(), 42.0);
    }
}
