//! Platform registry: configs and experiment specs name platforms by
//! string, and backends register themselves here — adding a hardware model
//! no longer touches `coordinator/`. SiLago and Bitfusion are registered
//! as built-ins; `examples/custom_platform.rs` shows a third backend
//! registered entirely from user code.
//!
//! A `PlatformSpec` is the serializable half (name + free-form parameter
//! map, round-tripping through the in-tree JSON codec); `resolve` turns it
//! into a live `Arc<dyn Platform + Send + Sync>` via the registered
//! factory.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, OnceLock, RwLock};

use super::{bitfusion::Bitfusion, silago::SiLago, Platform};
use crate::util::json::{Json, JsonError};

/// A platform resolved from the registry: shared, thread-safe, immutable.
pub type SharedPlatform = Arc<dyn Platform + Send + Sync>;

/// Factory building a platform instance from a spec's parameters.
pub type PlatformFactory =
    Arc<dyn Fn(&PlatformSpec) -> Result<SharedPlatform, RegistryError> + Send + Sync>;

/// Serializable platform reference: a registry name plus free-form
/// parameters (e.g. `{"name": "silago", "params": {"sram_mb": 6.0}}`).
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    pub name: String,
    pub params: BTreeMap<String, Json>,
}

impl PlatformSpec {
    pub fn new(name: impl Into<String>) -> PlatformSpec {
        PlatformSpec { name: name.into().to_lowercase(), params: BTreeMap::new() }
    }

    pub fn with_f64(mut self, key: impl Into<String>, value: f64) -> PlatformSpec {
        self.params.insert(key.into(), Json::Num(value));
        self
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.params.get(key).and_then(Json::as_f64)
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(self.name.clone()));
        if !self.params.is_empty() {
            obj.insert("params".to_string(), Json::Obj(self.params.clone()));
        }
        Json::Obj(obj)
    }

    /// Parse from JSON. Accepts the canonical `{"name", "params": {..}}`
    /// shape and, for config-file compatibility, the legacy flat shape
    /// `{"kind": "bitfusion", "sram_mb": 1.5}` (any key besides
    /// `name`/`kind`/`params` is treated as a parameter).
    pub fn from_json(j: &Json) -> Result<PlatformSpec, RegistryError> {
        let obj = j
            .as_obj()
            .ok_or_else(|| RegistryError::Invalid("platform must be a JSON object".into()))?;
        let name = j
            .get("name")
            .or_else(|| j.get("kind"))
            .and_then(Json::as_str)
            .ok_or_else(|| RegistryError::Invalid("platform needs a 'name' field".into()))?;
        let mut spec = PlatformSpec::new(name);
        if let Some(params) = j.get("params").and_then(Json::as_obj) {
            spec.params = params.clone();
        }
        for (k, v) in obj {
            if k != "name" && k != "kind" && k != "params" {
                spec.params.insert(k.clone(), v.clone());
            }
        }
        Ok(spec)
    }

    pub fn from_json_str(text: &str) -> Result<PlatformSpec, RegistryError> {
        let j = Json::parse(text).map_err(RegistryError::from)?;
        PlatformSpec::from_json(&j)
    }
}

/// Errors from registry lookup or platform construction.
#[derive(Debug, Clone)]
pub enum RegistryError {
    /// No factory registered under this name; `known` lists what is.
    Unknown { name: String, known: Vec<String> },
    /// The spec or its parameters were malformed.
    Invalid(String),
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::Unknown { name, known } => write!(
                f,
                "unknown platform '{name}' — registered platforms: {} \
                 (register custom backends via hw::registry::register)",
                known.join(", ")
            ),
            RegistryError::Invalid(msg) => write!(f, "invalid platform spec: {msg}"),
        }
    }
}

impl std::error::Error for RegistryError {}

impl From<JsonError> for RegistryError {
    fn from(e: JsonError) -> RegistryError {
        RegistryError::Invalid(e.to_string())
    }
}

type Registry = RwLock<BTreeMap<String, PlatformFactory>>;

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map: BTreeMap<String, PlatformFactory> = BTreeMap::new();
        map.insert(
            "silago".to_string(),
            Arc::new(|spec: &PlatformSpec| {
                // Experiment 2 default: 6 MB DiMArch scratchpad (§5.3).
                let mb = spec.f64("sram_mb").unwrap_or(6.0);
                Ok(Arc::new(SiLago::new(Some(mb * 1024.0 * 1024.0))) as SharedPlatform)
            }),
        );
        map.insert(
            "bitfusion".to_string(),
            Arc::new(|spec: &PlatformSpec| {
                // Experiment 3 default: 2 MB SRAM (§5.4).
                let mb = spec.f64("sram_mb").unwrap_or(2.0);
                Ok(Arc::new(Bitfusion::new(Some(mb * 1024.0 * 1024.0))) as SharedPlatform)
            }),
        );
        RwLock::new(map)
    })
}

/// Register (or replace) a platform factory under `name`. Names are
/// case-insensitive.
pub fn register<F>(name: &str, factory: F)
where
    F: Fn(&PlatformSpec) -> Result<SharedPlatform, RegistryError> + Send + Sync + 'static,
{
    registry()
        .write()
        .expect("platform registry poisoned")
        .insert(name.to_lowercase(), Arc::new(factory));
}

/// Resolve a spec into a live platform, or a helpful error naming the
/// registered platforms. A platform whose `supported_bits()` is empty is
/// rejected HERE, at the registry boundary — the coordinator derives the
/// genome's lower bound from that list and used to panic mid-search
/// (`min().unwrap()` on the empty iterator) when a custom backend
/// declared no precisions.
pub fn resolve(spec: &PlatformSpec) -> Result<SharedPlatform, RegistryError> {
    let factory = {
        let map = registry().read().expect("platform registry poisoned");
        map.get(&spec.name.to_lowercase()).cloned()
    };
    match factory {
        Some(f) => {
            let platform = f(spec)?;
            if platform.supported_bits().is_empty() {
                return Err(RegistryError::Invalid(format!(
                    "platform '{}' declares no supported precisions \
                     (supported_bits() is empty); a search over it cannot \
                     derive a genome range",
                    spec.name
                )));
            }
            Ok(platform)
        }
        None => Err(RegistryError::Unknown { name: spec.name.clone(), known: known_platforms() }),
    }
}

/// Names currently registered, sorted.
pub fn known_platforms() -> Vec<String> {
    registry()
        .read()
        .expect("platform registry poisoned")
        .keys()
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelDesc;
    use crate::quant::{Bits, QuantConfig};

    #[test]
    fn builtins_resolve_with_default_and_custom_sram() {
        let p = resolve(&PlatformSpec::new("silago")).unwrap();
        assert_eq!(p.name(), "SiLago");
        assert_eq!(p.sram_bytes(), Some(6.0 * 1024.0 * 1024.0));
        assert!(p.tied_wa());

        let p = resolve(&PlatformSpec::new("Bitfusion").with_f64("sram_mb", 1.5)).unwrap();
        assert_eq!(p.name(), "Bitfusion");
        assert_eq!(p.sram_bytes(), Some(1.5 * 1024.0 * 1024.0));
        assert!(!p.has_energy_model());
    }

    #[test]
    fn unknown_platform_lists_known_names() {
        let err = resolve(&PlatformSpec::new("tpu")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown platform 'tpu'"), "{msg}");
        assert!(msg.contains("silago") && msg.contains("bitfusion"), "{msg}");
    }

    #[test]
    fn custom_registration_from_outside() {
        struct Flat;
        impl Platform for Flat {
            fn name(&self) -> &str {
                "flat-test"
            }
            fn supported_bits(&self) -> &[Bits] {
                &Bits::SEARCHABLE
            }
            fn tied_wa(&self) -> bool {
                false
            }
            fn speedup(&self, m: &ModelDesc, qc: &QuantConfig) -> f64 {
                super::super::eq4_speedup(m, qc, |_, _| 2.0)
            }
            fn energy_pj(&self, _: &ModelDesc, _: &QuantConfig) -> Option<f64> {
                None
            }
            fn sram_bytes(&self) -> Option<f64> {
                None
            }
        }
        register("flat-test", |_| Ok(Arc::new(Flat)));
        let p = resolve(&PlatformSpec::new("flat-test")).unwrap();
        assert_eq!(p.name(), "flat-test");
        assert!(known_platforms().contains(&"flat-test".to_string()));
    }

    #[test]
    fn empty_bits_platform_rejected_at_resolve_time() {
        // Regression: a registered platform with an empty supported_bits
        // list used to resolve fine and then panic mid-search when the
        // session derived the genome lower bound (min().unwrap() on an
        // empty iterator). It must be rejected at the registry boundary.
        struct NoBits;
        impl Platform for NoBits {
            fn name(&self) -> &str {
                "no-bits"
            }
            fn supported_bits(&self) -> &[Bits] {
                &[]
            }
            fn tied_wa(&self) -> bool {
                false
            }
            fn speedup(&self, _: &ModelDesc, _: &QuantConfig) -> f64 {
                1.0
            }
            fn energy_pj(&self, _: &ModelDesc, _: &QuantConfig) -> Option<f64> {
                None
            }
            fn sram_bytes(&self) -> Option<f64> {
                None
            }
        }
        register("no-bits", |_| Ok(Arc::new(NoBits)));
        let err = resolve(&PlatformSpec::new("no-bits")).unwrap_err();
        assert!(matches!(err, RegistryError::Invalid(_)), "{err:?}");
        assert!(err.to_string().contains("no supported precisions"), "{err}");
    }

    #[test]
    fn spec_json_roundtrip_and_legacy_shape() {
        let spec = PlatformSpec::new("silago").with_f64("sram_mb", 4.5);
        let back = PlatformSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);

        let legacy =
            PlatformSpec::from_json_str(r#"{"kind": "bitfusion", "sram_mb": 1.5}"#).unwrap();
        assert_eq!(legacy.name, "bitfusion");
        assert_eq!(legacy.f64("sram_mb"), Some(1.5));
    }
}
