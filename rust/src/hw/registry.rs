//! Platform registry: configs and experiment specs name platforms by
//! string, and backends register themselves here — adding a hardware model
//! no longer touches `coordinator/`. SiLago and Bitfusion are registered
//! as built-ins; `examples/custom_platform.rs` shows a third backend
//! registered entirely from user code.
//!
//! A `PlatformSpec` is the serializable half (name + free-form parameter
//! map, round-tripping through the in-tree JSON codec); `resolve` turns it
//! into a live `Arc<dyn Platform + Send + Sync>` via the registered
//! factory.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::sync::{Arc, OnceLock, RwLock};

use super::manifest::{ManifestError, PlatformManifest};
use super::tabular::TabularPlatform;
use super::{bitfusion::Bitfusion, silago::SiLago, Platform};
use crate::util::json::{Json, JsonError};

/// A platform resolved from the registry: shared, thread-safe, immutable.
pub type SharedPlatform = Arc<dyn Platform + Send + Sync>;

/// Factory building a platform instance from a spec's parameters.
pub type PlatformFactory =
    Arc<dyn Fn(&PlatformSpec) -> Result<SharedPlatform, RegistryError> + Send + Sync>;

/// Serializable platform reference: a registry name plus free-form
/// parameters (e.g. `{"name": "silago", "params": {"sram_mb": 6.0}}`).
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    pub name: String,
    pub params: BTreeMap<String, Json>,
}

impl PlatformSpec {
    pub fn new(name: impl Into<String>) -> PlatformSpec {
        PlatformSpec { name: name.into().to_lowercase(), params: BTreeMap::new() }
    }

    pub fn with_f64(mut self, key: impl Into<String>, value: f64) -> PlatformSpec {
        self.params.insert(key.into(), Json::Num(value));
        self
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.params.get(key).and_then(Json::as_f64)
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(self.name.clone()));
        if !self.params.is_empty() {
            obj.insert("params".to_string(), Json::Obj(self.params.clone()));
        }
        Json::Obj(obj)
    }

    /// Parse from JSON. Accepts the canonical `{"name", "params": {..}}`
    /// shape and, for config-file compatibility, the legacy flat shape
    /// `{"kind": "bitfusion", "sram_mb": 1.5}` (any key besides
    /// `name`/`kind`/`params` is treated as a parameter).
    pub fn from_json(j: &Json) -> Result<PlatformSpec, RegistryError> {
        let obj = j
            .as_obj()
            .ok_or_else(|| RegistryError::Invalid("platform must be a JSON object".into()))?;
        let name = j
            .get("name")
            .or_else(|| j.get("kind"))
            .and_then(Json::as_str)
            .ok_or_else(|| RegistryError::Invalid("platform needs a 'name' field".into()))?;
        let mut spec = PlatformSpec::new(name);
        if let Some(params) = j.get("params").and_then(Json::as_obj) {
            spec.params = params.clone();
        }
        for (k, v) in obj {
            if k != "name" && k != "kind" && k != "params" {
                spec.params.insert(k.clone(), v.clone());
            }
        }
        Ok(spec)
    }

    pub fn from_json_str(text: &str) -> Result<PlatformSpec, RegistryError> {
        let j = Json::parse(text).map_err(RegistryError::from)?;
        PlatformSpec::from_json(&j)
    }
}

/// Errors from registry lookup or platform construction.
#[derive(Debug, Clone)]
pub enum RegistryError {
    /// No factory registered under this name; `known` lists what is.
    Unknown { name: String, known: Vec<String> },
    /// The spec or its parameters were malformed.
    Invalid(String),
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::Unknown { name, known } => write!(
                f,
                "unknown platform '{name}' — registered platforms: {} \
                 (register custom backends via hw::registry::register)",
                known.join(", ")
            ),
            RegistryError::Invalid(msg) => write!(f, "invalid platform spec: {msg}"),
        }
    }
}

impl std::error::Error for RegistryError {}

impl From<JsonError> for RegistryError {
    fn from(e: JsonError) -> RegistryError {
        RegistryError::Invalid(e.to_string())
    }
}

/// Where a registry entry came from — surfaced by
/// [`known_platforms_with_sources`] so `mohaq platforms` and serve-mode
/// discovery can tell tenants which names are data-driven.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlatformSource {
    /// Compiled into the binary (`hw::silago`, `hw::bitfusion`).
    Builtin,
    /// Registered from Rust via [`register`].
    Custom,
    /// Loaded from a [`PlatformManifest`] (file or `register_manifest`).
    Manifest,
}

impl fmt::Display for PlatformSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PlatformSource::Builtin => "builtin",
            PlatformSource::Custom => "custom",
            PlatformSource::Manifest => "manifest",
        })
    }
}

struct Entry {
    factory: PlatformFactory,
    source: PlatformSource,
    /// Present iff `source == Manifest`; kept for idempotence checks
    /// (re-registering the identical manifest is a no-op, a *different*
    /// manifest under the same name is a collision) and discovery.
    manifest: Option<PlatformManifest>,
}

type Registry = RwLock<BTreeMap<String, Entry>>;

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        let mut map: BTreeMap<String, Entry> = BTreeMap::new();
        map.insert(
            "silago".to_string(),
            Entry {
                factory: Arc::new(|spec: &PlatformSpec| {
                    // Experiment 2 default: 6 MB DiMArch scratchpad (§5.3).
                    let mb = spec.f64("sram_mb").unwrap_or(6.0);
                    Ok(Arc::new(SiLago::new(Some(mb * 1024.0 * 1024.0))) as SharedPlatform)
                }),
                source: PlatformSource::Builtin,
                manifest: None,
            },
        );
        map.insert(
            "bitfusion".to_string(),
            Entry {
                factory: Arc::new(|spec: &PlatformSpec| {
                    // Experiment 3 default: 2 MB SRAM (§5.4).
                    let mb = spec.f64("sram_mb").unwrap_or(2.0);
                    Ok(Arc::new(Bitfusion::new(Some(mb * 1024.0 * 1024.0))) as SharedPlatform)
                }),
                source: PlatformSource::Builtin,
                manifest: None,
            },
        );
        RwLock::new(map)
    })
}

/// Register (or replace) a platform factory under `name`. Names are
/// case-insensitive.
pub fn register<F>(name: &str, factory: F)
where
    F: Fn(&PlatformSpec) -> Result<SharedPlatform, RegistryError> + Send + Sync + 'static,
{
    registry().write().expect("platform registry poisoned").insert(
        name.to_lowercase(),
        Entry { factory: Arc::new(factory), source: PlatformSource::Custom, manifest: None },
    );
}

/// The factory a registered manifest resolves through: a
/// [`TabularPlatform`] rebuilt per spec so the spec-level `sram_mb`
/// override keeps the built-ins' semantics.
fn manifest_factory(m: PlatformManifest) -> PlatformFactory {
    Arc::new(move |spec: &PlatformSpec| {
        let platform =
            TabularPlatform::from_manifest(&m).map_err(|e| RegistryError::Invalid(e.to_string()))?;
        Ok(Arc::new(match spec.f64("sram_mb") {
            Some(mb) => platform.with_sram_mb(Some(mb)),
            None => platform,
        }) as SharedPlatform)
    })
}

/// Register a validated manifest as a resolvable platform.
///
/// Collision rules: a name held by a built-in or Rust-registered
/// platform is never shadowed by data ([`ManifestError::Collision`]);
/// re-registering the *identical* manifest is an idempotent no-op (the
/// registry is process-global, so startup dirs and tests load the same
/// files repeatedly); a *different* manifest under a taken name is a
/// collision.
pub fn register_manifest(m: &PlatformManifest) -> Result<(), ManifestError> {
    m.validate()?;
    let mut map = registry().write().expect("platform registry poisoned");
    match map.get(&m.name) {
        Some(existing) if existing.manifest.as_ref() == Some(m) => Ok(()),
        Some(existing) => Err(ManifestError::Collision {
            name: m.name.clone(),
            existing: existing.source.to_string(),
        }),
        None => {
            map.insert(
                m.name.clone(),
                Entry {
                    factory: manifest_factory(m.clone()),
                    source: PlatformSource::Manifest,
                    manifest: Some(m.clone()),
                },
            );
            Ok(())
        }
    }
}

/// Load every `*.json` manifest in `dir` (sorted by file name, so
/// registration order — and any collision reported — is deterministic)
/// and register each. Returns the registered names in load order.
pub fn load_manifest_dir(dir: impl AsRef<Path>) -> Result<Vec<String>, ManifestError> {
    let dir = dir.as_ref();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| ManifestError::Io(format!("{}: {e}", dir.display())))?;
    let mut paths: Vec<std::path::PathBuf> = entries
        .filter_map(Result::ok)
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|ext| ext == "json"))
        .collect();
    paths.sort();
    let mut names = Vec::with_capacity(paths.len());
    for path in paths {
        let m = PlatformManifest::load_file(&path)?;
        register_manifest(&m)?;
        names.push(m.name);
    }
    Ok(names)
}

/// Resolve a spec into a live platform, or a helpful error naming the
/// registered platforms. A platform whose `supported_bits()` is empty is
/// rejected HERE, at the registry boundary — the coordinator derives the
/// genome's lower bound from that list and used to panic mid-search
/// (`min().unwrap()` on the empty iterator) when a custom backend
/// declared no precisions.
pub fn resolve(spec: &PlatformSpec) -> Result<SharedPlatform, RegistryError> {
    // An inline manifest (spec param "manifest") resolves without
    // touching the global registry — this is how serve-mode tenant
    // manifests and manifest-carrying config files stay scoped to their
    // own spec. It may not shadow a built-in or Rust-registered name; it
    // MAY coincide with a globally registered manifest (the inline copy
    // wins for this spec, which keeps a tenant's view self-contained).
    if let Some(mj) = spec.params.get("manifest") {
        let m = PlatformManifest::from_json(mj)
            .map_err(|e| RegistryError::Invalid(format!("inline manifest: {e}")))?;
        if m.name != spec.name.to_lowercase() {
            return Err(RegistryError::Invalid(format!(
                "inline manifest names '{}' but the platform entry is '{}'",
                m.name, spec.name
            )));
        }
        if let Some(source) = source_of(&m.name) {
            if source != PlatformSource::Manifest {
                return Err(RegistryError::Invalid(
                    ManifestError::Collision { name: m.name, existing: source.to_string() }
                        .to_string(),
                ));
            }
        }
        let platform = TabularPlatform::from_manifest(&m)
            .map_err(|e| RegistryError::Invalid(format!("inline manifest: {e}")))?;
        return Ok(Arc::new(match spec.f64("sram_mb") {
            Some(mb) => platform.with_sram_mb(Some(mb)),
            None => platform,
        }) as SharedPlatform);
    }

    let factory = {
        let map = registry().read().expect("platform registry poisoned");
        map.get(&spec.name.to_lowercase()).map(|e| e.factory.clone())
    };
    match factory {
        Some(f) => {
            let platform = f(spec)?;
            if platform.supported_bits().is_empty() {
                return Err(RegistryError::Invalid(format!(
                    "platform '{}' declares no supported precisions \
                     (supported_bits() is empty); a search over it cannot \
                     derive a genome range",
                    spec.name
                )));
            }
            Ok(platform)
        }
        None => Err(RegistryError::Unknown { name: spec.name.clone(), known: known_platforms() }),
    }
}

/// Names currently registered, sorted (BTreeMap key order — the listing
/// is deterministic however registration interleaved).
pub fn known_platforms() -> Vec<String> {
    registry()
        .read()
        .expect("platform registry poisoned")
        .keys()
        .cloned()
        .collect()
}

/// Sorted `(name, source)` pairs — the discovery listing behind
/// `mohaq platforms` and the serve-mode `platforms` request.
pub fn known_platforms_with_sources() -> Vec<(String, PlatformSource)> {
    registry()
        .read()
        .expect("platform registry poisoned")
        .iter()
        .map(|(name, entry)| (name.clone(), entry.source))
        .collect()
}

/// The source of a registered name, if any.
pub fn source_of(name: &str) -> Option<PlatformSource> {
    registry()
        .read()
        .expect("platform registry poisoned")
        .get(&name.to_lowercase())
        .map(|e| e.source)
}

/// The manifest registered under `name`, if that entry is data-driven.
pub fn manifest_of(name: &str) -> Option<PlatformManifest> {
    registry()
        .read()
        .expect("platform registry poisoned")
        .get(&name.to_lowercase())
        .and_then(|e| e.manifest.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelDesc;
    use crate::quant::{Bits, QuantConfig};

    #[test]
    fn builtins_resolve_with_default_and_custom_sram() {
        let p = resolve(&PlatformSpec::new("silago")).unwrap();
        assert_eq!(p.name(), "SiLago");
        assert_eq!(p.sram_bytes(), Some(6.0 * 1024.0 * 1024.0));
        assert!(p.tied_wa());

        let p = resolve(&PlatformSpec::new("Bitfusion").with_f64("sram_mb", 1.5)).unwrap();
        assert_eq!(p.name(), "Bitfusion");
        assert_eq!(p.sram_bytes(), Some(1.5 * 1024.0 * 1024.0));
        assert!(!p.has_energy_model());
    }

    #[test]
    fn unknown_platform_lists_known_names() {
        let err = resolve(&PlatformSpec::new("tpu")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown platform 'tpu'"), "{msg}");
        assert!(msg.contains("silago") && msg.contains("bitfusion"), "{msg}");
    }

    #[test]
    fn custom_registration_from_outside() {
        struct Flat;
        impl Platform for Flat {
            fn name(&self) -> &str {
                "flat-test"
            }
            fn supported_bits(&self) -> &[Bits] {
                &Bits::SEARCHABLE
            }
            fn tied_wa(&self) -> bool {
                false
            }
            fn speedup(&self, m: &ModelDesc, qc: &QuantConfig) -> f64 {
                super::super::eq4_speedup(m, qc, |_, _| 2.0)
            }
            fn energy_pj(&self, _: &ModelDesc, _: &QuantConfig) -> Option<f64> {
                None
            }
            fn sram_bytes(&self) -> Option<f64> {
                None
            }
        }
        register("flat-test", |_| Ok(Arc::new(Flat)));
        let p = resolve(&PlatformSpec::new("flat-test")).unwrap();
        assert_eq!(p.name(), "flat-test");
        assert!(known_platforms().contains(&"flat-test".to_string()));
    }

    #[test]
    fn empty_bits_platform_rejected_at_resolve_time() {
        // Regression: a registered platform with an empty supported_bits
        // list used to resolve fine and then panic mid-search when the
        // session derived the genome lower bound (min().unwrap() on an
        // empty iterator). It must be rejected at the registry boundary.
        struct NoBits;
        impl Platform for NoBits {
            fn name(&self) -> &str {
                "no-bits"
            }
            fn supported_bits(&self) -> &[Bits] {
                &[]
            }
            fn tied_wa(&self) -> bool {
                false
            }
            fn speedup(&self, _: &ModelDesc, _: &QuantConfig) -> f64 {
                1.0
            }
            fn energy_pj(&self, _: &ModelDesc, _: &QuantConfig) -> Option<f64> {
                None
            }
            fn sram_bytes(&self) -> Option<f64> {
                None
            }
        }
        register("no-bits", |_| Ok(Arc::new(NoBits)));
        let err = resolve(&PlatformSpec::new("no-bits")).unwrap_err();
        assert!(matches!(err, RegistryError::Invalid(_)), "{err:?}");
        assert!(err.to_string().contains("no supported precisions"), "{err}");
    }

    #[test]
    fn spec_json_roundtrip_and_legacy_shape() {
        let spec = PlatformSpec::new("silago").with_f64("sram_mb", 4.5);
        let back = PlatformSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);

        let legacy =
            PlatformSpec::from_json_str(r#"{"kind": "bitfusion", "sram_mb": 1.5}"#).unwrap();
        assert_eq!(legacy.name, "bitfusion");
        assert_eq!(legacy.f64("sram_mb"), Some(1.5));
    }

    fn test_manifest(name: &str) -> PlatformManifest {
        let text = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/platforms/silago_lut.json"
        ))
        .unwrap();
        let mut m = PlatformManifest::from_json_str(&text).unwrap();
        m.name = name.to_string();
        m
    }

    #[test]
    fn register_manifest_is_idempotent_but_rejects_collisions() {
        let m = test_manifest("reg-manifest-test");
        register_manifest(&m).unwrap();
        // Identical re-registration: no-op (the registry is process-global
        // and manifest dirs get re-loaded by every entry point).
        register_manifest(&m).unwrap();
        assert_eq!(source_of("reg-manifest-test"), Some(PlatformSource::Manifest));
        assert_eq!(manifest_of("reg-manifest-test"), Some(m.clone()));

        // A DIFFERENT manifest under the same name is a collision.
        let mut other = m.clone();
        other.sram_mb = Some(1.0);
        let err = register_manifest(&other).unwrap_err();
        assert!(matches!(err, ManifestError::Collision { .. }), "{err:?}");

        // Built-in names are never shadowed by data.
        let mut shadow = m;
        shadow.name = "silago".into();
        let err = register_manifest(&shadow).unwrap_err();
        assert!(err.to_string().contains("builtin"), "{err}");

        // The resolved platform honors the spec-level sram override.
        let p = resolve(&PlatformSpec::new("reg-manifest-test")).unwrap();
        assert_eq!(p.sram_bytes(), Some(6.0 * 1024.0 * 1024.0));
        let p = resolve(&PlatformSpec::new("reg-manifest-test").with_f64("sram_mb", 2.0)).unwrap();
        assert_eq!(p.sram_bytes(), Some(2.0 * 1024.0 * 1024.0));
    }

    #[test]
    fn listing_is_sorted_and_carries_sources() {
        register_manifest(&test_manifest("zz-listing-test")).unwrap();
        let listed = known_platforms_with_sources();
        let names: Vec<&String> = listed.iter().map(|(n, _)| n).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "listing must be deterministic (sorted)");
        assert!(listed
            .iter()
            .any(|(n, s)| n == "silago" && *s == PlatformSource::Builtin));
        assert!(listed
            .iter()
            .any(|(n, s)| n == "zz-listing-test" && *s == PlatformSource::Manifest));
        assert!(known_platforms().contains(&"zz-listing-test".to_string()));
    }

    #[test]
    fn inline_manifest_resolves_without_registration() {
        let m = test_manifest("inline-only-test");
        let mut spec = PlatformSpec::new("inline-only-test");
        spec.params.insert("manifest".into(), m.to_json());
        let p = resolve(&spec).unwrap();
        assert_eq!(p.name(), "inline-only-test");
        assert!(p.tied_wa());
        // The name never reached the global registry.
        assert_eq!(source_of("inline-only-test"), None);

        // Name mismatch between entry and manifest is rejected.
        let mut wrong = PlatformSpec::new("other-name");
        wrong.params.insert("manifest".into(), m.to_json());
        let err = resolve(&wrong).unwrap_err();
        assert!(err.to_string().contains("names"), "{err}");

        // Inline manifests may not shadow built-ins.
        let mut shadow_m = test_manifest("silago");
        shadow_m.name = "silago".into();
        let mut shadow = PlatformSpec::new("silago");
        shadow.params.insert("manifest".into(), shadow_m.to_json());
        let err = resolve(&shadow).unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err}");

        // Inline + sram_mb override compose.
        let mut with_sram = PlatformSpec::new("inline-only-test").with_f64("sram_mb", 3.0);
        with_sram.params.insert("manifest".into(), m.to_json());
        let p = resolve(&with_sram).unwrap();
        assert_eq!(p.sram_bytes(), Some(3.0 * 1024.0 * 1024.0));

        // A malformed inline manifest is an Invalid error, not a panic.
        let mut bad = PlatformSpec::new("inline-only-test");
        bad.params.insert("manifest".into(), Json::Str("not an object".into()));
        let err = resolve(&bad).unwrap_err();
        assert!(matches!(err, RegistryError::Invalid(_)), "{err:?}");
    }

    #[test]
    fn load_manifest_dir_registers_checked_in_platforms() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/platforms");
        let names = load_manifest_dir(dir).unwrap();
        assert_eq!(names, ["bitfusion_lut", "silago_lut"], "sorted by file name");
        // Idempotent on re-load.
        assert_eq!(load_manifest_dir(dir).unwrap(), names);
        assert_eq!(source_of("silago_lut"), Some(PlatformSource::Manifest));
        let p = resolve(&PlatformSpec::new("bitfusion_lut")).unwrap();
        assert!(!p.tied_wa());
        // Missing directory is a typed Io error.
        let err = load_manifest_dir("/nonexistent-manifest-dir").unwrap_err();
        assert!(matches!(err, ManifestError::Io(_)), "{err:?}");
    }
}
