//! Table-driven `Platform` backend: Eq. 3/Eq. 4 scoring where the
//! per-precision MAC costs come from a [`PlatformManifest`]'s lookup
//! tables instead of Rust code (HAQ-style latency tables). Because this
//! routes through the SAME `eq4_speedup`/`eq3_energy_pj` free functions
//! as the built-ins, a manifest transcribing a built-in's tables scores
//! every candidate to the identical f64 — the manifest-vs-builtin
//! bitwise-front invariant rests on that.

use std::collections::BTreeMap;

use super::manifest::{ManifestError, PlatformManifest};
use super::{eq3_energy_pj, eq4_speedup, Platform};
use crate::model::ModelDesc;
use crate::quant::{Bits, QuantConfig};

#[derive(Debug, Clone)]
struct EnergyTables {
    bit_load_pj: f64,
    fixed_op_pj: f64,
    mac_pj: BTreeMap<(u32, u32), f64>,
    /// Conservative fallback for an off-table pair (see `mac_energy`).
    max_mac_pj: f64,
}

/// A live platform backed entirely by manifest tables.
#[derive(Debug, Clone)]
pub struct TabularPlatform {
    name: String,
    tied: bool,
    bits: Vec<Bits>,
    speedup: BTreeMap<(u32, u32), f64>,
    energy: Option<EnergyTables>,
    sram_bytes: Option<f64>,
}

impl TabularPlatform {
    /// Build from a manifest, re-validating it (hand-assembled manifests
    /// get the same strictness as loaded ones).
    pub fn from_manifest(m: &PlatformManifest) -> Result<TabularPlatform, ManifestError> {
        m.validate()?;
        Ok(TabularPlatform {
            name: m.name.clone(),
            tied: m.tied_wa,
            bits: m.supported_bits.clone(),
            speedup: m.speedup.clone(),
            energy: m.energy.as_ref().map(|e| EnergyTables {
                bit_load_pj: e.bit_load_pj,
                fixed_op_pj: e.fixed_op_pj,
                mac_pj: e.mac_pj.clone(),
                max_mac_pj: e.mac_pj.values().cloned().fold(0.0, f64::max),
            }),
            sram_bytes: m.sram_mb.map(|mb| mb * 1024.0 * 1024.0),
        })
    }

    /// Override the SRAM capacity (the spec-level `sram_mb` parameter,
    /// same semantics as the built-ins' factories).
    pub fn with_sram_mb(mut self, mb: Option<f64>) -> TabularPlatform {
        self.sram_bytes = mb.map(|mb| mb * 1024.0 * 1024.0);
        self
    }

    /// Per-op speedup for a precision pair. Validation guarantees the
    /// table covers every pair a genome over `supported_bits` can
    /// produce, so a miss only happens for configs the search would
    /// never emit (e.g. a driver scoring a hand-built 2-bit config on a
    /// {4,8,16} platform); fall back to the 1.0 baseline rather than
    /// panic.
    fn mac_speedup(&self, w: Bits, a: Bits) -> f64 {
        let (w, a) = self.effective_pair(w, a);
        self.speedup.get(&(w, a)).copied().unwrap_or(1.0)
    }

    /// Same contract as `mac_speedup`; the off-table fallback is the
    /// most expensive MAC in the table (conservative for an energy
    /// objective being minimized).
    fn mac_energy(&self, e: &EnergyTables, w: Bits, a: Bits) -> f64 {
        let (w, a) = self.effective_pair(w, a);
        e.mac_pj.get(&(w, a)).copied().unwrap_or(e.max_mac_pj)
    }

    /// A tied-W=A platform runs the whole layer at the weight precision
    /// (the built-in SiLago model indexes its tables by W alone), so a
    /// mixed pair degrades to the diagonal entry.
    fn effective_pair(&self, w: Bits, a: Bits) -> (u32, u32) {
        if self.tied {
            (w.bits(), w.bits())
        } else {
            (w.bits(), a.bits())
        }
    }
}

impl Platform for TabularPlatform {
    fn name(&self) -> &str {
        &self.name
    }

    fn supported_bits(&self) -> &[Bits] {
        &self.bits
    }

    fn tied_wa(&self) -> bool {
        self.tied
    }

    fn has_energy_model(&self) -> bool {
        self.energy.is_some()
    }

    fn speedup(&self, model: &ModelDesc, qc: &QuantConfig) -> f64 {
        eq4_speedup(model, qc, |w, a| self.mac_speedup(w, a))
    }

    fn energy_pj(&self, model: &ModelDesc, qc: &QuantConfig) -> Option<f64> {
        self.energy.as_ref().map(|e| {
            eq3_energy_pj(model, qc, e.bit_load_pj, |w, a| self.mac_energy(e, w, a), e.fixed_op_pj)
        })
    }

    fn sram_bytes(&self) -> Option<f64> {
        self.sram_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{bitfusion::Bitfusion, silago::SiLago};

    fn load(file: &str) -> PlatformManifest {
        let path = format!("{}/platforms/{file}", env!("CARGO_MANIFEST_DIR"));
        PlatformManifest::load_file(path).unwrap()
    }

    /// Every tied config over {4,8,16} on the paper model.
    fn tied_configs(model: &ModelDesc) -> Vec<QuantConfig> {
        let layers = model.layers.len();
        let choices = [Bits::B4, Bits::B8, Bits::B16];
        // Enumerate a deterministic spread rather than the full 3^L grid:
        // uniform configs plus rotations mixing all three precisions.
        let mut configs: Vec<QuantConfig> = choices
            .iter()
            .map(|b| QuantConfig::uniform(layers, *b, *b))
            .collect();
        for offset in 0..3 {
            let w: Vec<Bits> = (0..layers).map(|i| choices[(i + offset) % 3]).collect();
            configs.push(QuantConfig { w_bits: w.clone(), a_bits: w });
        }
        configs
    }

    #[test]
    fn silago_manifest_scores_bitwise_like_builtin() {
        let p = TabularPlatform::from_manifest(&load("silago_lut.json")).unwrap();
        let builtin = SiLago::paper_experiment();
        let model = ModelDesc::paper();
        assert_eq!(p.sram_bytes(), builtin.sram_bytes());
        assert!(p.tied_wa());
        assert!(p.has_energy_model());
        for qc in tied_configs(&model) {
            assert_eq!(
                p.speedup(&model, &qc).to_bits(),
                builtin.speedup(&model, &qc).to_bits(),
                "speedup diverged on {qc:?}"
            );
            assert_eq!(
                p.energy_pj(&model, &qc).unwrap().to_bits(),
                builtin.energy_pj(&model, &qc).unwrap().to_bits(),
                "energy diverged on {qc:?}"
            );
        }
    }

    #[test]
    fn bitfusion_manifest_scores_bitwise_like_builtin() {
        let p = TabularPlatform::from_manifest(&load("bitfusion_lut.json")).unwrap();
        let builtin = Bitfusion::paper_experiment();
        let model = ModelDesc::paper();
        assert_eq!(p.sram_bytes(), builtin.sram_bytes());
        assert!(!p.tied_wa());
        assert_eq!(p.energy_pj(&model, &QuantConfig::uniform(model.layers.len(), Bits::B8, Bits::B8)), None);
        let layers = model.layers.len();
        let mut configs = Vec::new();
        for w in Bits::SEARCHABLE {
            for a in Bits::SEARCHABLE {
                configs.push(QuantConfig::uniform(layers, w, a));
            }
        }
        for offset in 0..4 {
            let w: Vec<Bits> =
                (0..layers).map(|i| Bits::SEARCHABLE[(i + offset) % 4]).collect();
            let a: Vec<Bits> =
                (0..layers).map(|i| Bits::SEARCHABLE[(i + offset + 1) % 4]).collect();
            configs.push(QuantConfig { w_bits: w, a_bits: a });
        }
        for qc in configs {
            assert_eq!(
                p.speedup(&model, &qc).to_bits(),
                builtin.speedup(&model, &qc).to_bits(),
                "speedup diverged on {qc:?}"
            );
        }
    }

    #[test]
    fn sram_override_matches_builtin_convention() {
        let p = TabularPlatform::from_manifest(&load("silago_lut.json"))
            .unwrap()
            .with_sram_mb(Some(1.5));
        assert_eq!(p.sram_bytes(), Some(1.5 * 1024.0 * 1024.0));
    }

    #[test]
    fn off_table_lookups_fall_back_instead_of_panicking() {
        let p = TabularPlatform::from_manifest(&load("silago_lut.json")).unwrap();
        let model = ModelDesc::paper();
        // 2-bit is outside the manifest's {4,8,16} grid.
        let qc = QuantConfig::uniform(model.layers.len(), Bits::B2, Bits::B2);
        assert!(p.speedup(&model, &qc).is_finite());
        assert!(p.energy_pj(&model, &qc).unwrap().is_finite());
    }
}
