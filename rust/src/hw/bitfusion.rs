//! Bitfusion model (paper §2.5.2; Sharma et al. 2017).
//!
//! A Fused-PE groups 16 bit-bricks; each brick multiplies 1- or 2-bit
//! operands. An (w x a) MAC consumes (w/2)*(a/2) bricks (operands below
//! 2 bits round up to one brick), so per-cycle parallelism is
//! 16 / (bricks per op). 16-bit operands are processed as 8-bit halves
//! over two cycles each. Relative to the 16x16 baseline this gives the
//! paper's headline: 2-bit ops are 64x faster than 16-bit ops.

use super::{eq4_speedup, Platform};
use crate::model::ModelDesc;
use crate::quant::{Bits, QuantConfig};

#[derive(Debug, Clone)]
pub struct Bitfusion {
    /// Experiment 3 constrains the SRAM to 2 MB (§5.4).
    pub sram_bytes: Option<f64>,
}

/// Bit-bricks consumed by one operand lane (min one brick => 2-bit lanes).
fn brick_width(bits: Bits) -> f64 {
    (bits.bits().max(2).min(8) as f64) / 2.0
}

/// Extra cycles for 16-bit operands (8-bit halves over 2 cycles).
fn cycle_factor(bits: Bits) -> f64 {
    if bits.bits() >= 16 {
        2.0
    } else {
        1.0
    }
}

/// Throughput of a (w x a) MAC relative to a 16x16 MAC.
/// T(2,2) = 64, T(8,8) = 4, T(16,16) = 1 — the paper's §2.5.2 anchors.
pub fn mac_speedup(w: Bits, a: Bits) -> f64 {
    64.0 / (brick_width(w) * brick_width(a) * cycle_factor(w) * cycle_factor(a))
}

impl Bitfusion {
    pub fn new(sram_bytes: Option<f64>) -> Self {
        Bitfusion { sram_bytes }
    }

    /// The §5.4 configuration: 2 MB SRAM (10.6x compression needed).
    pub fn paper_experiment() -> Self {
        Bitfusion { sram_bytes: Some(2.0 * 1024.0 * 1024.0) }
    }
}

impl Platform for Bitfusion {
    fn name(&self) -> &str {
        "Bitfusion"
    }

    fn supported_bits(&self) -> &[Bits] {
        &Bits::SEARCHABLE
    }

    fn tied_wa(&self) -> bool {
        false
    }

    fn speedup(&self, model: &ModelDesc, qc: &QuantConfig) -> f64 {
        eq4_speedup(model, qc, mac_speedup)
    }

    fn energy_pj(&self, _model: &ModelDesc, _qc: &QuantConfig) -> Option<f64> {
        // The paper uses Bitfusion with speedup + memory constraint only.
        None
    }

    fn sram_bytes(&self) -> Option<f64> {
        self.sram_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qc(wa: &[(u32, u32)]) -> QuantConfig {
        QuantConfig {
            w_bits: wa.iter().map(|&(w, _)| Bits::from_bits(w).unwrap()).collect(),
            a_bits: wa.iter().map(|&(_, a)| Bits::from_bits(a).unwrap()).collect(),
        }
    }

    #[test]
    fn paper_anchor_speedups() {
        assert_eq!(mac_speedup(Bits::B2, Bits::B2), 64.0);
        assert_eq!(mac_speedup(Bits::B8, Bits::B8), 4.0);
        assert_eq!(mac_speedup(Bits::B16, Bits::B16), 1.0);
        assert_eq!(mac_speedup(Bits::B4, Bits::B4), 16.0);
        assert_eq!(mac_speedup(Bits::B2, Bits::B8), 16.0);
        assert_eq!(mac_speedup(Bits::B8, Bits::B16), 2.0);
    }

    #[test]
    fn table7_s26_speedup() {
        // S26: 8/16 2/2 2/2 2/2 4/4 2/8 2/2 2/4 -> paper: 40.7x.
        let m = ModelDesc::paper();
        let p = Bitfusion::paper_experiment();
        let cfg = qc(&[(8, 16), (2, 2), (2, 2), (2, 2), (4, 4), (2, 8), (2, 2), (2, 4)]);
        let s = p.speedup(&m, &cfg);
        assert!((s - 40.7).abs() < 0.2, "speedup {s}");
    }

    #[test]
    fn table8_s20_speedup() {
        // Beacon S20: 4/16 2/2 2/2 2/4 2/2 2/4 2/2 2/4 -> paper: 47.1x.
        let m = ModelDesc::paper();
        let p = Bitfusion::paper_experiment();
        let cfg = qc(&[(4, 16), (2, 2), (2, 2), (2, 4), (2, 2), (2, 4), (2, 2), (2, 4)]);
        let s = p.speedup(&m, &cfg);
        assert!((s - 47.1).abs() < 0.3, "speedup {s}");
    }

    #[test]
    fn table7_s1_speedup() {
        // S1: 8/16 2/2 2/16 4/8 4/8 4/16 4/4 2/8 -> paper: 14.6x.
        let m = ModelDesc::paper();
        let p = Bitfusion::paper_experiment();
        let cfg = qc(&[(8, 16), (2, 2), (2, 16), (4, 8), (4, 8), (4, 16), (4, 4), (2, 8)]);
        let s = p.speedup(&m, &cfg);
        assert!((s - 14.6).abs() < 0.2, "speedup {s}");
    }

    #[test]
    fn two_mb_needs_heavy_compression() {
        let m = ModelDesc::paper();
        let p = Bitfusion::paper_experiment();
        // All-4-bit (8x) is ~2.65 MB: violates 2 MB.
        assert!(p.sram_violation(&m, &QuantConfig::uniform(8, Bits::B4, Bits::B4)) > 0.0);
        // All-2-bit (~15.6x) fits.
        assert_eq!(
            p.sram_violation(&m, &QuantConfig::uniform(8, Bits::B2, Bits::B2)),
            0.0
        );
    }

    #[test]
    fn speedup_symmetric_in_operands() {
        for (w, a) in [(Bits::B2, Bits::B8), (Bits::B4, Bits::B16)] {
            assert_eq!(mac_speedup(w, a), mac_speedup(a, w));
        }
    }
}
