//! Versioned platform manifests: accelerator cost models as *data*.
//!
//! A manifest describes everything the search needs to score hardware
//! objectives on a platform — supported precisions, tied-W=A rule,
//! per-precision speedup lookup table (HAQ-style latency tables), SRAM
//! capacity and an optional Eq. 3 energy model — without a line of Rust.
//! `hw::tabular` turns a validated manifest into a live [`Platform`]
//! (same Eq. 3/Eq. 4 free functions as the built-ins, so a manifest that
//! transcribes SiLago's Table 2 reproduces the built-in's fronts bit for
//! bit), and the registry loads manifests at startup
//! (`mohaq --platform-dir`), per spec (an inline `"manifest"` platform
//! parameter) or per serve request (`register_platform` frames).
//!
//! Validation is strict on purpose: unknown fields are rejected at every
//! object level (a typo'd `"enery"` must not silently drop the energy
//! model), `format_version` is gated exactly, and the cost tables must
//! cover precisely the declared precision grid (diagonal when
//! `tied_wa`, full W×A cross product otherwise). Future format versions
//! may add optional fields under a bumped `format_version`; readers of
//! version N reject version N+1 rather than guess.
//!
//! [`Platform`]: super::Platform

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::Path;

use crate::quant::Bits;
use crate::util::json::{Json, JsonError};

/// The manifest format version this build reads and writes.
pub const MANIFEST_VERSION: u64 = 1;

/// Typed manifest failure. Every parse/validation/IO path lands here —
/// feeding arbitrary bytes into the loader must never panic.
#[derive(Debug, Clone, PartialEq)]
pub enum ManifestError {
    /// The text is not valid JSON (position details in the message).
    Parse(String),
    /// `format_version` is missing or not one this build understands.
    Version { found: u64, supported: u64 },
    /// A required field is absent.
    Missing { field: String },
    /// A field this schema does not define (strict rejection).
    UnknownField { context: String, field: String },
    /// A field is present but its value is out of contract.
    Invalid(String),
    /// Filesystem failure while loading (path in the message).
    Io(String),
    /// Registration collided with an existing platform name.
    Collision { name: String, existing: String },
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Parse(msg) => write!(f, "manifest is not valid JSON: {msg}"),
            ManifestError::Version { found, supported } => write!(
                f,
                "manifest format_version {found} is not supported (this build reads \
                 version {supported})"
            ),
            ManifestError::Missing { field } => write!(f, "manifest is missing '{field}'"),
            ManifestError::UnknownField { context, field } => write!(
                f,
                "unknown field '{field}' in {context} (the manifest schema is strict; \
                 see DESIGN.md \"Platform manifests\")"
            ),
            ManifestError::Invalid(msg) => write!(f, "invalid manifest: {msg}"),
            ManifestError::Io(msg) => write!(f, "manifest io error: {msg}"),
            ManifestError::Collision { name, existing } => write!(
                f,
                "platform name '{name}' is already registered as a {existing} platform; \
                 manifests may not shadow it"
            ),
        }
    }
}

impl std::error::Error for ManifestError {}

impl From<JsonError> for ManifestError {
    fn from(e: JsonError) -> ManifestError {
        ManifestError::Parse(e.to_string())
    }
}

/// Optional Eq. 3 energy model: per-bit load energy from SRAM, a MAC
/// energy table over the precision grid, and a flat per-op cost for the
/// fixed (element-wise / nonlinear) ops.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// Energy to load one bit from on-chip memory (pJ).
    pub bit_load_pj: f64,
    /// Energy per fixed op (pJ); optional in JSON, defaults to 0.
    pub fixed_op_pj: f64,
    /// MAC energy (pJ) per `(w_bits, a_bits)` pair.
    pub mac_pj: BTreeMap<(u32, u32), f64>,
}

/// A validated, versioned platform description. Field order here is the
/// schema; `from_json` rejects anything outside it.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformManifest {
    /// Registry name (normalized lowercase; no whitespace).
    pub name: String,
    /// Free-text provenance/notes; round-trips but is never interpreted.
    pub description: Option<String>,
    /// Whether weight and activation precision must match per layer.
    pub tied_wa: bool,
    /// MAC precisions the platform supports, sorted ascending. 32-bit is
    /// the float baseline, not a searchable precision, and is rejected.
    pub supported_bits: Vec<Bits>,
    /// On-chip SRAM capacity in MB (the memory constraint), if any.
    pub sram_mb: Option<f64>,
    /// Speedup over the platform's widest-precision baseline per
    /// `(w_bits, a_bits)` pair (Eq. 4 lookup table).
    pub speedup: BTreeMap<(u32, u32), f64>,
    /// Optional energy model (platforms without one reject `energy_uj`
    /// objectives at spec validation, same as built-in Bitfusion).
    pub energy: Option<EnergyModel>,
}

/// `"4x8"` ↔ `(4, 8)` — the JSON spelling of a W×A table key.
fn parse_pair_key(key: &str) -> Result<(u32, u32), ManifestError> {
    let bad = || {
        ManifestError::Invalid(format!(
            "table key '{key}' is not of the form 'WxA' (e.g. \"4x8\")"
        ))
    };
    let (w, a) = key.split_once('x').ok_or_else(bad)?;
    Ok((w.parse().map_err(|_| bad())?, a.parse().map_err(|_| bad())?))
}

fn pair_key(w: u32, a: u32) -> String {
    format!("{w}x{a}")
}

/// Parse a `{"WxA": cost}` table, checking values are finite and within
/// `(min, ∞)`, and every referenced precision is in `bits`.
fn parse_table(
    j: &Json,
    context: &str,
    bits: &BTreeSet<u32>,
    min_exclusive: f64,
) -> Result<BTreeMap<(u32, u32), f64>, ManifestError> {
    let obj = j
        .as_obj()
        .ok_or_else(|| ManifestError::Invalid(format!("'{context}' must be a JSON object")))?;
    let mut table = BTreeMap::new();
    for (key, value) in obj {
        let (w, a) = parse_pair_key(key)?;
        for b in [w, a] {
            if !bits.contains(&b) {
                return Err(ManifestError::Invalid(format!(
                    "'{context}' key '{key}' references {b}-bit, which is not in \
                     supported_bits"
                )));
            }
        }
        let v = value.as_f64().ok_or_else(|| {
            ManifestError::Invalid(format!("'{context}' entry '{key}' must be a number"))
        })?;
        if !v.is_finite() || v <= min_exclusive {
            let want = if min_exclusive < 0.0 {
                "a finite number >= 0".to_string()
            } else {
                format!("a finite number > {min_exclusive}")
            };
            return Err(ManifestError::Invalid(format!(
                "'{context}' entry '{key}' must be {want} (got {v})"
            )));
        }
        table.insert((w, a), v);
    }
    Ok(table)
}

/// The precision pairs a table must cover exactly: the diagonal for a
/// tied-W=A platform, the full cross product otherwise.
fn required_pairs(bits: &[Bits], tied: bool) -> BTreeSet<(u32, u32)> {
    let mut pairs = BTreeSet::new();
    for w in bits {
        for a in bits {
            if !tied || w == a {
                pairs.insert((w.bits(), a.bits()));
            }
        }
    }
    pairs
}

fn check_coverage(
    table: &BTreeMap<(u32, u32), f64>,
    context: &str,
    required: &BTreeSet<(u32, u32)>,
) -> Result<(), ManifestError> {
    for (w, a) in required {
        if !table.contains_key(&(*w, *a)) {
            return Err(ManifestError::Invalid(format!(
                "'{context}' is missing entry '{}' for a supported precision pair",
                pair_key(*w, *a)
            )));
        }
    }
    for (w, a) in table.keys() {
        if !required.contains(&(*w, *a)) {
            return Err(ManifestError::Invalid(format!(
                "'{context}' entry '{}' is unreachable (tied-W=A platforms take only \
                 diagonal WxW entries)",
                pair_key(*w, *a)
            )));
        }
    }
    Ok(())
}

fn reject_unknown_fields(
    obj: &BTreeMap<String, Json>,
    context: &str,
    allowed: &[&str],
) -> Result<(), ManifestError> {
    for key in obj.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(ManifestError::UnknownField {
                context: context.to_string(),
                field: key.clone(),
            });
        }
    }
    Ok(())
}

impl PlatformManifest {
    /// Parse and fully validate a manifest. Everything `from_json`
    /// accepts satisfies [`validate`](Self::validate).
    pub fn from_json(j: &Json) -> Result<PlatformManifest, ManifestError> {
        let obj = j
            .as_obj()
            .ok_or_else(|| ManifestError::Invalid("manifest must be a JSON object".into()))?;
        reject_unknown_fields(
            obj,
            "the manifest",
            &[
                "format_version",
                "name",
                "description",
                "tied_wa",
                "supported_bits",
                "sram_mb",
                "speedup",
                "energy",
            ],
        )?;

        // Version gate FIRST: a future-format manifest should fail with
        // "unsupported version", not whatever field error shows up first.
        let version = obj
            .get("format_version")
            .ok_or_else(|| ManifestError::Missing { field: "format_version".into() })
            .and_then(|v| {
                v.as_i64().filter(|n| *n >= 0).map(|n| n as u64).ok_or_else(|| {
                    ManifestError::Invalid("'format_version' must be a non-negative integer".into())
                })
            })?;
        if version != MANIFEST_VERSION {
            return Err(ManifestError::Version { found: version, supported: MANIFEST_VERSION });
        }

        let name = obj
            .get("name")
            .ok_or_else(|| ManifestError::Missing { field: "name".into() })?
            .as_str()
            .ok_or_else(|| ManifestError::Invalid("'name' must be a string".into()))?
            .to_lowercase();

        let description = match obj.get("description") {
            None => None,
            Some(d) => Some(
                d.as_str()
                    .ok_or_else(|| ManifestError::Invalid("'description' must be a string".into()))?
                    .to_string(),
            ),
        };

        let tied_wa = obj
            .get("tied_wa")
            .ok_or_else(|| ManifestError::Missing { field: "tied_wa".into() })?
            .as_bool()
            .ok_or_else(|| ManifestError::Invalid("'tied_wa' must be a boolean".into()))?;

        let bits_arr = obj
            .get("supported_bits")
            .ok_or_else(|| ManifestError::Missing { field: "supported_bits".into() })?
            .as_arr()
            .ok_or_else(|| ManifestError::Invalid("'supported_bits' must be an array".into()))?;
        let mut supported_bits: Vec<Bits> = Vec::with_capacity(bits_arr.len());
        for b in bits_arr {
            let n = b.as_i64().filter(|n| *n > 0).ok_or_else(|| {
                ManifestError::Invalid("'supported_bits' entries must be positive integers".into())
            })?;
            if n == 32 {
                return Err(ManifestError::Invalid(
                    "'supported_bits' may not include 32: 32-bit float is the \
                     unquantized baseline, not a searchable precision"
                        .into(),
                ));
            }
            let bits = Bits::from_bits(n as u32).ok_or_else(|| {
                ManifestError::Invalid(format!(
                    "'supported_bits' entry {n} is not a supported precision (2, 4, 8, 16)"
                ))
            })?;
            if supported_bits.contains(&bits) {
                return Err(ManifestError::Invalid(format!(
                    "'supported_bits' lists {n} twice"
                )));
            }
            supported_bits.push(bits);
        }
        supported_bits.sort_by_key(Bits::bits);

        let sram_mb = match obj.get("sram_mb") {
            None => None,
            Some(v) => {
                let mb = v
                    .as_f64()
                    .ok_or_else(|| ManifestError::Invalid("'sram_mb' must be a number".into()))?;
                if !mb.is_finite() || mb <= 0.0 {
                    return Err(ManifestError::Invalid(format!(
                        "'sram_mb' must be a finite number > 0 (got {mb})"
                    )));
                }
                Some(mb)
            }
        };

        let bit_set: BTreeSet<u32> = supported_bits.iter().map(Bits::bits).collect();
        let speedup = parse_table(
            obj.get("speedup")
                .ok_or_else(|| ManifestError::Missing { field: "speedup".into() })?,
            "speedup",
            &bit_set,
            0.0,
        )?;

        let energy = match obj.get("energy") {
            None => None,
            Some(e) => {
                let eobj = e.as_obj().ok_or_else(|| {
                    ManifestError::Invalid("'energy' must be a JSON object".into())
                })?;
                reject_unknown_fields(eobj, "'energy'", &["bit_load_pj", "fixed_op_pj", "mac_pj"])?;
                let pj = |field: &str, required: bool| -> Result<Option<f64>, ManifestError> {
                    match eobj.get(field) {
                        None if required => {
                            Err(ManifestError::Missing { field: format!("energy.{field}") })
                        }
                        None => Ok(None),
                        Some(v) => {
                            let pj = v.as_f64().ok_or_else(|| {
                                ManifestError::Invalid(format!(
                                    "'energy.{field}' must be a number"
                                ))
                            })?;
                            if !pj.is_finite() || pj < 0.0 {
                                return Err(ManifestError::Invalid(format!(
                                    "'energy.{field}' must be a finite number >= 0 (got {pj})"
                                )));
                            }
                            Ok(Some(pj))
                        }
                    }
                };
                let bit_load_pj = pj("bit_load_pj", true)?.expect("required field checked");
                let fixed_op_pj = pj("fixed_op_pj", false)?.unwrap_or(0.0);
                let mac_pj = parse_table(
                    eobj.get("mac_pj")
                        .ok_or_else(|| ManifestError::Missing { field: "energy.mac_pj".into() })?,
                    "energy.mac_pj",
                    &bit_set,
                    // MAC energy 0 is physically meaningless but harmless;
                    // forbid only negatives (min_exclusive just below 0).
                    -f64::MIN_POSITIVE,
                )?;
                Some(EnergyModel { bit_load_pj, fixed_op_pj, mac_pj })
            }
        };

        let manifest = PlatformManifest {
            name,
            description,
            tied_wa,
            supported_bits,
            sram_mb,
            speedup,
            energy,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    pub fn from_json_str(text: &str) -> Result<PlatformManifest, ManifestError> {
        let j = Json::parse(text).map_err(ManifestError::from)?;
        PlatformManifest::from_json(&j)
    }

    /// Load and validate a single manifest file.
    pub fn load_file(path: impl AsRef<Path>) -> Result<PlatformManifest, ManifestError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| ManifestError::Io(format!("{}: {e}", path.display())))?;
        PlatformManifest::from_json_str(&text)
            .map_err(|e| match e {
                // Keep typed variants intact; only prefix the free-text ones
                // with the offending path.
                ManifestError::Parse(msg) => {
                    ManifestError::Parse(format!("{}: {msg}", path.display()))
                }
                ManifestError::Invalid(msg) => {
                    ManifestError::Invalid(format!("{}: {msg}", path.display()))
                }
                other => other,
            })
    }

    /// Structural invariants, re-checkable on hand-built values (the
    /// registry re-validates before registering). `from_json` output
    /// always passes.
    pub fn validate(&self) -> Result<(), ManifestError> {
        if self.name.is_empty() {
            return Err(ManifestError::Invalid("'name' must be non-empty".into()));
        }
        if !self.name.chars().all(|c| c.is_ascii_alphanumeric() || "_-.".contains(c)) {
            return Err(ManifestError::Invalid(format!(
                "'name' '{}' may only contain [a-z0-9_.-] (it is used as a registry key \
                 and in 'metric@name' objective bindings)",
                self.name
            )));
        }
        if self.supported_bits.is_empty() {
            return Err(ManifestError::Invalid(
                "'supported_bits' must list at least one precision".into(),
            ));
        }
        let required = required_pairs(&self.supported_bits, self.tied_wa);
        check_coverage(&self.speedup, "speedup", &required)?;
        if let Some(e) = &self.energy {
            check_coverage(&e.mac_pj, "energy.mac_pj", &required)?;
        }
        Ok(())
    }

    /// Emit the canonical JSON form. `from_json(m.to_json()) == m` —
    /// the round trip is lossless (values travel as exact f64s through
    /// the in-tree codec's shortest-round-trip float formatting).
    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = BTreeMap::new();
        obj.insert("format_version".into(), Json::Num(MANIFEST_VERSION as f64));
        obj.insert("name".into(), Json::Str(self.name.clone()));
        if let Some(d) = &self.description {
            obj.insert("description".into(), Json::Str(d.clone()));
        }
        obj.insert("tied_wa".into(), Json::Bool(self.tied_wa));
        obj.insert(
            "supported_bits".into(),
            Json::Arr(self.supported_bits.iter().map(|b| Json::Num(b.bits() as f64)).collect()),
        );
        if let Some(mb) = self.sram_mb {
            obj.insert("sram_mb".into(), Json::Num(mb));
        }
        let table_json = |t: &BTreeMap<(u32, u32), f64>| {
            Json::Obj(t.iter().map(|((w, a), v)| (pair_key(*w, *a), Json::Num(*v))).collect())
        };
        obj.insert("speedup".into(), table_json(&self.speedup));
        if let Some(e) = &self.energy {
            let mut em: BTreeMap<String, Json> = BTreeMap::new();
            em.insert("bit_load_pj".into(), Json::Num(e.bit_load_pj));
            em.insert("fixed_op_pj".into(), Json::Num(e.fixed_op_pj));
            em.insert("mac_pj".into(), table_json(&e.mac_pj));
            obj.insert("energy".into(), Json::Obj(em));
        }
        Json::Obj(obj)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// One-line capability summary for `mohaq platform lint` / discovery.
    pub fn summary(&self) -> String {
        let bits: Vec<String> =
            self.supported_bits.iter().map(|b| b.bits().to_string()).collect();
        format!(
            "tied W=A: {:<5} bits: {{{}}}  sram: {}  speedup table: {} entries  energy model: {}",
            self.tied_wa,
            bits.join(","),
            match self.sram_mb {
                Some(mb) => format!("{mb} MB"),
                None => "none".into(),
            },
            self.speedup.len(),
            if self.energy.is_some() { "yes" } else { "no" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn silago_text() -> String {
        std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/platforms/silago_lut.json"
        ))
        .expect("checked-in manifest")
    }

    #[test]
    fn checked_in_manifests_parse_and_roundtrip() {
        for file in ["silago_lut.json", "bitfusion_lut.json"] {
            let path = format!("{}/platforms/{file}", env!("CARGO_MANIFEST_DIR"));
            let m = PlatformManifest::load_file(&path).unwrap_or_else(|e| panic!("{file}: {e}"));
            let back = PlatformManifest::from_json(&m.to_json()).unwrap();
            assert_eq!(m, back, "{file}: JSON round trip not lossless");
            // Bitwise: the emitted text re-parses to the same f64s.
            let reparsed = PlatformManifest::from_json_str(&m.to_json_string()).unwrap();
            for (k, v) in &m.speedup {
                assert_eq!(v.to_bits(), reparsed.speedup[k].to_bits());
            }
        }
    }

    #[test]
    fn silago_manifest_matches_builtin_tables() {
        let m = PlatformManifest::from_json_str(&silago_text()).unwrap();
        assert_eq!(m.name, "silago_lut");
        assert!(m.tied_wa);
        assert_eq!(m.supported_bits, vec![Bits::B4, Bits::B8, Bits::B16]);
        for b in [Bits::B4, Bits::B8, Bits::B16] {
            let pair = (b.bits(), b.bits());
            assert_eq!(m.speedup[&pair].to_bits(), super::super::silago::mac_speedup(b).to_bits());
            let e = m.energy.as_ref().unwrap();
            assert_eq!(e.mac_pj[&pair].to_bits(), super::super::silago::mac_energy_pj(b).to_bits());
        }
        assert_eq!(m.energy.as_ref().unwrap().bit_load_pj, super::super::silago::BIT_LOAD_PJ);
    }

    #[test]
    fn bitfusion_manifest_matches_builtin_tables() {
        let path = format!("{}/platforms/bitfusion_lut.json", env!("CARGO_MANIFEST_DIR"));
        let m = PlatformManifest::load_file(path).unwrap();
        assert!(!m.tied_wa);
        assert!(m.energy.is_none());
        assert_eq!(m.speedup.len(), 16);
        for w in Bits::SEARCHABLE {
            for a in Bits::SEARCHABLE {
                assert_eq!(
                    m.speedup[&(w.bits(), a.bits())].to_bits(),
                    super::super::bitfusion::mac_speedup(w, a).to_bits(),
                    "({w:?},{a:?})"
                );
            }
        }
    }

    #[test]
    fn version_gate_rejects_other_versions() {
        let text = silago_text().replace("\"format_version\": 1", "\"format_version\": 2");
        match PlatformManifest::from_json_str(&text) {
            Err(ManifestError::Version { found: 2, supported: 1 }) => {}
            other => panic!("expected version error, got {other:?}"),
        }
        let no_version = silago_text().replace("\"format_version\": 1,", "");
        match PlatformManifest::from_json_str(&no_version) {
            Err(ManifestError::Missing { field }) => assert_eq!(field, "format_version"),
            other => panic!("expected missing-version error, got {other:?}"),
        }
    }

    #[test]
    fn unknown_fields_rejected_at_every_level() {
        let top = silago_text().replace("\"tied_wa\"", "\"tied\": true, \"tied_wa\"");
        match PlatformManifest::from_json_str(&top) {
            Err(ManifestError::UnknownField { field, .. }) => assert_eq!(field, "tied"),
            other => panic!("expected unknown-field error, got {other:?}"),
        }
        let nested = silago_text().replace("\"bit_load_pj\"", "\"bit_laod_pj\": 1, \"bit_load_pj\"");
        match PlatformManifest::from_json_str(&nested) {
            Err(ManifestError::UnknownField { field, context }) => {
                assert_eq!(field, "bit_laod_pj");
                assert!(context.contains("energy"), "{context}");
            }
            other => panic!("expected unknown-field error, got {other:?}"),
        }
    }

    #[test]
    fn coverage_and_value_validation() {
        // Missing diagonal entry.
        let missing = silago_text().replace("\"8x8\": 2.0,", "");
        assert!(matches!(
            PlatformManifest::from_json_str(&missing),
            Err(ManifestError::Invalid(_))
        ));
        // Off-diagonal entry on a tied platform.
        let off = silago_text().replace("\"8x8\": 2.0,", "\"8x8\": 2.0, \"4x8\": 3.0,");
        let err = PlatformManifest::from_json_str(&off).unwrap_err();
        assert!(err.to_string().contains("unreachable"), "{err}");
        // Table key referencing an unsupported precision.
        let alien = silago_text().replace("\"8x8\": 2.0,", "\"8x8\": 2.0, \"2x2\": 9.0,");
        let err = PlatformManifest::from_json_str(&alien).unwrap_err();
        assert!(err.to_string().contains("not in"), "{err}");
        // Non-positive speedup.
        let zero = silago_text().replace("\"8x8\": 2.0", "\"8x8\": 0.0");
        assert!(PlatformManifest::from_json_str(&zero).is_err());
        // 32-bit is not a searchable precision.
        let b32 = silago_text().replace("[4, 8, 16]", "[4, 8, 16, 32]");
        let err = PlatformManifest::from_json_str(&b32).unwrap_err();
        assert!(err.to_string().contains("32"), "{err}");
        // Duplicate precision entry.
        let dup = silago_text().replace("[4, 8, 16]", "[4, 8, 16, 8]");
        let err = PlatformManifest::from_json_str(&dup).unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");
    }

    #[test]
    fn name_rules() {
        let upper = silago_text().replace("\"silago_lut\"", "\"SiLago_LUT\"");
        assert_eq!(PlatformManifest::from_json_str(&upper).unwrap().name, "silago_lut");
        let spaced = silago_text().replace("\"silago_lut\"", "\"si lago\"");
        assert!(PlatformManifest::from_json_str(&spaced).is_err());
        let empty = silago_text().replace("\"silago_lut\"", "\"\"");
        assert!(PlatformManifest::from_json_str(&empty).is_err());
    }
}
