//! SiLago CGRA model (paper §2.5.1, Table 2).
//!
//! The DRRA NACU MAC is reconfigurable via Vedic-multiplier splitting:
//! 1x 16-bit, 2x 8-bit or 4x 4-bit MACs per cycle — hence W and A share a
//! precision per layer and only {4, 8, 16} are supported (§5.3). Energy
//! comes from the post-layout Table 2 numbers (28nm): MAC energy per
//! precision plus 0.08 pJ per bit loaded from the DiMArch SRAM macros.

use super::{eq3_energy_pj, eq4_speedup, Platform};
use crate::model::ModelDesc;
use crate::quant::{Bits, QuantConfig};

#[derive(Debug, Clone)]
pub struct SiLago {
    /// DiMArch scratchpad capacity (experiment 2 uses 6 MB — §5.3).
    pub sram_bytes: Option<f64>,
}

/// Table 2 row "MAC speedup".
pub fn mac_speedup(bits: Bits) -> f64 {
    match bits {
        Bits::B4 => 4.0,
        Bits::B8 => 2.0,
        _ => 1.0, // 16-bit baseline (B2/B32 unsupported on SiLago)
    }
}

/// Table 2 row "MAC energy cost (pJ)".
pub fn mac_energy_pj(bits: Bits) -> f64 {
    match bits {
        Bits::B4 => 0.153,
        Bits::B8 => 0.542,
        _ => 1.666,
    }
}

/// Table 2 row "Loading 1-bit energy cost (pJ)".
pub const BIT_LOAD_PJ: f64 = 0.08;

impl SiLago {
    pub fn new(sram_bytes: Option<f64>) -> Self {
        SiLago { sram_bytes }
    }

    /// The §5.3 configuration: 6 MB SRAM constraint.
    pub fn paper_experiment() -> Self {
        SiLago { sram_bytes: Some(6.0 * 1024.0 * 1024.0) }
    }
}

impl Platform for SiLago {
    fn name(&self) -> &str {
        "SiLago"
    }

    fn supported_bits(&self) -> &[Bits] {
        &[Bits::B4, Bits::B8, Bits::B16]
    }

    fn tied_wa(&self) -> bool {
        true
    }

    fn has_energy_model(&self) -> bool {
        true
    }

    fn speedup(&self, model: &ModelDesc, qc: &QuantConfig) -> f64 {
        // W == A per layer on SiLago; the MAC runs at the layer precision.
        eq4_speedup(model, qc, |w, _a| mac_speedup(w))
    }

    fn energy_pj(&self, model: &ModelDesc, qc: &QuantConfig) -> Option<f64> {
        // Eq. 3 counts MAC energy + bit loading only (the paper's Base_S
        // 16.4 uJ and S7 2.6 uJ anchors hold exactly without charging the
        // element-wise/non-linear ops, so the fixed-op term is zero here).
        Some(eq3_energy_pj(model, qc, BIT_LOAD_PJ, |w, _a| mac_energy_pj(w), 0.0))
    }

    fn sram_bytes(&self) -> Option<f64> {
        self.sram_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_qc(bits: &[u32]) -> QuantConfig {
        let b: Vec<Bits> = bits.iter().map(|&x| Bits::from_bits(x).unwrap()).collect();
        QuantConfig { w_bits: b.clone(), a_bits: b }
    }

    #[test]
    fn base16_energy_matches_table6() {
        // Base_S row: 16-bit full implementation = 16.4 uJ.
        let m = ModelDesc::paper();
        let p = SiLago::paper_experiment();
        let qc = paper_qc(&[16; 8]);
        let uj = p.energy_pj(&m, &qc).unwrap() / 1e6;
        assert!((uj - 16.4).abs() < 0.2, "energy {uj} uJ");
        assert!((p.speedup(&m, &qc) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn all4_matches_table6_s7() {
        // S7 row: all 4-bit -> 3.9x speedup, 2.6 uJ.
        let m = ModelDesc::paper();
        let p = SiLago::paper_experiment();
        let qc = paper_qc(&[4; 8]);
        let s = p.speedup(&m, &qc);
        let uj = p.energy_pj(&m, &qc).unwrap() / 1e6;
        // Paper reports 3.9x; its own Table 4 element-wise total (88000)
        // is inconsistent with its per-layer rows (4 x 15400 = 61600),
        // which shifts the fixed-op share slightly — accept 3.9..4.0.
        assert!((3.85..4.0).contains(&s), "speedup {s}");
        assert!((uj - 2.6).abs() < 0.15, "energy {uj} uJ");
    }

    #[test]
    fn s1_row_matches_table6() {
        // S1: 16 4 8 8 4 16 4 8 -> 2.6x speedup, ~5.8 uJ (we allow 6%:
        // the paper's unlisted accounting of non-MxV ops differs slightly).
        let m = ModelDesc::paper();
        let p = SiLago::paper_experiment();
        let qc = paper_qc(&[16, 4, 8, 8, 4, 16, 4, 8]);
        let s = p.speedup(&m, &qc);
        let uj = p.energy_pj(&m, &qc).unwrap() / 1e6;
        assert!((s - 2.6).abs() < 0.06, "speedup {s}");
        assert!((uj - 5.8).abs() < 0.35, "energy {uj} uJ");
    }

    #[test]
    fn energy_monotone_in_precision() {
        let m = ModelDesc::paper();
        let p = SiLago::new(None);
        let e4 = p.energy_pj(&m, &paper_qc(&[4; 8])).unwrap();
        let e8 = p.energy_pj(&m, &paper_qc(&[8; 8])).unwrap();
        let e16 = p.energy_pj(&m, &paper_qc(&[16; 8])).unwrap();
        assert!(e4 < e8 && e8 < e16);
    }

    #[test]
    fn six_mb_constraint_allows_mixed_but_not_16bit() {
        let m = ModelDesc::paper();
        let p = SiLago::paper_experiment();
        assert!(p.sram_violation(&m, &paper_qc(&[16; 8])) > 0.0);
        assert_eq!(p.sram_violation(&m, &paper_qc(&[8; 8])), 0.0); // 5.3MB
    }
}
