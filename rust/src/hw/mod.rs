//! Hardware platform models (paper §4.4): analytical speedup (Eq. 4) and
//! energy (Eq. 3, the Eyeriss-style model of [51]) objectives plus the
//! on-chip SRAM size constraint. The paper itself has no RNN
//! implementation on either platform — "the hardware model is an input" —
//! so these analytical models ARE the paper's methodology, not a
//! simulation shortcut.

pub mod bitfusion;
pub mod manifest;
pub mod registry;
pub mod silago;
pub mod tabular;

pub use manifest::{ManifestError, PlatformManifest};
pub use registry::{register, resolve, PlatformSpec};
pub use tabular::TabularPlatform;

use crate::model::ModelDesc;
use crate::quant::{Bits, QuantConfig};

/// A hardware platform able to score a mixed-precision configuration.
///
/// Implementations must be `Send + Sync` to be registrable (the search
/// shares one platform handle across its evaluation thread pool); the
/// built-ins are plain data structs, so this is automatic.
pub trait Platform {
    fn name(&self) -> &str;

    /// Precisions the platform MACs support.
    fn supported_bits(&self) -> &[Bits];

    /// Whether weight and activation precision must match per layer
    /// (SiLago: yes — §5.3; Bitfusion: no).
    fn tied_wa(&self) -> bool;

    /// Whether `energy_pj` returns a value — used by spec validation to
    /// reject energy objectives on platforms without an energy model.
    fn has_energy_model(&self) -> bool {
        false
    }

    /// Expected speedup over the platform's 16-bit baseline (Eq. 4).
    fn speedup(&self, model: &ModelDesc, qc: &QuantConfig) -> f64;

    /// Expected energy in pJ (Eq. 3), if the platform has an energy model.
    fn energy_pj(&self, model: &ModelDesc, qc: &QuantConfig) -> Option<f64>;

    /// On-chip SRAM capacity in bytes (the memory constraint).
    fn sram_bytes(&self) -> Option<f64>;

    /// Constraint violation for the SRAM-size constraint in MB (0 if fits).
    fn sram_violation(&self, model: &ModelDesc, qc: &QuantConfig) -> f64 {
        match self.sram_bytes() {
            None => 0.0,
            Some(cap) => {
                let size = model.size_bytes(&qc.w_bits);
                ((size - cap) / (1024.0 * 1024.0)).max(0.0)
            }
        }
    }
}

/// Eq. 4 speedup: sum(S_i * N_i) / N_T, where N_i are MAC counts per
/// precision pair and N_T additionally includes the element-wise and
/// non-linear ops, which always run at 16-bit rate (speedup 1) — this
/// reproduces the paper's 3.9x (not 4.0x) max on SiLago.
pub fn eq4_speedup(
    model: &ModelDesc,
    qc: &QuantConfig,
    per_op_speedup: impl Fn(Bits, Bits) -> f64,
) -> f64 {
    let mut weighted = 0.0;
    let mut total = 0.0;
    for (i, layer) in model.layers.iter().enumerate() {
        let macs = layer.mac_ops() as f64;
        weighted += per_op_speedup(qc.w_bits[i], qc.a_bits[i]) * macs;
        total += macs;
        let fixed_ops = (layer.elementwise_ops() + layer.nonlinear_ops()) as f64;
        weighted += fixed_ops; // 16-bit rate, S=1
        total += fixed_ops;
    }
    weighted / total
}

/// Eq. 3 energy: E = N_b * C_M + sum(E_i * N_i). N_b is the total model
/// bits resident in SRAM (weights at their per-layer precision, vectors at
/// 16-bit); element-wise/non-linear ops are charged the 16-bit MAC energy.
pub fn eq3_energy_pj(
    model: &ModelDesc,
    qc: &QuantConfig,
    bit_load_pj: f64,
    mac_energy_pj: impl Fn(Bits, Bits) -> f64,
    fixed_op_energy_pj: f64,
) -> f64 {
    let n_bits = model.size_bits(&qc.w_bits) as f64;
    let mut e = n_bits * bit_load_pj;
    for (i, layer) in model.layers.iter().enumerate() {
        e += layer.mac_ops() as f64 * mac_energy_pj(qc.w_bits[i], qc.a_bits[i]);
        e += (layer.elementwise_ops() + layer.nonlinear_ops()) as f64
            * fixed_op_energy_pj;
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Flat;
    impl Platform for Flat {
        fn name(&self) -> &str {
            "flat"
        }
        fn supported_bits(&self) -> &[Bits] {
            &Bits::SEARCHABLE
        }
        fn tied_wa(&self) -> bool {
            false
        }
        fn speedup(&self, m: &ModelDesc, qc: &QuantConfig) -> f64 {
            eq4_speedup(m, qc, |_, _| 2.0)
        }
        fn energy_pj(&self, _: &ModelDesc, _: &QuantConfig) -> Option<f64> {
            None
        }
        fn sram_bytes(&self) -> Option<f64> {
            Some(2.0 * 1024.0 * 1024.0)
        }
    }

    #[test]
    fn sram_violation_positive_when_too_big() {
        let m = ModelDesc::paper();
        let p = Flat;
        let qc16 = QuantConfig::uniform(8, Bits::B16, Bits::B16);
        // 16-bit model is ~11 MB >> 2 MB.
        assert!(p.sram_violation(&m, &qc16) > 0.0);
        let qc2 = QuantConfig::uniform(8, Bits::B2, Bits::B2);
        // 2-bit model is ~1.42 MB < 2 MB.
        assert_eq!(p.sram_violation(&m, &qc2), 0.0);
    }

    #[test]
    fn eq4_is_mac_weighted_mean() {
        let m = ModelDesc::paper();
        let qc = QuantConfig::uniform(8, Bits::B4, Bits::B4);
        let s = eq4_speedup(&m, &qc, |_, _| 4.0);
        // All MACs at 4x, fixed ops at 1x -> slightly below 4.
        assert!(s < 4.0 && s > 3.9, "s={s}");
    }
}
