//! MOHAQ command-line launcher.
//!
//! Subcommands (each supports `--help`):
//!   info                          artifact bundle summary
//!   table4                        model op/param breakdown (paper Table 4)
//!   platforms                     list registered hardware platforms
//!   eval                          score one quantization config
//!   search                        run a full experiment (preset or config)
//!
//! Global: --artifacts DIR (default ./artifacts, built by the Python AOT
//! pipeline — see README.md).

use std::sync::Arc;

use anyhow::{Context, Result};

use mohaq::coordinator::{
    baseline_rows, ExperimentSpec, ScoredObjective, SearchEvent, SearchSession,
};
use mohaq::hw::registry;
use mohaq::hw::Platform;
use mohaq::moo::Topology;
use mohaq::quant::{Bits, QuantConfig};
use mohaq::report;
use mohaq::util::cli::Args;

const USAGE: &str = "\
mohaq — Multi-Objective Hardware-Aware Quantization

usage: mohaq <command> [options]

commands:
  info        artifact bundle summary
  table4      model op/param breakdown (paper Table 4)
  platforms   list registered hardware platforms
  platform    platform-manifest tooling (mohaq platform lint FILE|DIR...)
  eval        score one quantization config
  search      run a full experiment through a SearchSession
  serve       long-lived search service over a shared session (TCP)
  worker      distributed-search worker process (see search --workers)
  bench-gate  diff a bench JSON report against the committed baseline
  help        show this message

global options:
  --artifacts DIR     artifact bundle directory (default: artifacts)
  --platform-dir DIR  load every *.json platform manifest in DIR into the
                      registry before running (platforms / search / serve
                      / worker; see DESIGN.md 'Platform manifests')

run `mohaq <command> --help` for per-command options.";

const PLATFORM_USAGE: &str = "\
usage: mohaq platform lint [FILE|DIR ...]

Validate platform manifest files (default target: platforms/). Each
FILE is parsed and schema-checked; each DIR contributes its *.json
files in sorted order. A manifest that passes prints its resolved
capability summary (precisions, tied-W=A, SRAM, energy model, sample
best-case speedup on the paper model); any failure prints its typed
error and the command exits non-zero.";

const EVAL_USAGE: &str = "\
usage: mohaq eval --w BITS[,BITS...] [--a BITS[,BITS...]] [--artifacts DIR]

Score one quantization config on the AOT inference executable.

options:
  --w BITS    weight precisions: either one value broadcast to all layers
              (e.g. --w 4) or a comma-separated per-layer list
              (e.g. --w 4,4,4,2,4,4,4,4)
  --a BITS    activation precisions, same format (default: same as --w)";

const SEARCH_USAGE: &str = "\
usage: mohaq search [--exp exp1|exp2|exp3|cross] [--config FILE] [options]

Run a full MOHAQ experiment through a SearchSession.

options:
  --exp NAME        paper preset: exp1 (compression), exp2 (SiLago),
                    exp3 (Bitfusion), cross (joint SiLago + Bitfusion)
                    [default: exp1]
  --config FILE     JSON experiment config instead of a preset
                    (covers everything the presets do; see config module)
  --beacon          enable beacon-based retraining (exp3 preset only)
  --gens N          override the number of generations
  --seed N          override the GA seed
  --threads N       evaluation worker threads (0 = one per core; the
                    front is identical for any value)
  --out DIR         write front.csv / records.csv to DIR
  --synthetic       evaluate on the hermetic surrogate evaluator even if
                    an artifact bundle exists (deterministic, offline —
                    what the CI smoke jobs run)
  --platform-dir D  load every *.json manifest in D into the platform
                    registry first, so --platforms/--config can name them

cross-platform search (one front scored on several platforms at once):
  --platforms A,B   registry platforms to bind (e.g. silago,bitfusion);
                    every listed platform contributes its SRAM constraint
  --objectives LIST comma-separated objectives. 'metric@platform' binds
                    explicitly (neg_speedup@silago); a bare hardware
                    metric expands across every listed platform; energy
                    objectives skip platforms without an energy model
                    [default: error,neg_speedup,energy_uj]

island model (population scaling; front is identical for any thread count):
  --islands K            run K sub-populations in lockstep (default: spec's
                         setting, or a single population)
  --migration-interval M exchange elites every M generations (default 5)
  --topology T           migration topology: ring | full (default ring)
  --migrants N           elites sent per source island (default 2)

distributed search (islands sharded across worker PROCESSES; the merged
front is bitwise-identical to the same seed run in-process):
  --workers A,B          comma-separated addresses of running `mohaq
                         worker` processes to shard the islands across
  --spawn-workers N      spawn N local worker processes (ephemeral ports)
                         for this search and stop them afterwards; adds
                         to any --workers list
  Requires an island config (--islands or the spec's). Beacon retraining
  (--beacon) runs distributed: beacon selection and retraining happen on
  the coordinator at migration boundaries, and the finalized parameter
  sets replicate to every worker (param_push) before the next window, so
  the merged front is bitwise-identical to the single-process beacon run
  at the same seed — see DESIGN.md 'Parameter-set store'. Without an
  artifact bundle the search falls back to the hermetic surrogate
  evaluator so the distributed stack can be exercised offline.

checkpoint / resume (durable search state; see DESIGN.md 'Durable state'):
  --checkpoint FILE      write a search checkpoint (spec + per-island RNG
                         positions + populations + finalized beacons) to
                         FILE at every migration boundary, via atomic
                         rename; needs an island config with >= 2 islands
  --resume FILE          continue an interrupted search from a checkpoint.
                         The checkpoint carries the full spec, so spec
                         flags (--exp/--config/--gens/--seed/--islands/...)
                         are rejected alongside it; the finished front is
                         bitwise-identical to the uninterrupted run. Also
                         works distributed (--workers/--spawn-workers) —
                         a crashed coordinator resumes from its last
                         written boundary. A beacon checkpoint names its
                         parameter sets; resume it with the --store the
                         run saved, or it is rejected rather than
                         silently retrained
  --store DIR            durable eval store for this search: reload
                         DIR/eval_store.json first (beacon resumes
                         resolve their parameter-set names against it)
                         and save it back when the search finishes
  --stop-after-checkpoints N
                         exit(0) immediately after the Nth checkpoint
                         write: a deterministic mid-run interruption (what
                         the CI resume-smoke job uses to simulate a crash)";

const WORKER_USAGE: &str = "\
usage: mohaq worker [--addr HOST:PORT] [--artifacts DIR] [--threads N]

Run one distributed-search worker: a serve-protocol server that also
accepts the shard ops a `mohaq search --workers ...` coordinator sends
(shard_assign / run_islands / elite_exchange / shard_front — see the
dist module). Each worker evaluates its assigned islands on its own
thread pool; the coordinator performs the migrations and the final
merge. Workers hold no cross-search state: a coordinator that vanishes
simply costs the connection.

options:
  --addr HOST:PORT  listen address (default 127.0.0.1:0 — an ephemeral
                    port, announced on stdout as
                    'mohaq worker: listening on ADDR')
  --artifacts DIR   artifact bundle to evaluate against (default:
                    artifacts); falls back to the hermetic surrogate
                    evaluator when DIR/manifest.json is missing
  --threads N       evaluation pool workers (0 = one per core)
  --cache-cap N     bound the PTQ result memo to N entries (default ~1M)";

const SERVE_USAGE: &str = "\
usage: mohaq serve [--addr HOST:PORT] [--artifacts DIR] [--threads N]

Run a long-lived search service over ONE shared SearchSession: requests
arrive as line-delimited JSON over TCP (see serve::protocol), each
carrying its own ExperimentSpec — platform table, objectives, GA
settings. The compiled artifacts and the platform-independent PTQ result
cache are shared across requests, so concurrent tenants searching
different hardware reuse each other's candidate evaluations; all
in-flight searches fan out across one evaluation worker pool.

Without an artifact bundle the server falls back to the hermetic
surrogate evaluator (synthetic model, closed-form errors) — handy for
protocol work and CI.

options:
  --addr HOST:PORT  listen address (default 127.0.0.1:7070; port 0 picks
                    an ephemeral port and prints it)
  --artifacts DIR   artifact bundle to serve (default: artifacts). When
                    DIR/manifest.json is missing the server falls back
                    to the hermetic surrogate evaluator and says so.
  --threads N       evaluation pool workers shared by all requests
                    (0 = one per core)
  --cache-cap N     bound the shared PTQ result memo to N entries
                    (default ~1M; idle entries rotate out, see eval::)
  --evict-beacons   retire each request's beacon parameter sets (device
                    + host memory and their memo entries) once its front
                    is reported; only safe when beacon-enabled requests
                    run serially
  --store DIR       durable eval store: reload DIR/eval_store.json at
                    startup (after --cache-cap/--evict-beacons apply, so
                    the reloaded memo respects this server's bounds) and
                    save it back on clean shutdown — a restarted server
                    answers repeated configs from cache. A corrupt store
                    file is a hard typed error, never a partial load.
                    See DESIGN.md 'Durable state'
  --store-interval SECS
                    also save the eval store every SECS seconds from a
                    background thread (temp file + atomic rename, so
                    readers never see a torn store), bounding what a
                    crash can lose to one interval; requires --store

Drive it with examples/serve_quickstart.rs:
  cargo run --release --example serve_quickstart -- --addr 127.0.0.1:7070";

const BENCH_GATE_USAGE: &str = "\
usage: mohaq bench-gate --current FILE [--baseline FILE] [--max-regress-pct PCT]

Compare a fresh bench report (Bencher::emit_json output, e.g. the CI
bench-smoke artifact) against the committed baseline and exit non-zero
when any throughput bench regressed beyond the limit. Throughputs are
normalized by each report's own 'calibration spin' section so the
verdict survives runner-speed differences; see util::benchgate.

options:
  --current FILE         fresh report to judge (required)
  --baseline FILE        committed baseline (default: BENCH_baseline.json)
  --max-regress-pct PCT  allowed normalized slowdown in percent (default: 25)
  --write-baseline       instead of gating, promote --current to the
                         baseline path verbatim (validates it parses
                         first). Run this on a quiet machine — or take
                         the CI bench-smoke artifact — and commit the
                         result to arm the gate; a baseline carrying
                         \"provisional\": true only reports.";

fn cmd_bench_gate(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{BENCH_GATE_USAGE}");
        return Ok(());
    }
    let baseline_path = args.get_or("baseline", "BENCH_baseline.json");
    let current_path = args.get("current").context("--current required (see --help)")?;
    let read = |p: &str| -> Result<mohaq::util::json::Json> {
        let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        mohaq::util::json::Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e}"))
    };
    if args.has("write-baseline") {
        let report = read(current_path)?;
        anyhow::ensure!(
            report.get("calibration spin").is_some(),
            "{current_path} has no 'calibration spin' section; a baseline without it \
             cannot be speed-normalized (is this really a Bencher::emit_json report?)"
        );
        // Copy the bytes verbatim (not a re-serialization) so the committed
        // baseline diffs cleanly against the artifact it came from. Written
        // atomically: an interrupted promote must not leave a torn baseline
        // that fails every subsequent gate run.
        let text = std::fs::read_to_string(current_path)?;
        mohaq::util::fsio::atomic_write(std::path::Path::new(baseline_path), text.as_bytes())
            .with_context(|| format!("writing {baseline_path}"))?;
        println!("bench-gate: wrote {baseline_path} from {current_path}");
        println!("commit it to arm the >{}% regression gate", args.get_f64("max-regress-pct", 25.0));
        return Ok(());
    }
    let out = mohaq::util::benchgate::gate(
        &read(baseline_path)?,
        &read(current_path)?,
        args.get_f64("max-regress-pct", 25.0),
    );
    println!("bench-gate: {baseline_path} vs {current_path}");
    for c in &out.checked {
        println!(
            "  {:<28} {:<34} {:>10.4} -> {:>10.4}  ({:+.1}%)",
            c.section, c.name, c.baseline, c.current, c.delta_pct
        );
    }
    for n in &out.notes {
        println!("  note: {n}");
    }
    for f in &out.failures {
        eprintln!("  FAIL: {f}");
    }
    if !out.passed() {
        anyhow::bail!("{} bench(es) regressed past the gate", out.failures.len());
    }
    println!("bench-gate: PASS ({} benches compared)", out.checked.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{SERVE_USAGE}");
        return Ok(());
    }
    load_platform_dir(args)?;
    let dir = args.get_or("artifacts", "artifacts");
    let session = if std::path::Path::new(dir).join("manifest.json").exists() {
        let arts = Arc::new(mohaq::runtime::Artifacts::load(dir)?);
        println!("serving artifact bundle at {dir}");
        SearchSession::new(arts)?
    } else {
        println!("no artifact bundle at {dir}; serving the hermetic surrogate evaluator");
        SearchSession::synthetic()?
    };
    let state = mohaq::serve::ServeState::new(session, args.get_usize("threads", 0));
    if let Some(cap) = args.get("cache-cap") {
        let cap: usize = cap.parse().context("--cache-cap expects an entry count")?;
        state
            .session()
            .eval()
            .set_cache_capacity(cap)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let evict_beacons = args.has("evict-beacons");
    if evict_beacons {
        state.set_evict_beacons(true);
    }
    // --store DIR: reload the eval memo a previous server saved, and save
    // it back on clean shutdown. The load runs AFTER --cache-cap /
    // --evict-beacons apply so the reloaded memo respects this server's
    // bounds; a corrupt store file is a hard typed error, never a silent
    // partial warm-start.
    let store_path = args.get("store").map(|dir| std::path::Path::new(dir).join("eval_store.json"));
    if let Some(path) = &store_path {
        let dir = path.parent().expect("store path has a parent");
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        if path.exists() {
            let report = mohaq::store::eval_store::load(path, state.session().eval(), evict_beacons)
                .map_err(|e| anyhow::anyhow!("loading eval store {}: {e}", path.display()))?;
            println!(
                "eval store: reloaded {} ({} param set(s) registered, {} skipped; \
                 {} memo entries, {} dropped)",
                path.display(),
                report.param_sets_registered,
                report.param_sets_skipped,
                report.entries_loaded,
                report.entries_dropped
            );
        } else {
            println!("eval store: {} not found; starting cold", path.display());
        }
    }
    // --store-interval SECS: a background saver bounds what a crash can
    // lose to one interval. Each snapshot goes through atomic_write
    // (inside eval_store::save), so a reader — or the startup reload of
    // the next server — sees either the previous store or this one,
    // never a torn file. The thread polls shutdown at 200ms so it never
    // delays a clean exit by more than that.
    let store_interval = args.get_usize("store-interval", 0);
    anyhow::ensure!(
        store_interval == 0 || store_path.is_some(),
        "--store-interval requires --store DIR (there is nowhere to save)"
    );
    let state_for_save = state.clone();
    let mut saver = None;
    if store_interval > 0 {
        let path = store_path.clone().expect("checked above");
        let state = state_for_save.clone();
        saver = Some(std::thread::spawn(move || {
            let interval = std::time::Duration::from_secs(store_interval as u64);
            let mut next = std::time::Instant::now() + interval;
            while !state.is_shutdown() {
                std::thread::sleep(std::time::Duration::from_millis(200));
                if std::time::Instant::now() < next || state.is_shutdown() {
                    continue;
                }
                next = std::time::Instant::now() + interval;
                match mohaq::store::eval_store::save(&path, state.session().eval()) {
                    Ok(()) => println!("eval store: periodic save -> {}", path.display()),
                    // A failed snapshot must not kill a serving process;
                    // the next tick retries.
                    Err(e) => eprintln!("eval store: periodic save FAILED: {e}"),
                }
            }
        }));
    }
    let server = mohaq::serve::Server::bind(args.get_or("addr", "127.0.0.1:7070"), state)?;
    println!("mohaq serve: listening on {}", server.local_addr()?);
    println!("(send {{\"op\":\"shutdown\"}} on any connection to stop)");
    server.run()?;
    if let Some(h) = saver {
        let _ = h.join();
    }
    if let Some(path) = &store_path {
        mohaq::store::eval_store::save(path, state_for_save.session().eval())
            .map_err(|e| anyhow::anyhow!("saving eval store {}: {e}", path.display()))?;
        println!("eval store: saved {}", path.display());
    }
    println!("mohaq serve: shut down cleanly");
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{WORKER_USAGE}");
        return Ok(());
    }
    load_platform_dir(args)?;
    let dir = args.get_or("artifacts", "artifacts");
    let session = if std::path::Path::new(dir).join("manifest.json").exists() {
        let arts = Arc::new(mohaq::runtime::Artifacts::load(dir)?);
        eprintln!("worker evaluating artifact bundle at {dir}");
        SearchSession::new(arts)?
    } else {
        eprintln!("no artifact bundle at {dir}; worker uses the hermetic surrogate evaluator");
        SearchSession::synthetic()?
    };
    let state = mohaq::serve::ServeState::worker(session, args.get_usize("threads", 0));
    if let Some(cap) = args.get("cache-cap") {
        let cap: usize = cap.parse().context("--cache-cap expects an entry count")?;
        state
            .session()
            .eval()
            .set_cache_capacity(cap)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let server = mohaq::serve::Server::bind(args.get_or("addr", "127.0.0.1:0"), state)?;
    // The announce line is machine-read by `search --spawn-workers`; keep
    // its shape stable and make sure it leaves the process immediately.
    println!("mohaq worker: listening on {}", server.local_addr()?);
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    server.run()?;
    eprintln!("mohaq worker: shut down cleanly");
    Ok(())
}

fn parse_bits_list(s: &str, n: usize) -> Result<Vec<Bits>> {
    let parsed: Vec<Bits> = s
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<u32>()
                .ok()
                .and_then(Bits::from_bits)
                .with_context(|| format!("bad bits value '{t}'"))
        })
        .collect::<Result<_>>()?;
    // A single value broadcasts to every layer: `--w 4` == `--w 4,4,...`.
    if parsed.len() == 1 && n > 1 {
        return Ok(vec![parsed[0]; n]);
    }
    anyhow::ensure!(
        parsed.len() == n,
        "expected 1 or {n} comma-separated precisions, got {}",
        parsed.len()
    );
    Ok(parsed)
}

fn cmd_info(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("usage: mohaq info [--artifacts DIR]\n\nPrint a summary of the artifact bundle.");
        return Ok(());
    }
    let arts = mohaq::runtime::Artifacts::load(args.get_or("artifacts", "artifacts"))?;
    println!("artifact bundle: {}", arts.dir.display());
    println!("  layers: {:?}", arts.layer_names);
    println!(
        "  lowered batch {} x seq {} x feat {}, {} classes",
        arts.batch, arts.seq_len, arts.feat_dim, arts.num_classes
    );
    println!(
        "  splits: train {} seqs, val {}x{} seqs, test {} seqs",
        arts.train.num_seqs,
        arts.val_subsets.len(),
        arts.val_subsets.first().map(|s| s.num_seqs).unwrap_or(0),
        arts.test.num_seqs
    );
    println!(
        "  baseline: val {:.2}% (16-bit {:.2}%), test {:.2}%",
        arts.baseline.val_err * 100.0,
        arts.baseline.val_err_16bit * 100.0,
        arts.baseline.test_err * 100.0
    );
    println!("  params: {} tensors", arts.tensors.len());
    Ok(())
}

fn cmd_table4(args: &Args) -> Result<()> {
    if args.has("help") {
        println!(
            "usage: mohaq table4 [--artifacts DIR]\n\nPrint the model op/param breakdown (paper Table 4)."
        );
        return Ok(());
    }
    let arts = mohaq::runtime::Artifacts::load(args.get_or("artifacts", "artifacts"))?;
    println!("{}", arts.model.table4());
    Ok(())
}

/// Apply `--platform-dir DIR`: load every manifest in DIR into the
/// process registry. Announced on stderr so commands with machine-read
/// stdout (the worker announce line) stay clean.
fn load_platform_dir(args: &Args) -> Result<()> {
    if let Some(dir) = args.get("platform-dir") {
        let names = registry::load_manifest_dir(dir).map_err(|e| anyhow::anyhow!("{e}"))?;
        eprintln!("loaded {} platform manifest(s) from {dir}: {}", names.len(), names.join(", "));
    }
    Ok(())
}

fn cmd_platforms(args: &Args) -> Result<()> {
    load_platform_dir(args)?;
    println!("registered platforms (hw::registry):");
    for (name, source) in registry::known_platforms_with_sources() {
        let p = registry::resolve(&registry::PlatformSpec::new(&name))
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let marker = match source {
            registry::PlatformSource::Builtin => String::new(),
            other => format!("  (source: {other})"),
        };
        println!(
            "  {name:<14} tied W=A: {:<5}  energy model: {:<5}  default SRAM: {}{marker}",
            p.tied_wa(),
            p.has_energy_model(),
            p.sram_bytes()
                .map(|b| format!("{:.1} MB", b / (1024.0 * 1024.0)))
                .unwrap_or_else(|| "-".into()),
        );
    }
    println!("\nregister custom backends via mohaq::hw::registry::register,");
    println!("or load manifest files with --platform-dir DIR / register_manifest");
    println!("(see examples/custom_platform.rs and examples/manifest_platform.rs)");
    Ok(())
}

/// `mohaq platform lint [FILE|DIR ...]` — validate manifests and print
/// their resolved capability summaries.
fn cmd_platform(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{PLATFORM_USAGE}");
        return Ok(());
    }
    let sub = args.positional.get(1).map(|s| s.as_str());
    anyhow::ensure!(
        sub == Some("lint"),
        "unknown platform subcommand {:?}\n\n{PLATFORM_USAGE}",
        sub.unwrap_or("<none>")
    );
    let mut targets: Vec<String> = args.positional[2..].to_vec();
    if targets.is_empty() {
        targets.push("platforms".into());
    }
    // Expand directories to their sorted *.json files so the report
    // order is deterministic.
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    for t in &targets {
        let path = std::path::Path::new(t);
        if path.is_dir() {
            let mut batch: Vec<std::path::PathBuf> = std::fs::read_dir(path)
                .with_context(|| format!("reading directory {t}"))?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().is_some_and(|ext| ext == "json"))
                .collect();
            batch.sort();
            anyhow::ensure!(!batch.is_empty(), "{t} contains no *.json manifest files");
            files.extend(batch);
        } else {
            files.push(path.to_path_buf());
        }
    }
    let model = mohaq::model::ModelDesc::paper();
    let mut failures = 0usize;
    for file in &files {
        match mohaq::hw::PlatformManifest::load_file(file) {
            Err(e) => {
                failures += 1;
                eprintln!("FAIL {}: {e}", file.display());
            }
            Ok(m) => {
                // from_manifest re-validates; with the load green it
                // cannot fail, but route the error anyway.
                let p = mohaq::hw::TabularPlatform::from_manifest(&m)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", file.display()))?;
                let best_bits = m.supported_bits[0];
                let qc = QuantConfig::uniform(model.layers.len(), best_bits, best_bits);
                println!("OK   {}: {}", file.display(), m.summary());
                println!(
                    "       paper-model speedup at uniform {}-bit: {:.2}x",
                    best_bits.bits(),
                    p.speedup(&model, &qc)
                );
            }
        }
    }
    anyhow::ensure!(
        failures == 0,
        "{failures} of {} manifest(s) failed validation",
        files.len()
    );
    println!("platform lint: {} manifest(s) OK", files.len());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{EVAL_USAGE}");
        return Ok(());
    }
    let arts = Arc::new(mohaq::runtime::Artifacts::load(args.get_or("artifacts", "artifacts"))?);
    let n = arts.layer_names.len();
    let w = parse_bits_list(args.get("w").context("--w required (see --help)")?, n)?;
    let a = match args.get("a") {
        Some(s) => parse_bits_list(s, n)?,
        None => w.clone(),
    };
    let qc = QuantConfig { w_bits: w, a_bits: a };
    let rt = mohaq::runtime::Runtime::cpu()?;
    let eval = mohaq::eval::EvalService::new(&rt, arts.clone())?;
    let val = eval.val_error(&qc, 0)?;
    let test = eval.test_error(&qc, 0)?;
    println!("config      : {}", qc.display_wa());
    println!("WER_V       : {:.2}%", val * 100.0);
    println!("WER_T       : {:.2}%", test * 100.0);
    println!("Cp_r        : {:.1}x", arts.model.compression_ratio(&qc.w_bits));
    println!(
        "size        : {:.3} MB",
        arts.model.size_bytes(&qc.w_bits) / (1024.0 * 1024.0)
    );
    Ok(())
}

/// Build a cross-platform spec from `--platforms a,b` plus an optional
/// `--objectives` list: explicit `metric@platform` tokens pass through
/// the typed parser unchanged; bare hardware metrics expand across every
/// listed platform (energy only where the platform has an energy model).
fn spec_from_platform_flags(platforms: &str, objectives: Option<&str>) -> Result<ExperimentSpec> {
    let names: Vec<String> = platforms
        .split(',')
        .map(|s| s.trim().to_lowercase())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!names.is_empty(), "--platforms needs at least one platform name");

    // Resolve up front: validates the names and exposes capabilities for
    // the energy expansion below.
    let mut resolved = Vec::with_capacity(names.len());
    for name in &names {
        resolved.push(registry::resolve(&registry::PlatformSpec::new(name))?);
    }

    let mut b = ExperimentSpec::builder().name(format!("cross-{}", names.join("-")));
    for name in &names {
        b = b.platform(name.clone());
    }
    // A metric from the DEFAULT list that no listed platform supports is
    // dropped silently (the user never asked for it); an explicitly
    // passed one errors below.
    let explicit = objectives.is_some();
    for token in objectives.unwrap_or("error,neg_speedup,energy_uj").split(',') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        let obj = ScoredObjective::parse(token)?;
        if let Some(p) = obj.platform() {
            // An out-of-list platform would silently join the table (and
            // add its SRAM constraint); demand it be listed explicitly.
            anyhow::ensure!(
                names.iter().any(|n| n.as_str() == p),
                "objective '{token}' names platform '{p}' which is not in --platforms ({}); \
                 list it there so its constraints are explicit",
                names.join(", ")
            );
            b = b.objective(obj);
            continue;
        }
        if !obj.needs_platform() {
            b = b.objective(obj);
            continue;
        }
        // Bare hardware metric: one objective per capable platform.
        let mut bound_any = false;
        for (name, p) in names.iter().zip(&resolved) {
            if obj.needs_energy_model() && !p.has_energy_model() {
                eprintln!("note: skipping energy_uj@{name} (no energy model)");
                continue;
            }
            b = b.objective(obj.clone().on(name.clone()));
            bound_any = true;
        }
        anyhow::ensure!(
            bound_any || !explicit,
            "objective '{token}' has no capable platform among: {}",
            names.join(", ")
        );
    }
    Ok(b.build()?)
}

/// Child worker processes spawned for one `--spawn-workers` search;
/// killed (and reaped) on drop so no exit path leaks them.
struct SpawnedWorkers(Vec<std::process::Child>);

impl Drop for SpawnedWorkers {
    fn drop(&mut self) {
        for child in &mut self.0 {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Spawn `n` local `mohaq worker` processes on ephemeral ports (each
/// re-executes the current binary) and return them with the addresses
/// they announced on stdout.
fn spawn_workers(n: usize, dir: &str, threads: usize) -> Result<(SpawnedWorkers, Vec<String>)> {
    use std::io::BufRead;
    let exe = std::env::current_exe().context("locating the mohaq binary")?;
    let mut children = SpawnedWorkers(Vec::with_capacity(n));
    let mut addrs = Vec::with_capacity(n);
    for i in 0..n {
        let mut child = std::process::Command::new(&exe)
            .args(["worker", "--addr", "127.0.0.1:0", "--artifacts", dir])
            .args(["--threads", &threads.to_string()])
            .stdout(std::process::Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning worker {i}"))?;
        let stdout = child.stdout.take().context("worker stdout unavailable")?;
        children.0.push(child);
        let mut reader = std::io::BufReader::new(stdout);
        let addr = loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                anyhow::bail!("worker {i} exited before announcing its address");
            }
            if let Some(rest) = line.trim().strip_prefix("mohaq worker: listening on ") {
                break rest.to_string();
            }
        };
        // Keep draining so the child never blocks on a full stdout pipe.
        std::thread::spawn(move || for _ in reader.lines() {});
        addrs.push(addr);
    }
    Ok((children, addrs))
}

fn cmd_search(args: &Args) -> Result<()> {
    if args.has("help") {
        println!("{SEARCH_USAGE}");
        return Ok(());
    }
    load_platform_dir(args)?;
    let dir = args.get_or("artifacts", "artifacts");
    let distributed = args.get("workers").is_some() || args.get("spawn-workers").is_some();
    // --synthetic forces the surrogate; distributed runs fall back to it
    // without a bundle (matching serve/worker) so the whole stack works
    // offline; other local runs keep the hard artifact requirement.
    let session = if args.has("synthetic") {
        println!("searching the hermetic surrogate evaluator (--synthetic)");
        SearchSession::synthetic()?
    } else if !std::path::Path::new(dir).join("manifest.json").exists() && distributed {
        println!("no artifact bundle at {dir}; searching the hermetic surrogate evaluator");
        SearchSession::synthetic()?
    } else {
        SearchSession::new(Arc::new(mohaq::runtime::Artifacts::load(dir)?))?
    };
    let arts = session.artifacts().clone();
    // --resume FILE: the checkpoint carries the complete spec of the
    // interrupted run, so every spec-shaping flag is rejected — a resumed
    // search that silently diverged from the original would void the
    // bitwise-identical-front contract.
    let resume = match args.get("resume") {
        None => None,
        Some(path) => {
            for flag in [
                "exp",
                "config",
                "beacon",
                "platforms",
                "objectives",
                "gens",
                "seed",
                "islands",
                "migration-interval",
                "topology",
                "migrants",
            ] {
                anyhow::ensure!(
                    !args.has(flag),
                    "--{flag} cannot be combined with --resume: the checkpoint carries the \
                     full spec of the interrupted run"
                );
            }
            let ckpt = mohaq::store::SearchCheckpoint::load(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("loading checkpoint {path}: {e}"))?;
            println!(
                "loaded checkpoint {path}: '{}' at generation {} ({} islands)",
                ckpt.spec.name,
                ckpt.generation,
                ckpt.islands()
            );
            Some(ckpt)
        }
    };
    let mut spec = if let Some(ckpt) = &resume {
        ckpt.spec.clone()
    } else if let Some(cfg) = args.get("config") {
        // Refuse to silently discard flags the chosen spec source ignores.
        anyhow::ensure!(
            args.get("platforms").is_none() && args.get("objectives").is_none(),
            "--platforms/--objectives cannot be combined with --config (edit the config instead)"
        );
        mohaq::config::spec_from_file(cfg)?
    } else if let Some(platforms) = args.get("platforms") {
        spec_from_platform_flags(platforms, args.get("objectives"))?
    } else {
        anyhow::ensure!(
            args.get("objectives").is_none(),
            "--objectives requires --platforms (the presets fix their objective set)"
        );
        match args.get_or("exp", "exp1") {
            "exp1" => ExperimentSpec::exp1(),
            "exp2" => ExperimentSpec::exp2_silago(),
            "exp3" => ExperimentSpec::exp3_bitfusion(args.has("beacon")),
            "cross" | "cross_platform" => ExperimentSpec::cross_platform(),
            other => anyhow::bail!("unknown experiment '{other}' (see --help)"),
        }
    };
    if let Some(g) = args.get("gens") {
        spec.ga.generations = g.parse()?;
    }
    spec.ga.seed = args.get_u64("seed", spec.ga.seed);

    if args.has("islands")
        || args.has("migration-interval")
        || args.has("topology")
        || args.has("migrants")
    {
        // Without an explicit --islands (or a spec-provided config), tuning
        // flags alone keep the single-population engine (islands = 1).
        let mut cfg = spec
            .island
            .clone()
            .unwrap_or_else(|| mohaq::moo::IslandConfig { islands: 1, ..Default::default() });
        cfg.islands = args.get_usize("islands", cfg.islands);
        cfg.migration_interval = args.get_usize("migration-interval", cfg.migration_interval);
        cfg.migrants = args.get_usize("migrants", cfg.migrants);
        if let Some(t) = args.get("topology") {
            cfg.topology = Topology::from_id(t)
                .with_context(|| format!("unknown topology '{t}' (ring|full)"))?;
        }
        cfg.validate(spec.ga.pop_size)
            .map_err(|e| anyhow::anyhow!("island config: {e}"))?;
        spec.island = Some(cfg);
    }

    let session = session.threads(args.get_usize("threads", 0));

    // --store DIR: reload the durable eval store BEFORE the search runs,
    // so a beacon checkpoint's parameter-set names resolve against the
    // reloaded sets (a resume referencing a set the store lacks is
    // rejected, not silently retrained); saved back after the run.
    let search_store =
        args.get("store").map(|dir| std::path::Path::new(dir).join("eval_store.json"));
    if let Some(path) = &search_store {
        let dir = path.parent().expect("store path has a parent");
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        if path.exists() {
            let report = mohaq::store::eval_store::load(path, session.eval(), false)
                .map_err(|e| anyhow::anyhow!("loading eval store {}: {e}", path.display()))?;
            println!(
                "eval store: reloaded {} ({} param set(s) registered, {} skipped; \
                 {} memo entries, {} dropped)",
                path.display(),
                report.param_sets_registered,
                report.param_sets_skipped,
                report.entries_loaded,
                report.entries_dropped
            );
        } else {
            println!("eval store: {} not found; starting cold", path.display());
        }
    }

    // Distributed setup: collect worker addresses (named + spawned) and
    // make sure there is an island config to shard.
    let mut addrs: Vec<String> = args
        .get("workers")
        .map(|s| s.split(',').map(|t| t.trim().to_string()).filter(|t| !t.is_empty()).collect())
        .unwrap_or_default();
    let mut _spawned = None;
    if distributed {
        let n = args.get_usize("spawn-workers", 0);
        if n > 0 {
            let (guard, spawned_addrs) = spawn_workers(n, dir, args.get_usize("threads", 0))?;
            addrs.extend(spawned_addrs);
            _spawned = Some(guard);
        }
        anyhow::ensure!(!addrs.is_empty(), "--workers/--spawn-workers named no workers");
        if spec.island.is_none() {
            // One island per worker is the natural default; the merged
            // front still only depends on (seed, island config), not on
            // how the islands land on workers.
            let cfg = mohaq::moo::IslandConfig {
                islands: addrs.len(),
                ..Default::default()
            };
            cfg.validate(spec.ga.pop_size)
                .map_err(|e| anyhow::anyhow!("island config: {e}"))?;
            println!(
                "note: defaulting to {} island(s), one per worker (pass --islands to control)",
                cfg.islands
            );
            spec.island = Some(cfg);
        }
    }

    let on_event = |event: &SearchEvent| match event {
        SearchEvent::Started { name, num_vars, objectives, threads, islands } => {
            if *islands > 1 {
                println!(
                    "search '{name}': {num_vars} vars, {islands} islands, {threads} eval threads"
                );
            } else {
                println!("search '{name}': {num_vars} vars, {threads} eval threads");
            }
            println!("  objectives: {}", objectives.join(", "));
        }
        SearchEvent::BeaconCreated { name, retrain_steps } => {
            println!("  beacon created: {name} ({retrain_steps} steps)");
        }
        SearchEvent::Generation(log) => println!("{log}"),
        SearchEvent::Migration { generation, from, to, accepted } => {
            println!("  gen {generation:>3}  migration: island {from} -> island {to} ({accepted} elites)");
        }
        SearchEvent::ShardAssigned { worker, islands } => {
            println!("  worker {worker}: islands {islands:?}");
        }
        SearchEvent::ShardLost { worker, islands, retry } => {
            println!(
                "  worker {worker} LOST (islands {islands:?}); re-sharding onto survivors (retry {retry})"
            );
        }
        SearchEvent::Finished { .. } => {}
    };

    // --checkpoint FILE: persist (spec, generation, island snapshots,
    // finalized beacons) at every migration boundary, atomically. Only
    // island-model searches have boundaries, so anything else is
    // rejected up front.
    let checkpoint_path = args.get("checkpoint").map(std::path::PathBuf::from);
    if let Some(p) = &checkpoint_path {
        anyhow::ensure!(
            spec.island.as_ref().is_some_and(|c| c.islands >= 2),
            "--checkpoint {} needs an island config with >= 2 islands — checkpoints are \
             written at migration boundaries (pass --islands K)",
            p.display()
        );
    }
    let stop_after = args.get_usize("stop-after-checkpoints", 0);
    anyhow::ensure!(
        stop_after == 0 || checkpoint_path.is_some(),
        "--stop-after-checkpoints requires --checkpoint"
    );
    let spec_for_ckpt = spec.clone();
    let eval_for_ckpt = session.eval().clone();
    let store_for_ckpt = search_store.clone();
    let mut written = 0usize;
    let mut sink = |gen: usize,
                    snaps: &[mohaq::moo::IslandSnapshot],
                    beacons: &[mohaq::coordinator::BeaconSnapshot]| {
        let path = checkpoint_path.as_deref().expect("sink only installed with --checkpoint");
        match mohaq::store::SearchCheckpoint::new(
            spec_for_ckpt.clone(),
            gen,
            snaps.to_vec(),
            beacons.to_vec(),
        )
        .and_then(|c| c.save(path))
        {
            // A failed write must not kill a running search: a checkpoint
            // is a recovery aid, and losing one is strictly better than
            // losing the run.
            Err(e) => eprintln!("  checkpoint: FAILED writing {}: {e}", path.display()),
            Ok(()) => {
                written += 1;
                println!("  checkpoint: generation {gen} -> {}", path.display());
                if stop_after > 0 && written >= stop_after {
                    // The simulated crash must still leave a loadable
                    // eval store: a beacon checkpoint references its
                    // parameter sets by name, and the resume resolves
                    // them against --store.
                    if let Some(sp) = &store_for_ckpt {
                        match mohaq::store::eval_store::save(sp, &eval_for_ckpt) {
                            Ok(()) => println!("eval store: saved {}", sp.display()),
                            Err(e) => {
                                eprintln!("eval store: FAILED saving {}: {e}", sp.display())
                            }
                        }
                    }
                    println!(
                        "stopping after {written} checkpoint(s) as requested \
                         (--stop-after-checkpoints); continue with --resume {}",
                        path.display()
                    );
                    std::process::exit(0);
                }
            }
        }
    };
    let sink_opt: Option<
        &mut dyn FnMut(usize, &[mohaq::moo::IslandSnapshot], &[mohaq::coordinator::BeaconSnapshot]),
    > = if checkpoint_path.is_some() { Some(&mut sink) } else { None };

    let cancel = mohaq::coordinator::CancelToken::new();
    let dist_cfg = mohaq::dist::DistConfig::default();
    let outcome = match (resume, distributed) {
        (Some(ckpt), true) => session.run_distributed_resumable(
            &spec,
            &addrs,
            &dist_cfg,
            Some((ckpt.generation, ckpt.snapshots, ckpt.beacons)),
            sink_opt,
            on_event,
            &cancel,
        )?,
        (Some(ckpt), false) => session.run_resumed(
            &spec,
            ckpt.generation,
            ckpt.snapshots,
            ckpt.beacons,
            on_event,
            sink_opt,
            &cancel,
        )?,
        (None, true) => session.run_distributed_resumable(
            &spec,
            &addrs,
            &dist_cfg,
            None,
            sink_opt,
            on_event,
            &cancel,
        )?,
        (None, false) => session.run_checkpointed(&spec, on_event, sink_opt, &cancel)?,
    };
    println!(
        "\n{}",
        report::render_table(&outcome.rows, &baseline_rows(&arts), &arts)
    );
    if let Some(hv) = outcome.front_hypervolume {
        println!("front hypervolume (nadir-referenced): {hv:.4}\n");
    }
    println!("{}", report::summary_md(&outcome));
    if let Some(out_dir) = args.get("out") {
        std::fs::create_dir_all(out_dir)?;
        report::write_front_csv(format!("{out_dir}/front.csv"), &outcome.rows)?;
        report::write_records_csv(format!("{out_dir}/records.csv"), &outcome)?;
        println!("wrote {out_dir}/");
    }
    if let Some(path) = &search_store {
        mohaq::store::eval_store::save(path, session.eval())
            .map_err(|e| anyhow::anyhow!("saving eval store {}: {e}", path.display()))?;
        println!("eval store: saved {}", path.display());
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "table4" => cmd_table4(&args),
        "platforms" => cmd_platforms(&args),
        "platform" => cmd_platform(&args),
        "eval" => cmd_eval(&args),
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "bench-gate" => cmd_bench_gate(&args),
        "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}
