//! MOHAQ command-line launcher.
//!
//! Subcommands:
//!   info                          artifact bundle summary
//!   table4                        model op/param breakdown (paper Table 4)
//!   eval    --w 4,4,... --a 8,... score one quantization config
//!   search  --exp exp1|exp2|exp3  run a full experiment
//!           [--beacon] [--gens N] [--seed N] [--out DIR]
//!
//! Global: --artifacts DIR (default ./artifacts, built by `make artifacts`).

use std::rc::Rc;

use anyhow::{Context, Result};

use mohaq::coordinator::{baseline_rows, run_search, ExperimentSpec};
use mohaq::quant::{Bits, QuantConfig};
use mohaq::report;
use mohaq::util::cli::Args;

fn parse_bits_list(s: &str, n: usize) -> Result<Vec<Bits>> {
    let v: Vec<Bits> = s
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<u32>()
                .ok()
                .and_then(Bits::from_bits)
                .with_context(|| format!("bad bits value '{t}'"))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(v.len() == n, "expected {n} comma-separated precisions, got {}", v.len());
    Ok(v)
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let dir = args.get_or("artifacts", "artifacts");

    match cmd {
        "info" => {
            let arts = mohaq::runtime::Artifacts::load(dir)?;
            println!("artifact bundle: {}", arts.dir.display());
            println!("  layers: {:?}", arts.layer_names);
            println!(
                "  lowered batch {} x seq {} x feat {}, {} classes",
                arts.batch, arts.seq_len, arts.feat_dim, arts.num_classes
            );
            println!(
                "  splits: train {} seqs, val {}x{} seqs, test {} seqs",
                arts.train.num_seqs,
                arts.val_subsets.len(),
                arts.val_subsets.first().map(|s| s.num_seqs).unwrap_or(0),
                arts.test.num_seqs
            );
            println!(
                "  baseline: val {:.2}% (16-bit {:.2}%), test {:.2}%",
                arts.baseline.val_err * 100.0,
                arts.baseline.val_err_16bit * 100.0,
                arts.baseline.test_err * 100.0
            );
            println!("  params: {} tensors", arts.tensors.len());
        }
        "table4" => {
            let arts = mohaq::runtime::Artifacts::load(dir)?;
            println!("{}", arts.model.table4());
        }
        "eval" => {
            let arts = Rc::new(mohaq::runtime::Artifacts::load(dir)?);
            let n = arts.layer_names.len();
            let w = parse_bits_list(args.get("w").context("--w required")?, n)?;
            let a = match args.get("a") {
                Some(s) => parse_bits_list(s, n)?,
                None => w.clone(),
            };
            let qc = QuantConfig { w_bits: w, a_bits: a };
            let rt = mohaq::runtime::Runtime::cpu()?;
            let mut eval = mohaq::eval::EvalService::new(&rt, arts.clone())?;
            let val = eval.val_error(&qc, 0)?;
            let test = eval.test_error(&qc, 0)?;
            println!("config      : {}", qc.display_wa());
            println!("WER_V       : {:.2}%", val * 100.0);
            println!("WER_T       : {:.2}%", test * 100.0);
            println!("Cp_r        : {:.1}x", arts.model.compression_ratio(&qc.w_bits));
            println!(
                "size        : {:.3} MB",
                arts.model.size_bytes(&qc.w_bits) / (1024.0 * 1024.0)
            );
        }
        "search" => {
            let arts = Rc::new(mohaq::runtime::Artifacts::load(dir)?);
            let rt = mohaq::runtime::Runtime::cpu()?;
            let mut spec = if let Some(cfg) = args.get("config") {
                mohaq::config::spec_from_file(cfg)?
            } else {
                match args.get_or("exp", "exp1") {
                    "exp1" => ExperimentSpec::exp1(),
                    "exp2" => ExperimentSpec::exp2_silago(),
                    "exp3" => ExperimentSpec::exp3_bitfusion(args.has("beacon")),
                    other => anyhow::bail!("unknown experiment '{other}'"),
                }
            };
            if let Some(g) = args.get("gens") {
                spec.ga.generations = g.parse()?;
            }
            spec.ga.seed = args.get_u64("seed", spec.ga.seed);
            let outcome = run_search(&spec, arts.clone(), &rt, true)?;
            println!(
                "\n{}",
                report::render_table(&outcome.rows, &baseline_rows(&arts), &arts)
            );
            println!("{}", report::summary_md(&outcome));
            if let Some(out_dir) = args.get("out") {
                std::fs::create_dir_all(out_dir)?;
                report::write_front_csv(format!("{out_dir}/front.csv"), &outcome.rows)?;
                report::write_records_csv(format!("{out_dir}/records.csv"), &outcome)?;
                println!("wrote {out_dir}/");
            }
        }
        _ => {
            println!("mohaq — Multi-Objective Hardware-Aware Quantization");
            println!("usage: mohaq <info|table4|eval|search> [--artifacts DIR] ...");
            println!("  mohaq eval --w 4,4,4,2,4,4,4,4 [--a 16,8,...]");
            println!("  mohaq search --exp exp3 --beacon --gens 60 --out out/exp3");
            println!("  mohaq search --config my_experiment.json");
        }
    }
    Ok(())
}
