//! Parameter-set store: the authoritative table of model parameter sets.
//!
//! Index 0 is the baseline pre-trained model; beacon retraining (paper
//! §4.3) registers additional sets. The table used to be private state
//! inside `EvalService`; it is a first-class layer now so the distributed
//! fleet can replicate beacon sets across processes:
//!
//!   * [`LocalParamStore`] — the in-process table, bit-for-bit the
//!     behavior `EvalService` always had: append-only ids, tombstone
//!     eviction (ids stay reserved), poison-aware typed errors, and an
//!     optional device uploader so registered sets become PJRT-resident
//!     exactly once.
//!   * [`ReplicatedParamStore`] — the same table plus a replication role.
//!     The coordinator holds the `Authority` side (its set list is the
//!     truth; [`ReplicatedParamStore::sets_since`] is the journal the
//!     fleet ships at migration boundaries) and every worker holds a
//!     `Replica` (sets arrive through `param_push` wire ops and land via
//!     [`ReplicatedParamStore::apply_push`], which enforces index
//!     contiguity so replica ids are always identical to authority ids —
//!     the surrogate's jitter and the memo keys both hash the set index,
//!     so id identity is what makes distributed fronts bitwise-equal to
//!     single-process ones).
//!
//! Eviction STAYS an `EvalService` affair (`evict_param_set`): the memo
//! purge and the `param_sets_evicted` counter live next to the cache, so
//! callers must retire sets through the service, not the raw store.

use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::runtime::DeviceTensor;

pub struct ParamSet {
    pub name: String,
    /// Host copy (beacon sets need it as the start point of further runs
    /// and for the final report).
    pub host: Vec<Vec<f32>>,
    /// Device-resident copy when the owning store has an uploader.
    bufs: Vec<DeviceTensor>,
    /// Tombstone: the set was retired through
    /// `EvalService::evict_param_set` — its host/device memory is freed,
    /// its index stays reserved so later sets keep their ids, and any
    /// attempt to evaluate against it is a typed error.
    evicted: bool,
}

impl ParamSet {
    /// Device buffers uploaded at registration (empty on surrogate
    /// engines and tombstones).
    pub fn device_bufs(&self) -> &[DeviceTensor] {
        &self.bufs
    }

    pub fn is_evicted(&self) -> bool {
        self.evicted
    }
}

/// Uploads one set's host tensors to the device at registration time.
/// `EvalService` installs one over its PJRT executor; surrogate services
/// install none. Living IN the store (rather than at the call site) is
/// what lets replicated pushes land device-resident on PJRT workers
/// without the replication path knowing about engines.
pub type ParamUploader = Box<dyn Fn(&[Vec<f32>]) -> Result<Vec<DeviceTensor>> + Send + Sync>;

/// The parameter-set table behind a trait so `EvalService` (and the
/// beacon finalize path) read through it the same way in-process and
/// across the fleet. Every method surfaces lock poisoning as the typed
/// "poisoned" error `SearchError` classifies — never a second panic.
pub trait ParamStore: Send + Sync {
    /// Register a set; returns its id (append-only, never reused).
    fn add(&self, name: &str, host: Vec<Vec<f32>>) -> Result<usize>;

    /// Fetch a live set. Out-of-range and tombstoned ids are typed
    /// errors.
    fn get(&self, idx: usize) -> Result<Arc<ParamSet>>;

    /// Tombstone a set, freeing its host/device memory but reserving its
    /// id. Returns `true` the first time, `false` when already retired
    /// (idempotent). Index 0 — the baseline — is not evictable. Callers
    /// outside `EvalService::evict_param_set` must not use this: the
    /// memo purge lives there.
    fn evict(&self, idx: usize) -> Result<bool>;

    /// Registered slots, tombstones included (ids are dense).
    fn len(&self) -> Result<usize>;

    fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Live (non-evicted) sets with their ids, ascending.
    fn snapshot(&self) -> Result<Vec<(usize, Arc<ParamSet>)>>;

    /// Poison the table lock by panicking while holding it — the
    /// regression hook behind `EvalService::poison_param_sets_for_test`.
    #[doc(hidden)]
    fn poison_for_test(&self);
}

/// In-process store: exactly the table `EvalService` used to own.
pub struct LocalParamStore {
    sets: RwLock<Vec<Arc<ParamSet>>>,
    uploader: Option<ParamUploader>,
}

impl LocalParamStore {
    pub fn new(uploader: Option<ParamUploader>) -> LocalParamStore {
        LocalParamStore { sets: RwLock::new(Vec::new()), uploader }
    }

    fn read(&self) -> Result<std::sync::RwLockReadGuard<'_, Vec<Arc<ParamSet>>>> {
        self.sets.read().map_err(|_| {
            anyhow::anyhow!("param sets poisoned: a worker panicked while holding the lock")
        })
    }

    fn write(&self) -> Result<std::sync::RwLockWriteGuard<'_, Vec<Arc<ParamSet>>>> {
        self.sets.write().map_err(|_| {
            anyhow::anyhow!("param sets poisoned: a worker panicked while holding the lock")
        })
    }
}

impl ParamStore for LocalParamStore {
    fn add(&self, name: &str, host: Vec<Vec<f32>>) -> Result<usize> {
        // Every set must shape-match the baseline (set 0) — the one
        // structural invariant the store can enforce without knowing the
        // artifact (`EvalService::add_param_set` still validates against
        // the manifest first on its path).
        {
            let sets = self.read()?;
            if let Some(base) = sets.first() {
                anyhow::ensure!(
                    host.len() == base.host.len(),
                    "param set has {} tensors, the baseline has {}",
                    host.len(),
                    base.host.len()
                );
            }
        }
        // Upload OUTSIDE the lock: device transfers are slow and must
        // not block concurrent readers (in-flight evaluations).
        let bufs = match &self.uploader {
            Some(up) => up(&host)?,
            None => Vec::new(),
        };
        let mut sets = self.write()?;
        sets.push(Arc::new(ParamSet { name: name.to_string(), host, bufs, evicted: false }));
        Ok(sets.len() - 1)
    }

    fn get(&self, idx: usize) -> Result<Arc<ParamSet>> {
        let sets = self.read()?;
        let set = sets.get(idx).cloned().ok_or_else(|| {
            anyhow::anyhow!("parameter set {idx} out of range ({} registered)", sets.len())
        })?;
        anyhow::ensure!(!set.evicted, "parameter set {idx} ('{}') was evicted", set.name);
        Ok(set)
    }

    fn evict(&self, idx: usize) -> Result<bool> {
        anyhow::ensure!(idx != 0, "parameter set 0 is the baseline and cannot be evicted");
        let mut sets = self.write()?;
        let slot = sets.get_mut(idx).ok_or_else(|| {
            anyhow::anyhow!("parameter set {idx} out of range ({} registered)", sets.len())
        })?;
        if slot.evicted {
            return Ok(false); // already retired — idempotent
        }
        let name = slot.name.clone();
        *slot = Arc::new(ParamSet { name, host: Vec::new(), bufs: Vec::new(), evicted: true });
        Ok(true)
    }

    fn len(&self) -> Result<usize> {
        Ok(self.read()?.len())
    }

    fn snapshot(&self) -> Result<Vec<(usize, Arc<ParamSet>)>> {
        let sets = self.read()?;
        Ok(sets
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.evicted)
            .map(|(i, s)| (i, s.clone()))
            .collect())
    }

    fn poison_for_test(&self) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = self.sets.write();
            panic!("poisoning param sets");
        }));
    }
}

/// Which side of the replication protocol a store plays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreRole {
    /// The coordinator: its set list is the truth, `sets_since` is the
    /// journal shipped to the fleet.
    Authority,
    /// A worker: sets only arrive through `apply_push`, in index order.
    Replica,
}

/// A [`ParamStore`] participating in fleet replication. Plain store
/// operations delegate to the wrapped table unchanged; the replication
/// surface (`sets_since` / `apply_push`) is role-checked so a worker can
/// never invent authoritative ids and the coordinator can never be fed
/// pushes.
pub struct ReplicatedParamStore {
    inner: Arc<dyn ParamStore>,
    role: StoreRole,
}

impl ReplicatedParamStore {
    pub fn authority(inner: Arc<dyn ParamStore>) -> ReplicatedParamStore {
        ReplicatedParamStore { inner, role: StoreRole::Authority }
    }

    pub fn replica(inner: Arc<dyn ParamStore>) -> ReplicatedParamStore {
        ReplicatedParamStore { inner, role: StoreRole::Replica }
    }

    pub fn role(&self) -> StoreRole {
        self.role
    }

    /// Authority journal: every live set with id >= `from`, ascending.
    /// The fleet replays this to (re)connecting workers — `from = 1`
    /// ships all beacons (the baseline is re-derived from the artifacts
    /// on every process and is never replicated).
    pub fn sets_since(&self, from: usize) -> Result<Vec<(usize, Arc<ParamSet>)>> {
        anyhow::ensure!(
            self.role == StoreRole::Authority,
            "sets_since is an authority operation; this store is a replica"
        );
        let mut sets = self.inner.snapshot()?;
        sets.retain(|(i, _)| *i >= from);
        Ok(sets)
    }

    /// Replica apply: land one replicated set at exactly `index`.
    /// Returns `true` when newly registered, `false` when the push is a
    /// duplicate of a set already held (re-pushes happen on every worker
    /// reconnect — idempotence is what makes `ShardLost` replay safe).
    /// Gaps, id-0 pushes and name mismatches are typed errors: replica
    /// ids must be identical to authority ids (the memo keys and the
    /// surrogate jitter both hash the id).
    pub fn apply_push(&self, index: usize, name: &str, host: Vec<Vec<f32>>) -> Result<bool> {
        anyhow::ensure!(
            self.role == StoreRole::Replica,
            "apply_push is a replica operation; this store is the authority"
        );
        anyhow::ensure!(index != 0, "param push for set 0: the baseline is never replicated");
        let len = self.inner.len()?;
        if index < len {
            let existing = self.inner.get(index)?;
            anyhow::ensure!(
                existing.name == name,
                "param push for set {index} carries name '{name}', replica already holds '{}'",
                existing.name
            );
            anyhow::ensure!(
                existing.host.len() == host.len(),
                "param push for set {index} ('{name}') carries {} tensors, replica holds {}",
                host.len(),
                existing.host.len()
            );
            return Ok(false);
        }
        anyhow::ensure!(
            index == len,
            "param push for set {index} leaves a gap: replica holds {len} sets \
             (pushes must arrive in index order)"
        );
        let got = self.inner.add(name, host)?;
        debug_assert_eq!(got, index);
        Ok(true)
    }
}

impl ParamStore for ReplicatedParamStore {
    fn add(&self, name: &str, host: Vec<Vec<f32>>) -> Result<usize> {
        self.inner.add(name, host)
    }

    fn get(&self, idx: usize) -> Result<Arc<ParamSet>> {
        self.inner.get(idx)
    }

    fn evict(&self, idx: usize) -> Result<bool> {
        self.inner.evict(idx)
    }

    fn len(&self) -> Result<usize> {
        self.inner.len()
    }

    fn snapshot(&self) -> Result<Vec<(usize, Arc<ParamSet>)>> {
        self.inner.snapshot()
    }

    fn poison_for_test(&self) {
        self.inner.poison_for_test()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::EvalService;
    use crate::quant::{Bits, QuantConfig};
    use crate::runtime::Artifacts;

    fn tensors(n: usize) -> Vec<Vec<f32>> {
        (0..n).map(|i| vec![i as f32; 3]).collect()
    }

    #[test]
    fn stores_are_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<LocalParamStore>();
        check::<ReplicatedParamStore>();
    }

    #[test]
    fn local_store_is_append_only_with_tombstone_eviction() {
        let store = LocalParamStore::new(None);
        assert!(store.is_empty().unwrap());
        assert_eq!(store.add("baseline", tensors(2)).unwrap(), 0);
        assert_eq!(store.add("beacon0", tensors(2)).unwrap(), 1);
        assert_eq!(store.len().unwrap(), 2);
        // Shape mismatch against the baseline is a typed error.
        let err = store.add("bad", tensors(3)).unwrap_err();
        assert!(err.to_string().contains("the baseline has 2"), "{err}");

        assert!(store.evict(1).unwrap(), "first eviction");
        assert!(!store.evict(1).unwrap(), "idempotent");
        assert!(store.evict(0).is_err(), "baseline unevictable");
        assert!(store.evict(9).is_err(), "out of range");
        let err = store.get(1).unwrap_err();
        assert!(err.to_string().contains("was evicted"), "{err}");
        // Ids stay dense across tombstones; snapshots skip them.
        assert_eq!(store.add("beacon1", tensors(2)).unwrap(), 2);
        let live: Vec<usize> = store.snapshot().unwrap().iter().map(|(i, _)| *i).collect();
        assert_eq!(live, vec![0, 2]);
    }

    #[test]
    fn replica_pushes_are_contiguous_and_idempotent() {
        let replica = ReplicatedParamStore::replica(Arc::new(LocalParamStore::new(None)));
        replica.add("baseline", tensors(2)).unwrap();

        // The baseline is never replicated, and gaps are rejected.
        assert!(replica.apply_push(0, "baseline", tensors(2)).is_err());
        let gap = replica.apply_push(2, "beacon1", tensors(2)).unwrap_err();
        assert!(gap.to_string().contains("leaves a gap"), "{gap}");

        assert!(replica.apply_push(1, "beacon0", tensors(2)).unwrap(), "new set lands");
        assert_eq!(replica.get(1).unwrap().name, "beacon0");
        // Reconnect replay: the same push is a no-op...
        assert!(!replica.apply_push(1, "beacon0", tensors(2)).unwrap());
        assert_eq!(replica.len().unwrap(), 2);
        // ...but a DIFFERENT set claiming a held id is corruption.
        let clash = replica.apply_push(1, "impostor", tensors(2)).unwrap_err();
        assert!(clash.to_string().contains("already holds 'beacon0'"), "{clash}");

        // Role checks both ways.
        assert!(replica.sets_since(1).is_err());
        let authority = ReplicatedParamStore::authority(Arc::new(LocalParamStore::new(None)));
        authority.add("baseline", tensors(2)).unwrap();
        authority.add("beacon0", tensors(2)).unwrap();
        assert!(authority.apply_push(1, "beacon0", tensors(2)).is_err());
        let journal = authority.sets_since(1).unwrap();
        assert_eq!(journal.len(), 1);
        assert_eq!(journal[0].0, 1);
        assert_eq!(journal[0].1.name, "beacon0");
    }

    #[test]
    fn authority_journal_replays_into_an_identical_replica() {
        let authority = ReplicatedParamStore::authority(Arc::new(LocalParamStore::new(None)));
        authority.add("baseline", tensors(2)).unwrap();
        authority.add("beacon0", tensors(2)).unwrap();
        authority.add("beacon1", tensors(2)).unwrap();

        let replica = ReplicatedParamStore::replica(Arc::new(LocalParamStore::new(None)));
        replica.add("baseline", tensors(2)).unwrap();
        // Replaying the journal twice (a reconnect) converges to the same
        // table with authority-identical ids.
        for _ in 0..2 {
            for (idx, set) in authority.sets_since(1).unwrap() {
                replica.apply_push(idx, &set.name, set.host.clone()).unwrap();
            }
        }
        assert_eq!(replica.len().unwrap(), authority.len().unwrap());
        for (idx, set) in authority.snapshot().unwrap() {
            assert_eq!(replica.get(idx).unwrap().name, set.name);
        }
    }

    /// Moved from `eval/` with the store extraction. Regression:
    /// `.expect("param sets poisoned")` panicked every later eval in the
    /// pool once a worker died holding the lock. The accessors now
    /// return the typed "poisoned" error path that
    /// `SearchError::from_panic`/`SearchError::eval` classify.
    #[test]
    fn poisoned_param_sets_surface_typed_errors_not_panics() {
        let arts = Arc::new(Artifacts::synthetic());
        let svc = EvalService::surrogate(arts.clone()).unwrap();
        assert_eq!(svc.num_param_sets().unwrap(), 1);
        assert_eq!(svc.param_set(0).unwrap().name, "baseline");
        let oob = svc.param_set(7).unwrap_err();
        assert!(oob.to_string().contains("out of range"), "{oob}");

        svc.poison_param_sets_for_test();
        for err in [
            svc.param_set(0).unwrap_err(),
            svc.num_param_sets().unwrap_err(),
            svc.add_param_set("b", arts.weights.clone()).unwrap_err(),
        ] {
            assert!(err.to_string().contains("poisoned"), "{err}");
        }
        // The PJRT path (pjrt_run -> param_set) reads through the same
        // accessor, so evaluation errors out instead of panicking; the
        // surrogate path never touches the table and stays usable.
        let qc = QuantConfig::uniform(arts.layer_names.len(), Bits::B8, Bits::B8);
        assert!(svc.val_error(&qc, 0).is_ok());
    }

    /// Moved from `eval/` with the store extraction: eviction ordering —
    /// tombstoned ids stay reserved, memos purge, eviction is idempotent
    /// and the baseline is protected.
    #[test]
    fn evicting_a_param_set_frees_it_and_purges_its_memos() {
        let arts = Arc::new(Artifacts::synthetic());
        let svc = EvalService::surrogate(arts.clone()).unwrap();
        let beacon = svc.add_param_set("beacon-a", arts.weights.clone()).unwrap();
        let n = arts.layer_names.len();
        let qc = QuantConfig::uniform(n, Bits::B8, Bits::B8);
        svc.val_error(&qc, 0).unwrap();
        svc.val_error(&qc, beacon).unwrap();
        assert_eq!(svc.stats().unique_solutions, 2);

        svc.evict_param_set(beacon).unwrap();
        let stats = svc.stats();
        assert_eq!(stats.param_sets_evicted, 1);
        assert_eq!(stats.unique_solutions, 1, "beacon memo purged, baseline kept");
        assert_eq!(stats.evictions, 1);
        // The slot is tombstoned: id space is stable, access is a typed
        // error, and re-eviction is idempotent.
        let err = svc.param_set(beacon).unwrap_err();
        assert!(err.to_string().contains("evicted"), "{err}");
        svc.evict_param_set(beacon).unwrap();
        assert_eq!(svc.stats().param_sets_evicted, 1);
        let next = svc.add_param_set("beacon-b", arts.weights.clone()).unwrap();
        assert_eq!(next, beacon + 1);
        // The baseline is not evictable, and the baseline memo still hits.
        assert!(svc.evict_param_set(0).is_err());
        let before = svc.stats().executions;
        svc.val_error(&qc, 0).unwrap();
        assert_eq!(svc.stats().executions, before);
    }

    /// The replicated wrapper is transparent to evaluation: a surrogate
    /// service over a `ReplicatedParamStore` authority produces bitwise
    /// the errors and identical `EvalStats` to one over the plain local
    /// store, across random geometries with in-batch duplicates.
    #[test]
    fn replicated_store_service_matches_local_bitwise() {
        use crate::util::prop::check_prop;
        use crate::util::rng::Rng;
        let arts = Arc::new(Artifacts::synthetic());
        let n = arts.layer_names.len();
        let gen_batch = |r: &mut Rng| -> Vec<QuantConfig> {
            let m = 1 + r.below(6);
            let mut qcs: Vec<QuantConfig> = (0..m)
                .map(|_| QuantConfig {
                    w_bits: (0..n).map(|_| *r.choose(&Bits::SEARCHABLE)).collect(),
                    a_bits: (0..n).map(|_| *r.choose(&Bits::SEARCHABLE)).collect(),
                })
                .collect();
            // Force duplicates so the hit-accounting contract is covered.
            if qcs.len() > 1 {
                let dup = qcs[0].clone();
                qcs.push(dup);
            }
            qcs
        };
        check_prop(
            "replicated_store_matches_local",
            40,
            gen_batch,
            |qcs| {
                let local = EvalService::surrogate(arts.clone()).unwrap();
                let repl = EvalService::surrogate_replicated(arts.clone()).unwrap();
                for svc in [&local, &repl] {
                    svc.add_param_set("beacon0", arts.weights.clone()).unwrap();
                }
                for set in [0usize, 1] {
                    let a = local.val_error_batch(qcs, set).unwrap();
                    let b = repl.val_error_batch(qcs, set).unwrap();
                    if a.iter().zip(&b).any(|(x, y)| x.to_bits() != y.to_bits()) {
                        return Err(format!("set {set}: fronts differ: {a:?} vs {b:?}"));
                    }
                }
                if local.stats() != repl.stats() {
                    return Err(format!(
                        "stats differ: {:?} vs {:?}",
                        local.stats(),
                        repl.stats()
                    ));
                }
                Ok(())
            },
        );
    }
}
