//! Pareto-set utilities: dominance, front extraction, quality metrics.
//!
//! All objectives are MINIMIZED by convention (the paper negates speedup
//! to fit this, §4.2 — we do the same in the hardware objective wrappers).

pub mod hypervolume;

/// True iff `a` Pareto-dominates `b`: no worse in every objective and
/// strictly better in at least one.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_better = true;
        }
    }
    strictly_better
}

/// Constrained-domination (Deb 2002 §VI): a feasible solution dominates an
/// infeasible one; among infeasible, lower total violation dominates; among
/// feasible, plain Pareto dominance applies.
pub fn constrained_dominates(
    a: &[f64],
    a_violation: f64,
    b: &[f64],
    b_violation: f64,
) -> bool {
    let a_feas = a_violation <= 0.0;
    let b_feas = b_violation <= 0.0;
    match (a_feas, b_feas) {
        (true, false) => true,
        (false, true) => false,
        (false, false) => a_violation < b_violation,
        (true, true) => dominates(a, b),
    }
}

/// Indices of the non-dominated subset of `points` (the Pareto front).
/// O(n^2 m); n is small (populations, report sets).
pub fn pareto_front_indices(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, pj)| j != i && dominates(pj, &points[i]))
        })
        .collect()
}

/// Crowding distance per point within one front (NSGA-II §III-B). Extreme
/// points get +inf so they survive every truncation.
pub fn crowding_distances(points: &[Vec<f64>]) -> Vec<f64> {
    let n = points.len();
    if n == 0 {
        return vec![];
    }
    let m = points[0].len();
    let mut dist = vec![0.0f64; n];
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    for obj in 0..m {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| points[a][obj].partial_cmp(&points[b][obj]).unwrap());
        let lo = points[idx[0]][obj];
        let hi = points[idx[n - 1]][obj];
        dist[idx[0]] = f64::INFINITY;
        dist[idx[n - 1]] = f64::INFINITY;
        let span = hi - lo;
        if span <= 0.0 {
            continue;
        }
        for k in 1..n - 1 {
            let gap = points[idx[k + 1]][obj] - points[idx[k - 1]][obj];
            dist[idx[k]] += gap / span;
        }
    }
    dist
}

/// Generational distance-style spread: mean nearest-neighbour gap of a
/// front (used by the moo ablation benches).
pub fn mean_nearest_gap(points: &[Vec<f64>]) -> f64 {
    let n = points.len();
    if n < 2 {
        return 0.0;
    }
    let mut total = 0.0;
    for i in 0..n {
        let mut best = f64::INFINITY;
        for j in 0..n {
            if i == j {
                continue;
            }
            let d: f64 = points[i]
                .iter()
                .zip(&points[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            best = best.min(d);
        }
        total += best;
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 2.0], &[2.0, 1.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal
    }

    #[test]
    fn constrained_dominance_prefers_feasible() {
        assert!(constrained_dominates(&[9.0], 0.0, &[1.0], 0.5));
        assert!(!constrained_dominates(&[1.0], 0.5, &[9.0], 0.0));
        assert!(constrained_dominates(&[9.0], 0.1, &[1.0], 0.5));
        assert!(constrained_dominates(&[1.0], 0.0, &[2.0], 0.0));
    }

    #[test]
    fn front_extraction() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 3.0],
            vec![3.0, 2.0],
            vec![4.0, 1.0],
            vec![3.0, 3.0], // dominated by (2,3) and (3,2)
        ];
        assert_eq!(pareto_front_indices(&pts), vec![0, 1, 2, 3]);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let pts = vec![
            vec![0.0, 3.0],
            vec![1.0, 2.0],
            vec![2.0, 1.0],
            vec![3.0, 0.0],
        ];
        let d = crowding_distances(&pts);
        assert!(d[0].is_infinite() && d[3].is_infinite());
        assert!(d[1].is_finite() && d[2].is_finite());
        // Symmetric layout -> equal interior crowding.
        assert!((d[1] - d[2]).abs() < 1e-12);
    }

    #[test]
    fn crowding_small_fronts_all_infinite() {
        assert!(crowding_distances(&[vec![1.0, 2.0]]).iter().all(|d| d.is_infinite()));
        assert!(crowding_distances(&[vec![1.0, 2.0], vec![2.0, 1.0]])
            .iter()
            .all(|d| d.is_infinite()));
    }

    #[test]
    fn nearest_gap_positive_for_spread_points() {
        let pts = vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![2.0, 0.0]];
        assert!((mean_nearest_gap(&pts) - 1.0).abs() < 1e-12);
    }
}
