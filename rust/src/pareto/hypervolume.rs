//! Hypervolume indicator (minimization convention, w.r.t. a reference
//! point that every front member must dominate). Exact algorithms for 2-D
//! (sort-sweep) and 3-D (dimension-sweep); used to compare inference-only
//! vs beacon-based fronts and in the moo ablation benches.

use super::pareto_front_indices;

/// 2-D hypervolume: area dominated by `points` up to `reference`.
pub fn hypervolume_2d(points: &[Vec<f64>], reference: &[f64; 2]) -> f64 {
    let front: Vec<&Vec<f64>> = pareto_front_indices(points)
        .into_iter()
        .map(|i| &points[i])
        .filter(|p| p[0] < reference[0] && p[1] < reference[1])
        .collect();
    if front.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<&Vec<f64>> = front;
    sorted.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
    let mut hv = 0.0;
    let mut prev_y = reference[1];
    for p in sorted {
        // Non-dominated + sorted by x ascending => y strictly descending.
        let width = reference[0] - p[0];
        let height = prev_y - p[1];
        if height > 0.0 {
            hv += width * height;
            prev_y = p[1];
        }
    }
    hv
}

/// 3-D hypervolume by sweeping the third objective and accumulating 2-D
/// slabs (HSO-style). Exact for modest front sizes (O(n^2 log n)).
pub fn hypervolume_3d(points: &[Vec<f64>], reference: &[f64; 3]) -> f64 {
    let mut front: Vec<&Vec<f64>> = pareto_front_indices(points)
        .into_iter()
        .map(|i| &points[i])
        .filter(|p| p[0] < reference[0] && p[1] < reference[1] && p[2] < reference[2])
        .collect();
    if front.is_empty() {
        return 0.0;
    }
    front.sort_by(|a, b| a[2].partial_cmp(&b[2]).unwrap());
    let mut hv = 0.0;
    // Sweep z from each point's level to the next; the slab cross-section
    // is the 2-D hypervolume of all points at or below the current z.
    for i in 0..front.len() {
        let z_lo = front[i][2];
        let z_hi = if i + 1 < front.len() {
            front[i + 1][2]
        } else {
            reference[2]
        };
        if z_hi <= z_lo {
            continue;
        }
        let active: Vec<Vec<f64>> = front[..=i]
            .iter()
            .map(|p| vec![p[0], p[1]])
            .collect();
        hv += hypervolume_2d(&active, &[reference[0], reference[1]]) * (z_hi - z_lo);
    }
    hv
}

/// Dispatch on dimension (2 or 3 — all the paper's fronts).
pub fn hypervolume(points: &[Vec<f64>], reference: &[f64]) -> f64 {
    match reference.len() {
        2 => hypervolume_2d(points, &[reference[0], reference[1]]),
        3 => hypervolume_3d(points, &[reference[0], reference[1], reference[2]]),
        d => panic!("hypervolume: unsupported dimension {d}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_2d() {
        let hv = hypervolume_2d(&[vec![1.0, 1.0]], &[2.0, 2.0]);
        assert!((hv - 1.0).abs() < 1e-12);
    }

    #[test]
    fn staircase_2d() {
        let pts = vec![vec![1.0, 3.0], vec![2.0, 2.0], vec![3.0, 1.0]];
        // Union of three rectangles to ref (4,4): 1x(4-3)... compute:
        // sorted by x: (1,3): (4-1)*(4-3)=3; (2,2): (4-2)*(3-2)=2;
        // (3,1): (4-3)*(2-1)=1 => 6.
        let hv = hypervolume_2d(&pts, &[4.0, 4.0]);
        assert!((hv - 6.0).abs() < 1e-12);
    }

    #[test]
    fn dominated_points_do_not_add() {
        let base = vec![vec![1.0, 1.0]];
        let with_dominated = vec![vec![1.0, 1.0], vec![1.5, 1.5]];
        let r = [3.0, 3.0];
        assert!(
            (hypervolume_2d(&base, &r) - hypervolume_2d(&with_dominated, &r)).abs()
                < 1e-12
        );
    }

    #[test]
    fn out_of_reference_ignored() {
        let hv = hypervolume_2d(&[vec![5.0, 5.0]], &[2.0, 2.0]);
        assert_eq!(hv, 0.0);
    }

    #[test]
    fn single_point_3d_is_box() {
        let hv = hypervolume_3d(&[vec![1.0, 1.0, 1.0]], &[2.0, 3.0, 4.0]);
        assert!((hv - 1.0 * 2.0 * 3.0).abs() < 1e-12);
    }

    #[test]
    fn two_disjoint_boxes_3d() {
        // Two points trading off obj0 vs obj2.
        let pts = vec![vec![0.0, 0.0, 1.0], vec![1.0, 0.0, 0.0]];
        let r = [2.0, 2.0, 2.0];
        // p0 dominates box [0,2]x[0,2]x[1,2] = 2*2*1 = 4
        // p1 dominates box [1,2]x[0,2]x[0,2] = 1*2*2 = 4
        // overlap [1,2]x[0,2]x[1,2] = 1*2*1 = 2 => union = 6
        let hv = hypervolume_3d(&pts, &r);
        assert!((hv - 6.0).abs() < 1e-12, "hv={hv}");
    }

    #[test]
    fn hv_monotone_in_better_points() {
        let worse = vec![vec![2.0, 2.0]];
        let better = vec![vec![1.0, 1.0]];
        let r = [4.0, 4.0];
        assert!(hypervolume_2d(&better, &r) > hypervolume_2d(&worse, &r));
    }
}
