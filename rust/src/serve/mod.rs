//! Serve mode: a long-lived MOHAQ search service over one shared
//! [`SearchSession`](crate::coordinator::SearchSession).
//!
//! `mohaq serve --addr 127.0.0.1:7070` exposes the search API over a
//! line-delimited JSON protocol on TCP (hermetic, std-only — no HTTP
//! stack). One [`server::ServeState`] holds the compiled artifacts and
//! ONE `EvalService` across requests: the PTQ error cache is
//! platform-independent, so concurrent tenants submitting different
//! platform tables reuse each other's candidate evaluations, and all
//! in-flight searches fan their evaluation batches across one shared
//! [`WorkQueue`](crate::util::pool::WorkQueue) job stream. A tenant's
//! generation arrives as a handful of micro-batched
//! [`EvalService::val_error_batch`](crate::eval::EvalService::val_error_batch)
//! jobs (one per worker chunk), not one job per candidate, so queue
//! round trips stay proportional to the worker count rather than the
//! population size.
//!
//! Contracts (see DESIGN.md "Serve mode"):
//!   * determinism — a served search returns the front the equivalent
//!     offline `SearchSession::run` produces at the same seed, bit for
//!     bit;
//!   * cancellation — a `cancel` frame, a dead client (first failed
//!     frame write), or server shutdown aborts the search at its next
//!     evaluation batch with a typed `cancelled` error frame; a
//!     half-closed client that keeps reading drains its fronts instead;
//!   * panic isolation — no panic crosses the connection boundary:
//!     malformed input, invalid specs, evaluation failures and even
//!     engine panics all come back as typed `error` frames on a live
//!     connection.
//!
//! Without an artifact bundle the server falls back to the hermetic
//! surrogate evaluator (`SearchSession::synthetic`), which is how the CI
//! smoke job and `examples/serve_quickstart.rs` drive the full stack
//! offline.

pub mod client;
pub mod protocol;
pub mod server;

pub use client::{ClientError, SearchReply, ServeClient};
pub use protocol::{
    Frame, FrontRow, HwEntry, IncomingMigrants, PlatformInfo, Request, ServerStats,
    ShardElites, ShardMigration, ShardPop, ShardStats,
};
pub use server::{ServeState, Server};
