//! The serve-mode server: a long-lived search service over ONE shared
//! `SearchSession`.
//!
//! Architecture:
//!   * [`ServeState`] — the shared half: one session (one compiled
//!     executable + one platform-independent PTQ result cache) and one
//!     [`WorkQueue`]. Every request resolves its OWN spec fragment —
//!     platform table, objectives, GA settings — against the registry,
//!     while candidate errors are memoized across requests: concurrent
//!     tenants searching different hardware reuse each other's
//!     evaluations. Candidate batches from every in-flight search fan
//!     out across the shared pool as one job stream.
//!   * [`Server`] — the TCP half: one thread per connection, requests and
//!     replies as line-delimited JSON (`serve::protocol`). Searches run
//!     on their own threads so `cancel` frames are handled while a
//!     search streams. Cancellation contract: a `cancel` frame, server
//!     shutdown, or a FULLY gone client (first failed frame write)
//!     cancels in-flight searches; a half-closed client that keeps
//!     reading gets its remaining fronts drained to it.
//!
//! Panic policy: no panic crosses the connection boundary. The session
//! already converts engine panics into typed `SearchError`s; the serve
//! layer adds a `catch_unwind` backstop that turns anything left into an
//! `error` frame (`kind: "panic"`), and malformed input yields
//! `kind: "protocol"` frames — the connection stays up either way.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::protocol::{event_frame, front_frame, Frame, PlatformInfo, Request, ServerStats};
use crate::coordinator::{CancelToken, ExperimentSpec, SearchSession};
use crate::hw::manifest::{ManifestError, PlatformManifest};
use crate::hw::registry;
use crate::util::json::{obj, Json};
use crate::util::pool::{panic_message, relock, WorkQueue};

/// How often idle connection readers wake to check for server shutdown.
const POLL_INTERVAL: Duration = Duration::from_millis(200);

/// Frame-size ceiling for incoming lines: a client streaming bytes with
/// no newline must not grow the read buffer (and the server's memory)
/// without bound. Real spec frames are a few KB.
const MAX_LINE_BYTES: usize = 4 << 20;

/// Per-write deadline: a client that stops reading (full TCP send
/// buffer) must wedge neither the search thread streaming to it nor the
/// clean-shutdown join — after this, writes fail and the search is
/// cancelled instead.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Concurrent searches one connection may hold in flight: each costs an
/// OS thread plus per-search state, so it must not scale with whatever a
/// client chooses to send (the evaluation CPU itself is already bounded
/// by the shared pool). Excess requests get a typed `busy` error frame.
const MAX_INFLIGHT_PER_CONN: usize = 32;

/// Concurrent connections the accept loop will serve; beyond this, new
/// connections are dropped immediately. Bounds total thread count
/// (connections × per-connection searches) so a connection flood
/// degrades instead of exhausting OS threads.
const MAX_CONNECTIONS: usize = 256;

/// Shared server state: one session + one evaluation pool, reused by
/// every connection and request.
pub struct ServeState {
    session: SearchSession,
    requests: AtomicUsize,
    active: AtomicUsize,
    shutdown: AtomicBool,
    /// Worker mode: this server accepts the shard ops of the distributed
    /// protocol (`dist::worker`) in addition to the regular ops.
    worker: bool,
    /// Opt-in: retire the beacon parameter sets a search registered once
    /// its front is built (`EvalService::evict_param_set`), so a
    /// long-lived server's device memory does not grow with every
    /// beacon-enabled tenant. Off by default — eviction is index-window
    /// based, so it should only be enabled on servers whose
    /// beacon-enabled requests run serially (concurrent beacon searches
    /// could retire each other's sets mid-run).
    evict_beacons: AtomicBool,
}

impl ServeState {
    /// Wrap a session for serving: its candidate evaluations are routed
    /// through a new shared [`WorkQueue`] of `eval_workers` threads
    /// (0 = one per core).
    pub fn new(session: SearchSession, eval_workers: usize) -> Arc<ServeState> {
        let queue = Arc::new(WorkQueue::new(eval_workers));
        Arc::new(ServeState {
            session: session.shared_queue(queue),
            requests: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            worker: false,
            evict_beacons: AtomicBool::new(false),
        })
    }

    /// Like [`ServeState::new`], but in worker mode: the server also
    /// accepts `shard_assign` / `run_islands` / `elite_exchange` /
    /// `shard_front` / `param_push` / `param_fetch` ops from a
    /// distributed-search coordinator.
    pub fn worker(session: SearchSession, eval_workers: usize) -> Arc<ServeState> {
        let queue = Arc::new(WorkQueue::new(eval_workers));
        Arc::new(ServeState {
            session: session.shared_queue(queue),
            requests: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            worker: true,
            evict_beacons: AtomicBool::new(false),
        })
    }

    pub fn is_worker(&self) -> bool {
        self.worker
    }

    /// Enable per-request beacon-set eviction (see the `evict_beacons`
    /// field docs for the serial-requests caveat).
    pub fn set_evict_beacons(&self, on: bool) {
        self.evict_beacons.store(on, Ordering::SeqCst);
    }

    pub fn session(&self) -> &SearchSession {
        &self.session
    }

    pub fn stats(&self) -> ServerStats {
        let eval = self.session.eval().stats();
        ServerStats {
            executions: eval.executions,
            cache_hits: eval.cache_hits,
            unique_solutions: eval.unique_solutions,
            evictions: eval.evictions,
            param_sets_evicted: eval.param_sets_evicted,
            poisoned: eval.poisoned,
            requests: self.requests.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            surrogate: self.session.eval().is_surrogate(),
        }
    }

    /// Flag the server for shutdown; connection readers notice within
    /// `POLL_INTERVAL` and cancel their in-flight searches. Note: the
    /// accept loop itself wakes on its NEXT incoming connection — the
    /// `shutdown` protocol frame additionally nudges it with a
    /// self-connection; callers invoking this directly can do the same.
    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// Blocking TCP server over a [`ServeState`].
pub struct Server {
    listener: TcpListener,
    state: Arc<ServeState>,
}

impl Server {
    /// Bind the listening socket (use port 0 for an ephemeral port, then
    /// read it back via [`Server::local_addr`]).
    pub fn bind(addr: impl ToSocketAddrs, state: Arc<ServeState>) -> std::io::Result<Server> {
        Ok(Server { listener: TcpListener::bind(addr)?, state })
    }

    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Accept and serve connections until a client sends `shutdown`.
    /// Returns after every connection thread (and therefore every
    /// in-flight search) has wound down — the clean-shutdown contract.
    pub fn run(self) -> std::io::Result<()> {
        let addr = self.listener.local_addr()?;
        let mut conns = Vec::new();
        for stream in self.listener.incoming() {
            if self.state.is_shutdown() {
                // Includes the self-connection nudge sent by the handler
                // that processed the shutdown request.
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let state = self.state.clone();
            // Reap finished handlers so a months-long server does not
            // accumulate one JoinHandle per connection ever accepted —
            // and bound the live count: a connection flood degrades
            // (drops) instead of exhausting OS threads.
            conns.retain(|h| !h.is_finished());
            if conns.len() >= MAX_CONNECTIONS {
                drop(stream);
                continue;
            }
            // Builder::spawn reports thread exhaustion as an error
            // instead of panicking the accept loop off the air.
            let spawned = std::thread::Builder::new()
                .name("mohaq-serve-conn".into())
                .spawn(move || handle_connection(stream, state, addr));
            if let Ok(handle) = spawned {
                conns.push(handle);
            }
        }
        for c in conns {
            let _ = c.join();
        }
        Ok(())
    }
}

/// Write one frame; returns whether the client took it. Failures are
/// tolerated here (client gone or wedged past `WRITE_TIMEOUT`) — the
/// search-side caller cancels its search on a failed send, and the
/// reader loop notices a disconnect on its own.
pub(crate) fn send(writer: &Mutex<TcpStream>, frame: &Frame) -> bool {
    let mut line = frame.to_line();
    line.push('\n');
    let w = relock(writer);
    let mut out = &*w;
    let ok = out.write_all(line.as_bytes()).and_then(|()| out.flush()).is_ok();
    if !ok {
        // A failed (or timed-out) write may have left a TORN frame on
        // the socket — no later frame could be framed correctly, so tear
        // the connection down instead of streaming garbage; the reader
        // loop then sees EOF and cancels the connection's searches.
        let _ = w.shutdown(std::net::Shutdown::Both);
    }
    ok
}

/// Address a connection can reach the accept loop on, for the shutdown
/// nudge: a wildcard bind (0.0.0.0 / ::) is not connectable on every
/// platform, so rewrite it to the matching loopback.
fn nudge_addr(server_addr: SocketAddr) -> SocketAddr {
    let mut addr = server_addr;
    if addr.ip().is_unspecified() {
        addr.set_ip(match addr.ip() {
            IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
            IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
        });
    }
    addr
}

/// Run one search request on its own thread, streaming frames back;
/// returns the TERMINAL frame (front or typed error) for the caller to
/// deliver after clearing the request's inflight slot.
fn run_search(
    state: &ServeState,
    writer: &Mutex<TcpStream>,
    id: u64,
    spec: ExperimentSpec,
    cancel: CancelToken,
) -> Frame {
    state.requests.fetch_add(1, Ordering::Relaxed);
    state.active.fetch_add(1, Ordering::Relaxed);
    // For opt-in beacon eviction: every parameter set registered past
    // this watermark during the request belongs to it (valid while
    // beacon-enabled requests run serially; see `set_evict_beacons`).
    let sets_before = state.session.eval().num_param_sets().unwrap_or(usize::MAX);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        state.session.run_with_cancel(
            &spec,
            |event| {
                if let Some(frame) = event_frame(id, event) {
                    if !send(writer, &frame) {
                        // The client cannot take frames any more (gone,
                        // or wedged past the write timeout): stop
                        // burning evaluations on its behalf.
                        cancel.cancel();
                    }
                }
            },
            &cancel,
        )
    }));
    state.active.fetch_sub(1, Ordering::Relaxed);
    if state.evict_beacons.load(Ordering::SeqCst) {
        // The outcome's rows are fully scored by now — the retrained
        // sets' numbers live on in the front, only the device/host
        // buffers and their memo entries are released.
        if let Ok(after) = state.session.eval().num_param_sets() {
            for idx in sets_before..after {
                let _ = state.session.eval().evict_param_set(idx);
            }
        }
    }
    match result {
        Ok(Ok(outcome)) => front_frame(id, &outcome),
        Ok(Err(e)) => {
            Frame::Error { id: Some(id), kind: e.kind().into(), message: e.to_string() }
        }
        // Serve-layer backstop: even a panic that escaped the session's
        // own catch becomes a frame, never a dead connection.
        Err(payload) => {
            Frame::Error { id: Some(id), kind: "panic".into(), message: panic_message(payload) }
        }
    }
}

/// Inject a connection's tenant manifests into a raw search spec:
/// platform-table entries naming a tenant platform gain an inline
/// `"manifest"` parameter (unless the client inlined its own), and
/// `metric@name` objective bindings referencing a tenant platform absent
/// from the table get an entry appended. By the time
/// `ExperimentSpec::from_json` resolves the spec against the registry,
/// every tenant reference is self-contained — the GLOBAL registry is
/// never touched, which is the whole tenant-isolation contract.
fn inline_tenant_manifests(spec: Json, tenant: &BTreeMap<String, PlatformManifest>) -> Json {
    if tenant.is_empty() {
        return spec;
    }
    let mut top = match spec {
        Json::Obj(t) => t,
        other => return other, // not an object: the spec parser will say so
    };
    let entry_name = |e: &Json| {
        e.get("name").or_else(|| e.get("kind")).and_then(Json::as_str).map(str::to_lowercase)
    };
    let covered: BTreeSet<String> = top
        .get("platforms")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(entry_name)
        .collect();
    // Tenant platforms referenced only through objective bindings.
    let missing: BTreeSet<String> = top
        .get("objectives")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(Json::as_str)
        .filter_map(|o| o.rsplit_once('@').map(|(_, p)| p.trim().to_lowercase()))
        .filter(|p| tenant.contains_key(p) && !covered.contains(p))
        .collect();
    if let Some(Json::Arr(entries)) = top.get_mut("platforms") {
        for e in entries.iter_mut() {
            let Some(name) = entry_name(e) else { continue };
            let Some(m) = tenant.get(&name) else { continue };
            if let Json::Obj(o) = e {
                let has_inline = o.contains_key("manifest")
                    || o.get("params").and_then(|p| p.get("manifest")).is_some();
                if !has_inline {
                    o.insert("manifest".into(), m.to_json());
                }
            }
        }
    }
    if !missing.is_empty() {
        let new_entries: Vec<Json> = missing
            .iter()
            .map(|name| {
                obj(vec![("name", name.as_str().into()), ("manifest", tenant[name].to_json())])
            })
            .collect();
        match top.get_mut("platforms") {
            Some(Json::Arr(arr)) => arr.extend(new_entries),
            _ => {
                top.insert("platforms".into(), Json::Arr(new_entries));
            }
        }
    }
    Json::Obj(top)
}

/// The request id of a shard op (the dist ops all carry one).
fn shard_request_id(req: &Request) -> Option<u64> {
    match req {
        Request::ShardAssign { id, .. }
        | Request::RunIslands { id, .. }
        | Request::EliteExchange { id, .. }
        | Request::ShardFront { id }
        | Request::ParamPush { id, .. }
        | Request::ParamFetch { id, .. } => Some(*id),
        _ => None,
    }
}

fn handle_connection(stream: TcpStream, state: Arc<ServeState>, server_addr: SocketAddr) {
    // Reader polls with a timeout so a quiet connection still notices
    // server shutdown; the writer half is shared with search threads and
    // bounded by WRITE_TIMEOUT so a non-reading client cannot wedge them.
    let _ = stream.set_read_timeout(Some(POLL_INTERVAL));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let writer = Arc::new(Mutex::new(stream));
    let inflight: Arc<Mutex<HashMap<u64, CancelToken>>> = Arc::new(Mutex::new(HashMap::new()));
    let mut searches: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut buf: Vec<u8> = Vec::new();
    // Set when EOF arrives with a final un-terminated line still in
    // `buf`: process that line — and let a search it starts run to
    // completion — so a piped one-shot client
    // (`printf '{"op":...}' | nc`) gets its reply instead of a silent
    // drop or an instant cancellation.
    let mut last_line = false;
    // Worker mode: at most one island shard per connection, owned by the
    // coordinator on the other end (`dist::worker`). Dropped with the
    // connection, which is what frees a shard when a coordinator
    // re-shards after a loss.
    let mut shard: Option<crate::dist::worker::ShardSession> = None;
    // Tenant platform registry: manifests registered on THIS connection
    // only. Dropped with the connection; never written to the process
    // registry, so tenants cannot see (or shadow) each other's platforms.
    let mut tenant: BTreeMap<String, PlatformManifest> = BTreeMap::new();

    'conn: loop {
        // read_until may return a timeout mid-line; `buf` keeps the
        // partial bytes and the next pass continues the same line. The
        // `take` bound forces read_until back to the loop at the size
        // cap even when the socket supplies a continuous newline-free
        // stream (otherwise one call could grow `buf` forever), and the
        // guard below then rejects the oversized frame. Take returns
        // Ok(0) only at true EOF here — the remaining allowance is
        // always >= 1 because oversized buffers exit via the guard.
        let allowed = (MAX_LINE_BYTES + 1 - buf.len()) as u64;
        let complete = match std::io::Read::take(&mut reader, allowed).read_until(b'\n', &mut buf)
        {
            Ok(0) if buf.is_empty() => break 'conn, // EOF: client disconnected
            Ok(0) => {
                last_line = true; // EOF with a final un-terminated line
                true
            }
            Ok(_) => buf.ends_with(b"\n"),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.is_shutdown() {
                    break 'conn;
                }
                false
            }
            Err(_) => break 'conn,
        };
        if buf.len() > MAX_LINE_BYTES {
            send(
                &writer,
                &Frame::Error {
                    id: None,
                    kind: "protocol".into(),
                    message: format!("frame exceeds {MAX_LINE_BYTES} bytes"),
                },
            );
            break 'conn;
        }
        if !complete {
            continue; // partial line: keep accumulating
        }
        let line = String::from_utf8_lossy(&buf).into_owned();
        buf.clear();
        if line.trim().is_empty() {
            if last_line {
                break 'conn;
            }
            continue;
        }
        match Request::parse(&line) {
            Err(e) => {
                send(
                    &writer,
                    &Frame::Error { id: e.id, kind: "protocol".into(), message: e.message },
                );
            }
            Ok(Request::Ping) => {
                send(&writer, &Frame::Pong);
            }
            Ok(Request::Stats) => {
                send(&writer, &Frame::Stats(state.stats()));
            }
            Ok(Request::Cancel { id }) => {
                if let Some(token) = relock(&inflight).get(&id) {
                    token.cancel();
                }
            }
            Ok(Request::Shutdown) => {
                state.begin_shutdown();
                send(&writer, &Frame::Bye);
                // Nudge the accept loop out of its blocking accept.
                let _ = TcpStream::connect(nudge_addr(server_addr));
                break 'conn;
            }
            Ok(Request::RegisterPlatform { id, manifest }) => {
                match PlatformManifest::from_json(&manifest) {
                    Err(e) => {
                        send(
                            &writer,
                            &Frame::Error {
                                id: Some(id),
                                kind: "manifest".into(),
                                message: e.to_string(),
                            },
                        );
                    }
                    Ok(m) => {
                        if let Some(source) = registry::source_of(&m.name) {
                            // Built-in / custom / globally loaded names
                            // are off limits: a tenant must not shadow
                            // what other connections resolve by name.
                            let e = ManifestError::Collision {
                                name: m.name.clone(),
                                existing: source.to_string(),
                            };
                            send(
                                &writer,
                                &Frame::Error {
                                    id: Some(id),
                                    kind: "manifest".into(),
                                    message: e.to_string(),
                                },
                            );
                        } else if tenant.get(&m.name).is_some_and(|prev| prev != &m) {
                            send(
                                &writer,
                                &Frame::Error {
                                    id: Some(id),
                                    kind: "manifest".into(),
                                    message: format!(
                                        "platform '{}' is already registered on this \
                                         connection with different contents",
                                        m.name
                                    ),
                                },
                            );
                        } else {
                            // Identical re-registration is an idempotent
                            // ack; a rejected one (above) leaves `tenant`
                            // untouched.
                            let name = m.name.clone();
                            tenant.insert(name.clone(), m);
                            send(&writer, &Frame::PlatformRegistered { id, name });
                        }
                    }
                }
            }
            Ok(Request::Platforms) => {
                let mut platforms: Vec<PlatformInfo> = registry::known_platforms_with_sources()
                    .into_iter()
                    .map(|(name, source)| PlatformInfo { name, source: source.to_string() })
                    .collect();
                platforms.extend(tenant.keys().map(|name| PlatformInfo {
                    name: name.clone(),
                    source: "manifest (tenant)".into(),
                }));
                platforms.sort_by(|a, b| a.name.cmp(&b.name));
                send(&writer, &Frame::Platforms { platforms });
            }
            Ok(
                req @ (Request::ShardAssign { .. }
                | Request::RunIslands { .. }
                | Request::EliteExchange { .. }
                | Request::ShardFront { .. }
                | Request::ParamPush { .. }
                | Request::ParamFetch { .. }),
            ) => {
                if state.is_worker() {
                    // Shard ops are synchronous on the reader thread: the
                    // coordinator drives every worker in lockstep, so
                    // there is never a second op in flight while one
                    // computes (liveness comes from the worker's own
                    // heartbeat thread).
                    crate::dist::worker::handle(&state, &writer, &mut shard, req);
                } else {
                    send(
                        &writer,
                        &Frame::Error {
                            id: shard_request_id(&req),
                            kind: "protocol".into(),
                            message: "shard ops require a worker server (start one with \
                                      'mohaq worker')"
                                .into(),
                        },
                    );
                }
            }
            Ok(Request::Search { id, spec }) => {
                if relock(&inflight).contains_key(&id) {
                    send(
                        &writer,
                        &Frame::Error {
                            id: Some(id),
                            kind: "protocol".into(),
                            message: format!("request id {id} is already in flight"),
                        },
                    );
                    continue;
                }
                if relock(&inflight).len() >= MAX_INFLIGHT_PER_CONN {
                    send(
                        &writer,
                        &Frame::Error {
                            id: Some(id),
                            kind: "busy".into(),
                            message: format!(
                                "connection already has {MAX_INFLIGHT_PER_CONN} searches in \
                                 flight; wait for one to finish or cancel it"
                            ),
                        },
                    );
                    continue;
                }
                // Self-contain any references to this connection's tenant
                // platforms, then parse server-side so validation
                // failures come back as typed error frames tagged with
                // the request id.
                let spec = inline_tenant_manifests(spec, &tenant);
                let spec = match ExperimentSpec::from_json(&spec) {
                    Ok(s) => s,
                    Err(e) => {
                        send(
                            &writer,
                            &Frame::Error {
                                id: Some(id),
                                kind: e.kind().into(),
                                message: e.to_string(),
                            },
                        );
                        continue;
                    }
                };
                let token = CancelToken::new();
                relock(&inflight).insert(id, token.clone());
                // Reap completed searches so a long-lived connection
                // submitting many sequential requests stays bounded.
                searches.retain(|h| !h.is_finished());
                let (state, writer, inflight) =
                    (state.clone(), writer.clone(), inflight.clone());
                let spawned = std::thread::Builder::new()
                    .name("mohaq-serve-search".into())
                    .spawn({
                        let (writer, inflight) = (writer.clone(), inflight.clone());
                        move || {
                            let terminal = run_search(&state, &writer, id, spec, token);
                            // Clear the inflight slot BEFORE delivering
                            // the terminal frame: a client reusing the id
                            // the moment it reads the front must not race
                            // a stale entry.
                            relock(&inflight).remove(&id);
                            send(&writer, &terminal);
                        }
                    });
                match spawned {
                    Ok(handle) => searches.push(handle),
                    Err(e) => {
                        // Thread exhaustion degrades to a typed frame,
                        // never a panic in the reader.
                        relock(&inflight).remove(&id);
                        send(
                            &writer,
                            &Frame::Error {
                                id: Some(id),
                                kind: "busy".into(),
                                message: format!("cannot start search worker: {e}"),
                            },
                        );
                    }
                }
            }
        }
        // Close after the final un-terminated line, and stop serving a
        // busy connection (one that never hits the idle timeout) once
        // another client has requested shutdown.
        if last_line || state.is_shutdown() {
            break 'conn;
        }
    }

    // Epilogue: drain in-flight searches. EOF on the read side may be a
    // one-shot client's deliberate half-close ("no more requests, finish
    // what I sent") — its searches run to completion and stream their
    // fronts to the still-open write side. A client that is fully gone
    // is caught by `send`: the first failed write tears the connection
    // down AND cancels the search (see `run_search`), so dead clients
    // never keep work alive for long. Server shutdown — already flagged,
    // or arriving while we wait — cancels promptly.
    loop {
        if state.is_shutdown() {
            for token in relock(&inflight).values() {
                token.cancel();
            }
            break;
        }
        if searches.iter().all(std::thread::JoinHandle::is_finished) {
            break;
        }
        std::thread::sleep(POLL_INTERVAL);
    }
    for s in searches {
        let _ = s.join();
    }
}
