//! Serve-mode wire protocol: line-delimited JSON over TCP, std-only.
//!
//! Every message is one JSON object on one line. Client → server messages
//! carry an `"op"` discriminator, server → client frames an `"event"`:
//!
//! ```text
//! → {"op":"search","id":1,"spec":{...ExperimentSpec JSON...}}
//! ← {"event":"started","id":1,"name":"exp2-silago","num_vars":8,...}
//! ← {"event":"generation","id":1,"generation":0,"best_err":0.17,...}
//! ← {"event":"front","id":1,"rows":[...],"cache_hits":120,...}
//! → {"op":"cancel","id":1}          (any time while 1 is in flight)
//! ← {"event":"error","id":1,"kind":"cancelled","message":"..."}
//! → {"op":"stats"}                  → {"event":"stats",...}
//! → {"op":"ping"}                   → {"event":"pong"}
//! → {"op":"shutdown"}               → {"event":"bye"}   (server stops)
//! ```
//!
//! Error frames carry the typed [`SearchError::kind`] string, so clients
//! match on failure classes without parsing messages; `"protocol"` marks
//! malformed input, `"busy"` the per-connection in-flight cap, and
//! `"panic"` the serve-layer backstop (none takes the connection down).
//! Numbers round-trip losslessly: the JSON codec emits
//! shortest-round-trip floats and `NaN`/`Infinity` spellings its parser
//! (and Python's json module) accepts, which is what makes served
//! fronts bitwise-comparable to offline runs. Caveat for foreign
//! clients: the non-finite spellings are a deliberate deviation from
//! RFC 8259 (matching Python's default), so a strict parser must treat
//! `NaN`/`Infinity` tokens the way Python's json module does — they
//! only ever appear in numeric positions like a generation's `best_err`
//! before any feasible solution exists.
//!
//! Worker mode (distributed island sharding, `rust/src/dist/`) extends
//! the protocol with coordinator → worker ops `shard_assign` /
//! `run_islands` / `elite_exchange` / `shard_front` / `param_push` /
//! `param_fetch` and worker → coordinator frames `shard_assigned` /
//! `elite_exchange` / `migration_applied` / `shard_front` /
//! `param_pushed` / `param_set` / `worker_heartbeat`.
//! Individuals and island snapshots ride the same lossless number
//! codec; the one exception is the RNG state, whose `u64` words exceed
//! f64 precision and therefore travel as decimal strings (the same
//! convention `ExperimentSpec` uses for `ga.seed`). Replicated
//! parameter tensors are f32 and travel as plain JSON numbers — every
//! f32 is exactly representable as an f64, and the parser rejects any
//! value a cast back to f32 would alter (same contract as
//! `store::eval_store`), so a pushed beacon set lands bit-for-bit.

use crate::coordinator::{SearchEvent, SearchOutcome, SolutionRow};
use crate::moo::{Individual, IslandSnapshot};
use crate::quant::{Bits, QuantConfig};
use crate::util::json::{obj, Json};

/// Client → server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Run a search; `spec` is raw `ExperimentSpec` JSON (parsed server
    /// side so validation errors come back typed, tagged with `id`).
    Search { id: u64, spec: Json },
    /// Cancel the in-flight search with this id (same connection).
    Cancel { id: u64 },
    /// Snapshot of the shared service counters.
    Stats,
    Ping,
    /// Stop the server once outstanding work is cancelled.
    Shutdown,
    /// Register a platform manifest (`hw::manifest` JSON) scoped to THIS
    /// connection's tenant: later `search` requests on the connection may
    /// name it in their platform table and objective bindings. Rejected
    /// (typed `"manifest"` error frame) on schema violations or a name
    /// collision with a globally registered platform; never touches the
    /// global registry.
    RegisterPlatform { id: u64, manifest: Json },
    /// List the platforms this connection may bind objectives to: the
    /// global registry plus the tenant's own registered manifests.
    Platforms,
    /// Coordinator → worker: own these global island indices of the
    /// search described by `spec`. `restore` carries post-migration
    /// snapshots when the shard replays work a lost worker had done
    /// (empty = fresh shard, seeded from scratch); `base_gen` is the
    /// generation the snapshots were taken at.
    ShardAssign { id: u64, spec: Json, islands: Vec<usize>, base_gen: usize, restore: Vec<IslandSnapshot> },
    /// Coordinator → worker: advance the assigned shard to `upto_gen`;
    /// the worker replies with an `elite_exchange` frame holding its
    /// islands' elites at that boundary.
    RunIslands { id: u64, upto_gen: usize },
    /// Coordinator → worker: migrants routed by the coordinator's
    /// topology; the worker injects them (in the listed order — that
    /// order is part of the determinism contract) and replies with a
    /// `migration_applied` frame.
    EliteExchange { id: u64, generation: usize, incoming: Vec<IncomingMigrants> },
    /// Coordinator → worker: ship back the full final island
    /// populations for the global merge.
    ShardFront { id: u64 },
    /// Coordinator → worker: replicate one finalized beacon parameter
    /// set. `index` is the authoritative store id (pushes arrive in
    /// index order — the replica enforces contiguity so worker ids are
    /// identical to coordinator ids); `qc` is the beacon's quantization
    /// config, which the worker's share-only `BeaconManager` needs so
    /// mid-window candidates resolve `share_target` exactly like the
    /// coordinator. Re-pushes after a worker reconnect are idempotent.
    ParamPush { id: u64, index: usize, name: String, tensors: Vec<Vec<f32>>, qc: QuantConfig },
    /// Coordinator (or a diagnostic client) → worker: read back one
    /// replicated set for verification.
    ParamFetch { id: u64, index: usize },
}

/// Migrants routed to one island of a worker's shard, grouped by source
/// island (the coordinator → worker leg of a migration boundary).
#[derive(Debug, Clone, PartialEq)]
pub struct IncomingMigrants {
    /// Global index of the receiving island.
    pub island: usize,
    /// `(from_island, migrants)` in topology-source order.
    pub sources: Vec<(usize, Vec<Individual>)>,
}

/// One island's elites as shipped worker → coordinator at a boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardElites {
    pub island: usize,
    pub elites: Vec<Individual>,
}

/// Per-island generation bookkeeping after a migration was applied —
/// the coordinator synthesizes the boundary `Generation` events from
/// these instead of having workers stream them out of order.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStats {
    pub evaluations: usize,
    pub best_err: f64,
    pub feasible: usize,
    pub pop_size: usize,
}

/// One island's `migration_applied` entry: per-source acceptance
/// counts, generation stats, and the post-migration snapshot the
/// coordinator keeps so a later worker loss can replay from here.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardMigration {
    pub island: usize,
    /// `(from_island, accepted)` per injected source, in order.
    pub accepted: Vec<(usize, usize)>,
    pub stats: ShardStats,
    pub state: IslandSnapshot,
}

/// One island's slice of the `shard_front` reply. This is the FULL
/// final population, not the island-local front: the global merge
/// re-ranks the concatenation, and dropping dominated locals here would
/// change crowding/dedup relative to the single-process run.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardPop {
    pub island: usize,
    pub evaluations: usize,
    pub pop: Vec<Individual>,
}

/// Parse failure; carries the request id when one could be extracted so
/// the error frame can still be correlated.
#[derive(Debug)]
pub struct ProtocolError {
    pub id: Option<u64>,
    pub message: String,
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for ProtocolError {}

/// Extract a request/frame id: must be a non-negative integer small
/// enough to survive the f64 wire representation. A fractional or
/// negative id must NOT silently truncate — `{"id":3.9}` targeting
/// request 3 would be a cross-request correlation bug.
fn get_u64(j: &Json, key: &str) -> Option<u64> {
    j.get(key)
        .and_then(Json::as_f64)
        .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= 9.0e15)
        .map(|n| n as u64)
}

// --------------------------------------------------- dist payload codecs

/// Individual wire form carries all five fields: the merge re-ranks, but
/// snapshots must restore the exact in-memory state, rank/crowding
/// included.
fn ind_to_json(i: &Individual) -> Json {
    obj(vec![
        ("genome", Json::Arr(i.genome.iter().map(|g| Json::Num(*g as f64)).collect())),
        ("objectives", Json::Arr(i.objectives.iter().map(|o| Json::Num(*o)).collect())),
        ("violation", i.violation.into()),
        // usize::MAX (the unranked sentinel) exceeds 2^53; the emitter
        // prints the rounded float and the saturating cast in `as_usize`
        // maps it back to exactly usize::MAX on parse.
        ("rank", Json::Num(i.rank as f64)),
        ("crowding", i.crowding.into()),
    ])
}

fn ind_from_json(j: &Json) -> Result<Individual, ProtocolError> {
    let genome = j
        .get("genome")
        .and_then(Json::as_arr)
        .ok_or_else(|| ProtocolError { id: None, message: "individual missing 'genome'".into() })?
        .iter()
        .filter_map(Json::as_i64)
        .collect();
    Ok(Individual {
        genome,
        objectives: j.get("objectives").and_then(Json::f64_vec).unwrap_or_default(),
        violation: j.get("violation").and_then(Json::as_f64).unwrap_or(0.0),
        rank: j.get("rank").and_then(Json::as_usize).unwrap_or(usize::MAX),
        crowding: j.get("crowding").and_then(Json::as_f64).unwrap_or(0.0),
    })
}

fn inds_to_json(xs: &[Individual]) -> Json {
    Json::Arr(xs.iter().map(ind_to_json).collect())
}

fn inds_from_json(j: Option<&Json>) -> Result<Vec<Individual>, ProtocolError> {
    j.and_then(Json::as_arr).unwrap_or(&[]).iter().map(ind_from_json).collect()
}

// Also the checkpoint-file payload codec (`store::checkpoint`): the
// wire and the disk must agree bitwise on what an island snapshot is.
pub(crate) fn snapshot_to_json(s: &IslandSnapshot) -> Json {
    obj(vec![
        ("island", s.island.into()),
        // u64 state words would lose low bits through the f64 wire type.
        ("rng", Json::Arr(s.rng.iter().map(|w| w.to_string().into()).collect())),
        ("evaluations", s.evaluations.into()),
        ("pop", inds_to_json(&s.pop)),
    ])
}

pub(crate) fn snapshot_from_json(j: &Json) -> Result<IslandSnapshot, ProtocolError> {
    let bad = |msg: &str| ProtocolError { id: None, message: msg.into() };
    let island = j
        .get("island")
        .and_then(Json::as_usize)
        .ok_or_else(|| bad("snapshot missing 'island'"))?;
    let words: Vec<u64> = j
        .get("rng")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(|w| w.as_str().and_then(|s| s.parse::<u64>().ok()))
        .collect();
    let rng: [u64; 4] =
        words.try_into().map_err(|_| bad("snapshot 'rng' must be 4 decimal strings"))?;
    Ok(IslandSnapshot {
        island,
        rng,
        evaluations: j.get("evaluations").and_then(Json::as_usize).unwrap_or(0),
        pop: inds_from_json(j.get("pop"))?,
    })
}

fn tensors_to_json(tensors: &[Vec<f32>]) -> Json {
    Json::Arr(
        tensors
            .iter()
            .map(|t| Json::Arr(t.iter().map(|v| Json::Num(f64::from(*v))).collect()))
            .collect(),
    )
}

/// Parse replicated f32 tensors. Every f32 round-trips exactly through
/// f64; anything a cast would alter was not written by us (same
/// contract as the eval-store codec).
fn tensors_from_json(j: Option<&Json>) -> Result<Vec<Vec<f32>>, ProtocolError> {
    let bad = |msg: String| ProtocolError { id: None, message: msg };
    let mut tensors = Vec::new();
    for (t, tj) in j.and_then(Json::as_arr).unwrap_or(&[]).iter().enumerate() {
        let vals = tj
            .as_arr()
            .ok_or_else(|| bad(format!("tensors[{t}] must be an array of numbers")))?;
        let mut data = Vec::with_capacity(vals.len());
        for (k, vj) in vals.iter().enumerate() {
            let v = vj
                .as_f64()
                .ok_or_else(|| bad(format!("tensors[{t}][{k}] must be a number")))?;
            let f = v as f32;
            if f64::from(f).to_bits() != v.to_bits() {
                return Err(bad(format!("tensors[{t}][{k}] = {v} is not an f32 value")));
            }
            data.push(f);
        }
        tensors.push(data);
    }
    Ok(tensors)
}

/// Quantization configs travel as two bit-width arrays (`[2,4,8,16]`
/// values) — the searchable `Bits` domain, validated on parse. Also the
/// checkpoint-file beacon codec (`store::checkpoint`): the wire and the
/// disk must agree on what a beacon's config is.
pub(crate) fn qc_to_json(qc: &QuantConfig) -> Json {
    let widths =
        |bits: &[Bits]| Json::Arr(bits.iter().map(|b| Json::Num(f64::from(b.bits()))).collect());
    obj(vec![("w_bits", widths(&qc.w_bits)), ("a_bits", widths(&qc.a_bits))])
}

pub(crate) fn qc_from_json(j: Option<&Json>) -> Result<QuantConfig, ProtocolError> {
    let bad = |msg: String| ProtocolError { id: None, message: msg };
    let j = j.ok_or_else(|| bad("missing 'qc'".into()))?;
    let widths = |key: &str| -> Result<Vec<Bits>, ProtocolError> {
        j.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| bad(format!("'qc' missing '{key}'")))?
            .iter()
            .map(|w| {
                let n = w
                    .as_usize()
                    .and_then(|n| u32::try_from(n).ok())
                    .ok_or_else(|| bad(format!("'qc.{key}' entries must be bit widths")))?;
                Bits::from_bits(n).ok_or_else(|| bad(format!("'qc.{key}' has no {n}-bit level")))
            })
            .collect()
    };
    let qc = QuantConfig { w_bits: widths("w_bits")?, a_bits: widths("a_bits")? };
    if qc.w_bits.len() != qc.a_bits.len() || qc.w_bits.is_empty() {
        return Err(bad("'qc' bit arrays must be non-empty and equal-length".into()));
    }
    Ok(qc)
}

fn parse_incoming_migrants(m: &Json) -> Result<IncomingMigrants, ProtocolError> {
    let island = m.get("island").and_then(Json::as_usize).ok_or_else(|| ProtocolError {
        id: None,
        message: "migrant group missing 'island'".into(),
    })?;
    let sources = m
        .get("sources")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .map(|s| {
            let from = s.get("from").and_then(Json::as_usize).ok_or_else(|| ProtocolError {
                id: None,
                message: "migrant source missing 'from'".into(),
            })?;
            Ok((from, inds_from_json(s.get("migrants"))?))
        })
        .collect::<Result<_, ProtocolError>>()?;
    Ok(IncomingMigrants { island, sources })
}

fn parse_shard_elites(s: &Json) -> Result<ShardElites, ProtocolError> {
    Ok(ShardElites {
        island: s.get("island").and_then(Json::as_usize).ok_or_else(|| ProtocolError {
            id: None,
            message: "shard entry missing 'island'".into(),
        })?,
        elites: inds_from_json(s.get("elites"))?,
    })
}

fn parse_shard_migration(s: &Json) -> Result<ShardMigration, ProtocolError> {
    let island = s.get("island").and_then(Json::as_usize).ok_or_else(|| ProtocolError {
        id: None,
        message: "shard entry missing 'island'".into(),
    })?;
    let accepted = s
        .get("accepted")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .filter_map(|pair| {
            let p = pair.as_arr()?;
            Some((p.first()?.as_usize()?, p.get(1)?.as_usize()?))
        })
        .collect();
    let num = |key: &str| s.get(key).and_then(Json::as_usize).unwrap_or(0);
    let state = s
        .get("state")
        .ok_or_else(|| ProtocolError { id: None, message: "shard entry missing 'state'".into() })
        .and_then(snapshot_from_json)?;
    Ok(ShardMigration {
        island,
        accepted,
        stats: ShardStats {
            evaluations: num("evaluations"),
            best_err: s.get("best_err").and_then(Json::as_f64).unwrap_or(f64::INFINITY),
            feasible: num("feasible"),
            pop_size: num("pop_size"),
        },
        state,
    })
}

fn parse_shard_pop(s: &Json) -> Result<ShardPop, ProtocolError> {
    Ok(ShardPop {
        island: s.get("island").and_then(Json::as_usize).ok_or_else(|| ProtocolError {
            id: None,
            message: "shard entry missing 'island'".into(),
        })?,
        evaluations: s.get("evaluations").and_then(Json::as_usize).unwrap_or(0),
        pop: inds_from_json(s.get("pop"))?,
    })
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Search { id, spec } => obj(vec![
                ("op", "search".into()),
                ("id", (*id as usize).into()),
                ("spec", spec.clone()),
            ]),
            Request::Cancel { id } => {
                obj(vec![("op", "cancel".into()), ("id", (*id as usize).into())])
            }
            Request::Stats => obj(vec![("op", "stats".into())]),
            Request::Ping => obj(vec![("op", "ping".into())]),
            Request::Shutdown => obj(vec![("op", "shutdown".into())]),
            Request::RegisterPlatform { id, manifest } => obj(vec![
                ("op", "register_platform".into()),
                ("id", (*id as usize).into()),
                ("manifest", manifest.clone()),
            ]),
            Request::Platforms => obj(vec![("op", "platforms".into())]),
            Request::ShardAssign { id, spec, islands, base_gen, restore } => obj(vec![
                ("op", "shard_assign".into()),
                ("id", (*id as usize).into()),
                ("spec", spec.clone()),
                ("islands", Json::Arr(islands.iter().map(|i| (*i).into()).collect())),
                ("base_gen", (*base_gen).into()),
                ("restore", Json::Arr(restore.iter().map(snapshot_to_json).collect())),
            ]),
            Request::RunIslands { id, upto_gen } => obj(vec![
                ("op", "run_islands".into()),
                ("id", (*id as usize).into()),
                ("upto_gen", (*upto_gen).into()),
            ]),
            Request::EliteExchange { id, generation, incoming } => obj(vec![
                ("op", "elite_exchange".into()),
                ("id", (*id as usize).into()),
                ("generation", (*generation).into()),
                (
                    "incoming",
                    Json::Arr(
                        incoming
                            .iter()
                            .map(|m| {
                                let sources = m
                                    .sources
                                    .iter()
                                    .map(|(from, migrants)| {
                                        obj(vec![
                                            ("from", (*from).into()),
                                            ("migrants", inds_to_json(migrants)),
                                        ])
                                    })
                                    .collect();
                                obj(vec![
                                    ("island", m.island.into()),
                                    ("sources", Json::Arr(sources)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Request::ShardFront { id } => {
                obj(vec![("op", "shard_front".into()), ("id", (*id as usize).into())])
            }
            Request::ParamPush { id, index, name, tensors, qc } => obj(vec![
                ("op", "param_push".into()),
                ("id", (*id as usize).into()),
                ("index", (*index).into()),
                ("name", name.as_str().into()),
                ("tensors", tensors_to_json(tensors)),
                ("qc", qc_to_json(qc)),
            ]),
            Request::ParamFetch { id, index } => obj(vec![
                ("op", "param_fetch".into()),
                ("id", (*id as usize).into()),
                ("index", (*index).into()),
            ]),
        }
    }

    /// One wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(line: &str) -> Result<Request, ProtocolError> {
        let j = Json::parse(line.trim())
            .map_err(|e| ProtocolError { id: None, message: format!("bad frame: {e}") })?;
        let id = get_u64(&j, "id");
        let op = j.get("op").and_then(Json::as_str).ok_or_else(|| ProtocolError {
            id,
            message: "frame missing 'op'".into(),
        })?;
        let need_id = |id: Option<u64>| {
            id.ok_or_else(|| ProtocolError {
                id: None,
                message: format!("'{op}' needs a numeric 'id'"),
            })
        };
        match op {
            "search" => {
                let spec = j
                    .get("spec")
                    .cloned()
                    .ok_or_else(|| ProtocolError { id, message: "'search' needs a 'spec'".into() })?;
                Ok(Request::Search { id: need_id(id)?, spec })
            }
            "cancel" => Ok(Request::Cancel { id: need_id(id)? }),
            "stats" => Ok(Request::Stats),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            "register_platform" => {
                let manifest = j.get("manifest").cloned().ok_or_else(|| ProtocolError {
                    id,
                    message: "'register_platform' needs a 'manifest'".into(),
                })?;
                Ok(Request::RegisterPlatform { id: need_id(id)?, manifest })
            }
            "platforms" => Ok(Request::Platforms),
            "shard_assign" => {
                let spec = j.get("spec").cloned().ok_or_else(|| ProtocolError {
                    id,
                    message: "'shard_assign' needs a 'spec'".into(),
                })?;
                let islands = j.get("islands").and_then(Json::usize_vec).unwrap_or_default();
                if islands.is_empty() {
                    return Err(ProtocolError {
                        id,
                        message: "'shard_assign' needs a non-empty 'islands' array".into(),
                    });
                }
                let restore = j
                    .get("restore")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(snapshot_from_json)
                    .collect::<Result<_, _>>()
                    .map_err(|e: ProtocolError| ProtocolError { id, message: e.message })?;
                Ok(Request::ShardAssign {
                    id: need_id(id)?,
                    spec,
                    islands,
                    base_gen: j.get("base_gen").and_then(Json::as_usize).unwrap_or(0),
                    restore,
                })
            }
            "run_islands" => {
                let upto_gen =
                    j.get("upto_gen").and_then(Json::as_usize).ok_or_else(|| ProtocolError {
                        id,
                        message: "'run_islands' needs 'upto_gen'".into(),
                    })?;
                Ok(Request::RunIslands { id: need_id(id)?, upto_gen })
            }
            "elite_exchange" => {
                let incoming = j
                    .get("incoming")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_incoming_migrants)
                    .collect::<Result<_, _>>()
                    .map_err(|e: ProtocolError| ProtocolError { id, message: e.message })?;
                Ok(Request::EliteExchange {
                    id: need_id(id)?,
                    generation: j.get("generation").and_then(Json::as_usize).unwrap_or(0),
                    incoming,
                })
            }
            "shard_front" => Ok(Request::ShardFront { id: need_id(id)? }),
            "param_push" => {
                let index = j.get("index").and_then(Json::as_usize).ok_or_else(|| {
                    ProtocolError { id, message: "'param_push' needs an 'index'".into() }
                })?;
                let name = j.get("name").and_then(Json::as_str).ok_or_else(|| {
                    ProtocolError { id, message: "'param_push' needs a 'name'".into() }
                })?;
                let tensors = tensors_from_json(j.get("tensors"))
                    .map_err(|e| ProtocolError { id, message: e.message })?;
                let qc = qc_from_json(j.get("qc"))
                    .map_err(|e| ProtocolError { id, message: e.message })?;
                Ok(Request::ParamPush {
                    id: need_id(id)?,
                    index,
                    name: name.to_string(),
                    tensors,
                    qc,
                })
            }
            "param_fetch" => {
                let index = j.get("index").and_then(Json::as_usize).ok_or_else(|| {
                    ProtocolError { id, message: "'param_fetch' needs an 'index'".into() }
                })?;
                Ok(Request::ParamFetch { id: need_id(id)?, index })
            }
            other => Err(ProtocolError { id, message: format!("unknown op '{other}'") }),
        }
    }
}

/// One per-platform metric entry of a front row.
#[derive(Debug, Clone, PartialEq)]
pub struct HwEntry {
    pub platform: String,
    pub speedup: f64,
    pub energy_uj: Option<f64>,
}

/// One Pareto solution as served over the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontRow {
    /// `QuantConfig::display_wa` rendering (e.g. `W4A8 ...`).
    pub config: String,
    pub wer_v: f64,
    pub wer_t: f64,
    pub cp_r: f64,
    pub size_mb: f64,
    pub param_set: String,
    pub hw: Vec<HwEntry>,
}

impl FrontRow {
    pub fn from_row(row: &SolutionRow) -> FrontRow {
        FrontRow {
            config: row.qc.display_wa(),
            wer_v: row.wer_v,
            wer_t: row.wer_t,
            cp_r: row.cp_r,
            size_mb: row.size_mb,
            param_set: row.param_set.clone(),
            hw: row
                .hw
                .iter()
                .map(|h| HwEntry {
                    platform: h.platform.clone(),
                    speedup: h.speedup,
                    energy_uj: h.energy_uj,
                })
                .collect(),
        }
    }

    fn to_json(&self) -> Json {
        let hw: Vec<Json> = self
            .hw
            .iter()
            .map(|h| {
                obj(vec![
                    ("platform", h.platform.as_str().into()),
                    ("speedup", h.speedup.into()),
                    ("energy_uj", h.energy_uj.map_or(Json::Null, Json::Num)),
                ])
            })
            .collect();
        obj(vec![
            ("config", self.config.as_str().into()),
            ("wer_v", self.wer_v.into()),
            ("wer_t", self.wer_t.into()),
            ("cp_r", self.cp_r.into()),
            ("size_mb", self.size_mb.into()),
            ("param_set", self.param_set.as_str().into()),
            ("hw", Json::Arr(hw)),
        ])
    }

    fn from_json(j: &Json) -> Result<FrontRow, ProtocolError> {
        let field = |key: &str| {
            j.get(key).and_then(Json::as_f64).ok_or_else(|| ProtocolError {
                id: None,
                message: format!("row missing '{key}'"),
            })
        };
        Ok(FrontRow {
            config: j.get("config").and_then(Json::as_str).unwrap_or_default().to_string(),
            wer_v: field("wer_v")?,
            wer_t: field("wer_t")?,
            cp_r: field("cp_r")?,
            size_mb: field("size_mb")?,
            param_set: j.get("param_set").and_then(Json::as_str).unwrap_or_default().to_string(),
            hw: j
                .get("hw")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|h| HwEntry {
                    platform: h
                        .get("platform")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    speedup: h.get("speedup").and_then(Json::as_f64).unwrap_or(f64::NAN),
                    energy_uj: h.get("energy_uj").and_then(Json::as_f64),
                })
                .collect(),
        })
    }
}

/// One entry of the `platforms` discovery reply. `source` is the
/// registry's [`PlatformSource`](crate::hw::registry::PlatformSource)
/// rendering (`builtin` / `custom` / `manifest`), or `manifest (tenant)`
/// for a manifest registered on this connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlatformInfo {
    pub name: String,
    pub source: String,
}

/// Server-level counter snapshot (the `stats` reply).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStats {
    pub executions: usize,
    pub cache_hits: usize,
    pub unique_solutions: usize,
    /// Memo entries discarded so far (capacity rotation + param-set
    /// purges).
    pub evictions: usize,
    /// Beacon parameter sets retired so far.
    pub param_sets_evicted: usize,
    /// The shared result cache was poisoned by a worker panic.
    pub poisoned: bool,
    /// Search requests accepted since the server started.
    pub requests: usize,
    /// Searches currently in flight.
    pub active: usize,
    /// Whether the server evaluates through the hermetic surrogate.
    pub surrogate: bool,
}

/// Server → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Started {
        id: u64,
        name: String,
        num_vars: usize,
        objectives: Vec<String>,
        threads: usize,
        islands: usize,
    },
    Generation {
        id: u64,
        generation: usize,
        evaluations: usize,
        best_err: f64,
        feasible: usize,
        pop_size: usize,
        island: Option<usize>,
    },
    Beacon { id: u64, name: String, retrain_steps: usize },
    Migration { id: u64, generation: usize, from: usize, to: usize, accepted: usize },
    /// Terminal success frame of one search request.
    Front {
        id: u64,
        objectives: Vec<String>,
        rows: Vec<FrontRow>,
        evaluations: usize,
        /// Executions / cache hits during this request's window (deltas
        /// of the shared service counters: cross-request hits count —
        /// the reuse signal — and concurrent requests' activity is
        /// included; exact when requests are serial).
        exec_calls: usize,
        cache_hits: usize,
        wall_secs: f64,
        hypervolume: Option<f64>,
    },
    /// Terminal failure frame (`kind` is `SearchError::kind`, plus
    /// `"protocol"` and `"panic"`); `id` is absent when a malformed line
    /// could not be correlated.
    Error { id: Option<u64>, kind: String, message: String },
    Stats(ServerStats),
    Pong,
    Bye,
    /// Ack of `register_platform`, echoing the (normalized) name the
    /// connection's searches may now bind objectives to.
    PlatformRegistered { id: u64, name: String },
    /// Reply to the `platforms` op: sorted discovery listing.
    Platforms { platforms: Vec<PlatformInfo> },
    /// Worker ack of `shard_assign`, echoing the owned global indices.
    ShardAssigned { id: u64, islands: Vec<usize> },
    /// Worker reply to `run_islands`: this shard's elites at a boundary.
    EliteExchange { id: u64, generation: usize, shards: Vec<ShardElites> },
    /// Worker reply to the `elite_exchange` op: per-island acceptance,
    /// stats, and post-migration snapshots.
    MigrationApplied { id: u64, generation: usize, shards: Vec<ShardMigration> },
    /// Worker reply to `shard_front`: full final island populations.
    ShardFront { id: u64, shards: Vec<ShardPop> },
    /// Worker ack of `param_push`: the set landed (or was already held —
    /// re-pushes after a reconnect are idempotent) at exactly `index`.
    ParamPushed { id: u64, index: usize },
    /// Worker reply to `param_fetch`: one replicated set, tensors on the
    /// lossless f32 codec so round trips are bit-for-bit.
    ParamSet { id: u64, index: usize, name: String, tensors: Vec<Vec<f32>> },
    /// Liveness signal streamed while a `run_islands` advance is in
    /// flight; a coordinator that stops seeing these (or generation
    /// frames) past its deadline declares the worker lost. Also
    /// streamed while a `param_push` lands, so replication windows
    /// (device upload included) never trip the liveness deadline.
    WorkerHeartbeat { id: u64, generation: usize },
}

/// Translate a streaming `SearchEvent` into the wire frame for `id`.
/// `Finished` is skipped — the terminal `front` frame carries its data.
pub fn event_frame(id: u64, event: &SearchEvent) -> Option<Frame> {
    Some(match event {
        SearchEvent::Started { name, num_vars, objectives, threads, islands } => Frame::Started {
            id,
            name: name.clone(),
            num_vars: *num_vars,
            objectives: objectives.clone(),
            threads: *threads,
            islands: *islands,
        },
        SearchEvent::Generation(log) => Frame::Generation {
            id,
            generation: log.generation,
            evaluations: log.evaluations,
            best_err: log.best_err,
            feasible: log.feasible,
            pop_size: log.pop_size,
            island: log.island,
        },
        SearchEvent::BeaconCreated { name, retrain_steps } => {
            Frame::Beacon { id, name: name.clone(), retrain_steps: *retrain_steps }
        }
        SearchEvent::Migration { generation, from, to, accepted } => Frame::Migration {
            id,
            generation: *generation,
            from: *from,
            to: *to,
            accepted: *accepted,
        },
        // Shard lifecycle events are coordinator-local: they describe
        // the coordinator's own worker fleet, which a serve client of
        // the coordinator has no use for.
        SearchEvent::ShardAssigned { .. } | SearchEvent::ShardLost { .. } => return None,
        SearchEvent::Finished { .. } => return None,
    })
}

/// The terminal success frame for a finished request.
pub fn front_frame(id: u64, outcome: &SearchOutcome) -> Frame {
    Frame::Front {
        id,
        objectives: outcome.objective_names.clone(),
        rows: outcome.rows.iter().map(FrontRow::from_row).collect(),
        evaluations: outcome.evaluations,
        exec_calls: outcome.exec_calls,
        cache_hits: outcome.cache_hits,
        wall_secs: outcome.wall_secs,
        hypervolume: outcome.front_hypervolume,
    }
}

impl Frame {
    pub fn to_json(&self) -> Json {
        let uid = |id: u64| Json::Num(id as f64);
        match self {
            Frame::Started { id, name, num_vars, objectives, threads, islands } => obj(vec![
                ("event", "started".into()),
                ("id", uid(*id)),
                ("name", name.as_str().into()),
                ("num_vars", (*num_vars).into()),
                (
                    "objectives",
                    Json::Arr(objectives.iter().map(|o| o.as_str().into()).collect()),
                ),
                ("threads", (*threads).into()),
                ("islands", (*islands).into()),
            ]),
            Frame::Generation { id, generation, evaluations, best_err, feasible, pop_size, island } => {
                obj(vec![
                    ("event", "generation".into()),
                    ("id", uid(*id)),
                    ("generation", (*generation).into()),
                    ("evaluations", (*evaluations).into()),
                    ("best_err", (*best_err).into()),
                    ("feasible", (*feasible).into()),
                    ("pop_size", (*pop_size).into()),
                    ("island", island.map_or(Json::Null, |i| i.into())),
                ])
            }
            Frame::Beacon { id, name, retrain_steps } => obj(vec![
                ("event", "beacon".into()),
                ("id", uid(*id)),
                ("name", name.as_str().into()),
                ("retrain_steps", (*retrain_steps).into()),
            ]),
            Frame::Migration { id, generation, from, to, accepted } => obj(vec![
                ("event", "migration".into()),
                ("id", uid(*id)),
                ("generation", (*generation).into()),
                ("from", (*from).into()),
                ("to", (*to).into()),
                ("accepted", (*accepted).into()),
            ]),
            Frame::Front {
                id,
                objectives,
                rows,
                evaluations,
                exec_calls,
                cache_hits,
                wall_secs,
                hypervolume,
            } => obj(vec![
                ("event", "front".into()),
                ("id", uid(*id)),
                (
                    "objectives",
                    Json::Arr(objectives.iter().map(|o| o.as_str().into()).collect()),
                ),
                ("rows", Json::Arr(rows.iter().map(FrontRow::to_json).collect())),
                ("evaluations", (*evaluations).into()),
                ("exec_calls", (*exec_calls).into()),
                ("cache_hits", (*cache_hits).into()),
                ("wall_secs", (*wall_secs).into()),
                ("hypervolume", hypervolume.map_or(Json::Null, Json::Num)),
            ]),
            Frame::Error { id, kind, message } => obj(vec![
                ("event", "error".into()),
                ("id", id.map_or(Json::Null, |i| Json::Num(i as f64))),
                ("kind", kind.as_str().into()),
                ("message", message.as_str().into()),
            ]),
            Frame::Stats(s) => obj(vec![
                ("event", "stats".into()),
                ("executions", s.executions.into()),
                ("cache_hits", s.cache_hits.into()),
                ("unique_solutions", s.unique_solutions.into()),
                ("evictions", s.evictions.into()),
                ("param_sets_evicted", s.param_sets_evicted.into()),
                ("poisoned", s.poisoned.into()),
                ("requests", s.requests.into()),
                ("active", s.active.into()),
                ("surrogate", s.surrogate.into()),
            ]),
            Frame::Pong => obj(vec![("event", "pong".into())]),
            Frame::Bye => obj(vec![("event", "bye".into())]),
            Frame::PlatformRegistered { id, name } => obj(vec![
                ("event", "platform_registered".into()),
                ("id", uid(*id)),
                ("name", name.as_str().into()),
            ]),
            Frame::Platforms { platforms } => obj(vec![
                ("event", "platforms".into()),
                (
                    "platforms",
                    Json::Arr(
                        platforms
                            .iter()
                            .map(|p| {
                                obj(vec![
                                    ("name", p.name.as_str().into()),
                                    ("source", p.source.as_str().into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Frame::ShardAssigned { id, islands } => obj(vec![
                ("event", "shard_assigned".into()),
                ("id", uid(*id)),
                ("islands", Json::Arr(islands.iter().map(|i| (*i).into()).collect())),
            ]),
            Frame::EliteExchange { id, generation, shards } => obj(vec![
                ("event", "elite_exchange".into()),
                ("id", uid(*id)),
                ("generation", (*generation).into()),
                (
                    "shards",
                    Json::Arr(
                        shards
                            .iter()
                            .map(|s| {
                                obj(vec![
                                    ("island", s.island.into()),
                                    ("elites", inds_to_json(&s.elites)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Frame::MigrationApplied { id, generation, shards } => obj(vec![
                ("event", "migration_applied".into()),
                ("id", uid(*id)),
                ("generation", (*generation).into()),
                (
                    "shards",
                    Json::Arr(
                        shards
                            .iter()
                            .map(|s| {
                                let accepted = s
                                    .accepted
                                    .iter()
                                    .map(|(from, n)| Json::Arr(vec![(*from).into(), (*n).into()]))
                                    .collect();
                                obj(vec![
                                    ("island", s.island.into()),
                                    ("accepted", Json::Arr(accepted)),
                                    ("evaluations", s.stats.evaluations.into()),
                                    ("best_err", s.stats.best_err.into()),
                                    ("feasible", s.stats.feasible.into()),
                                    ("pop_size", s.stats.pop_size.into()),
                                    ("state", snapshot_to_json(&s.state)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Frame::ShardFront { id, shards } => obj(vec![
                ("event", "shard_front".into()),
                ("id", uid(*id)),
                (
                    "shards",
                    Json::Arr(
                        shards
                            .iter()
                            .map(|s| {
                                obj(vec![
                                    ("island", s.island.into()),
                                    ("evaluations", s.evaluations.into()),
                                    ("pop", inds_to_json(&s.pop)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Frame::ParamPushed { id, index } => obj(vec![
                ("event", "param_pushed".into()),
                ("id", uid(*id)),
                ("index", (*index).into()),
            ]),
            Frame::ParamSet { id, index, name, tensors } => obj(vec![
                ("event", "param_set".into()),
                ("id", uid(*id)),
                ("index", (*index).into()),
                ("name", name.as_str().into()),
                ("tensors", tensors_to_json(tensors)),
            ]),
            Frame::WorkerHeartbeat { id, generation } => obj(vec![
                ("event", "worker_heartbeat".into()),
                ("id", uid(*id)),
                ("generation", (*generation).into()),
            ]),
        }
    }

    /// One wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(line: &str) -> Result<Frame, ProtocolError> {
        let j = Json::parse(line.trim())
            .map_err(|e| ProtocolError { id: None, message: format!("bad frame: {e}") })?;
        let event = j.get("event").and_then(Json::as_str).ok_or_else(|| ProtocolError {
            id: get_u64(&j, "id"),
            message: "frame missing 'event'".into(),
        })?;
        let id = || {
            get_u64(&j, "id").ok_or_else(|| ProtocolError {
                id: None,
                message: format!("'{event}' frame missing 'id'"),
            })
        };
        let num = |key: &str| {
            j.get(key).and_then(Json::as_usize).ok_or_else(|| ProtocolError {
                id: get_u64(&j, "id"),
                message: format!("'{event}' frame missing '{key}'"),
            })
        };
        let strings = |key: &str| -> Vec<String> {
            j.get(key)
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect()
        };
        Ok(match event {
            "started" => Frame::Started {
                id: id()?,
                name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                num_vars: num("num_vars")?,
                objectives: strings("objectives"),
                threads: num("threads")?,
                islands: num("islands")?,
            },
            "generation" => Frame::Generation {
                id: id()?,
                generation: num("generation")?,
                evaluations: num("evaluations")?,
                best_err: j.get("best_err").and_then(Json::as_f64).unwrap_or(f64::NAN),
                feasible: num("feasible")?,
                pop_size: num("pop_size")?,
                island: j.get("island").and_then(Json::as_usize),
            },
            "beacon" => Frame::Beacon {
                id: id()?,
                name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                retrain_steps: num("retrain_steps")?,
            },
            "migration" => Frame::Migration {
                id: id()?,
                generation: num("generation")?,
                from: num("from")?,
                to: num("to")?,
                accepted: num("accepted")?,
            },
            "front" => Frame::Front {
                id: id()?,
                objectives: strings("objectives"),
                rows: j
                    .get("rows")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(FrontRow::from_json)
                    .collect::<Result<_, _>>()?,
                evaluations: num("evaluations")?,
                exec_calls: num("exec_calls")?,
                cache_hits: num("cache_hits")?,
                wall_secs: j.get("wall_secs").and_then(Json::as_f64).unwrap_or(0.0),
                hypervolume: j.get("hypervolume").and_then(Json::as_f64),
            },
            "error" => Frame::Error {
                id: get_u64(&j, "id"),
                kind: j.get("kind").and_then(Json::as_str).unwrap_or("unknown").to_string(),
                message: j.get("message").and_then(Json::as_str).unwrap_or_default().to_string(),
            },
            "stats" => Frame::Stats(ServerStats {
                executions: num("executions")?,
                cache_hits: num("cache_hits")?,
                unique_solutions: num("unique_solutions")?,
                // Lenient: frames from servers predating these counters
                // still parse (same posture as `poisoned`/`surrogate`).
                evictions: j.get("evictions").and_then(Json::as_usize).unwrap_or(0),
                param_sets_evicted: j
                    .get("param_sets_evicted")
                    .and_then(Json::as_usize)
                    .unwrap_or(0),
                poisoned: j.get("poisoned").and_then(Json::as_bool).unwrap_or(false),
                requests: num("requests")?,
                active: num("active")?,
                surrogate: j.get("surrogate").and_then(Json::as_bool).unwrap_or(false),
            }),
            "pong" => Frame::Pong,
            "bye" => Frame::Bye,
            "platform_registered" => Frame::PlatformRegistered {
                id: id()?,
                name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
            },
            "platforms" => Frame::Platforms {
                platforms: j
                    .get("platforms")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(|p| PlatformInfo {
                        name: p.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                        source: p
                            .get("source")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                    })
                    .collect(),
            },
            "shard_assigned" => Frame::ShardAssigned {
                id: id()?,
                islands: j.get("islands").and_then(Json::usize_vec).unwrap_or_default(),
            },
            "elite_exchange" => Frame::EliteExchange {
                id: id()?,
                generation: num("generation")?,
                shards: j
                    .get("shards")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_shard_elites)
                    .collect::<Result<_, _>>()?,
            },
            "migration_applied" => Frame::MigrationApplied {
                id: id()?,
                generation: num("generation")?,
                shards: j
                    .get("shards")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_shard_migration)
                    .collect::<Result<_, _>>()?,
            },
            "shard_front" => Frame::ShardFront {
                id: id()?,
                shards: j
                    .get("shards")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(parse_shard_pop)
                    .collect::<Result<_, _>>()?,
            },
            "param_pushed" => Frame::ParamPushed { id: id()?, index: num("index")? },
            "param_set" => Frame::ParamSet {
                id: id()?,
                index: num("index")?,
                name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                tensors: tensors_from_json(j.get("tensors"))?,
            },
            "worker_heartbeat" => Frame::WorkerHeartbeat { id: id()?, generation: num("generation")? },
            other => {
                return Err(ProtocolError {
                    id: get_u64(&j, "id"),
                    message: format!("unknown event '{other}'"),
                })
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExperimentSpec;

    #[test]
    fn requests_round_trip() {
        let reqs = vec![
            Request::Search { id: 3, spec: ExperimentSpec::exp1().to_json() },
            Request::Cancel { id: 7 },
            Request::Stats,
            Request::Ping,
            Request::Shutdown,
        ];
        for r in reqs {
            let line = r.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Request::parse(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn frames_round_trip() {
        let frames = vec![
            Frame::Started {
                id: 1,
                name: "exp".into(),
                num_vars: 8,
                objectives: vec!["WER_V".into(), "-speedup@silago".into()],
                threads: 4,
                islands: 1,
            },
            Frame::Generation {
                id: 1,
                generation: 2,
                evaluations: 40,
                best_err: 0.1625,
                feasible: 9,
                pop_size: 10,
                island: Some(2),
            },
            // No feasible solution yet: best_err is +Infinity and must
            // survive the wire (regression for the json emitter).
            Frame::Generation {
                id: 1,
                generation: 0,
                evaluations: 10,
                best_err: f64::INFINITY,
                feasible: 0,
                pop_size: 10,
                island: None,
            },
            Frame::Beacon { id: 1, name: "W2A8...".into(), retrain_steps: 200 },
            Frame::Migration { id: 1, generation: 5, from: 0, to: 1, accepted: 2 },
            Frame::Front {
                id: 1,
                objectives: vec!["WER_V".into()],
                rows: vec![FrontRow {
                    config: "W4A4 ...".into(),
                    wer_v: 0.17250000000000001,
                    wer_t: 0.18,
                    cp_r: 7.9,
                    size_mb: 0.61,
                    param_set: "baseline".into(),
                    hw: vec![HwEntry {
                        platform: "silago".into(),
                        speedup: 3.25,
                        energy_uj: None,
                    }],
                }],
                evaluations: 400,
                exec_calls: 120,
                cache_hits: 280,
                wall_secs: 1.25,
                hypervolume: Some(0.82),
            },
            Frame::Error { id: Some(4), kind: "cancelled".into(), message: "search cancelled".into() },
            Frame::Error { id: None, kind: "protocol".into(), message: "bad frame".into() },
            Frame::Stats(ServerStats {
                executions: 10,
                cache_hits: 5,
                unique_solutions: 8,
                evictions: 3,
                param_sets_evicted: 1,
                poisoned: false,
                requests: 2,
                active: 1,
                surrogate: true,
            }),
            Frame::Pong,
            Frame::Bye,
        ];
        for f in frames {
            let line = f.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Frame::parse(&line).unwrap(), f, "{line}");
        }
    }

    #[test]
    fn platform_ops_round_trip() {
        let manifest = crate::util::json::obj(vec![
            ("format_version", 1.0.into()),
            ("name", "lut-test".into()),
        ]);
        let reqs = vec![
            Request::RegisterPlatform { id: 5, manifest },
            Request::Platforms,
        ];
        for r in reqs {
            let line = r.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Request::parse(&line).unwrap(), r, "{line}");
        }
        let e = Request::parse(r#"{"op":"register_platform","id":5}"#).unwrap_err();
        assert!(e.message.contains("manifest"), "{e}");
        let e = Request::parse(r#"{"op":"register_platform","manifest":{}}"#).unwrap_err();
        assert!(e.message.contains("id"), "{e}");

        let frames = vec![
            Frame::PlatformRegistered { id: 5, name: "lut-test".into() },
            Frame::Platforms {
                platforms: vec![
                    PlatformInfo { name: "bitfusion".into(), source: "builtin".into() },
                    PlatformInfo { name: "lut-test".into(), source: "manifest (tenant)".into() },
                ],
            },
            Frame::Platforms { platforms: vec![] },
        ];
        for f in frames {
            let line = f.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Frame::parse(&line).unwrap(), f, "{line}");
        }
    }

    #[test]
    fn floats_round_trip_bitwise() {
        // Shortest-round-trip float formatting is what makes a served
        // front bitwise-comparable to the offline run that produced it.
        for v in [0.1, 1.0 / 3.0, 0.16000000000000003, 123456.789012345] {
            let f = Frame::Generation {
                id: 0,
                generation: 0,
                evaluations: 0,
                best_err: v,
                feasible: 0,
                pop_size: 0,
                island: None,
            };
            match Frame::parse(&f.to_line()).unwrap() {
                Frame::Generation { best_err, .. } => {
                    assert_eq!(best_err.to_bits(), v.to_bits())
                }
                other => panic!("wrong frame {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_lines_yield_protocol_errors_with_best_effort_ids() {
        assert!(Request::parse("{").is_err());
        assert!(Request::parse("[]").is_err());
        let e = Request::parse(r#"{"op":"warp","id":9}"#).unwrap_err();
        assert_eq!(e.id, Some(9), "id extracted even for unknown ops");
        let e = Request::parse(r#"{"op":"search"}"#).unwrap_err();
        assert!(e.message.contains("spec"), "{e}");
        let e = Request::parse(r#"{"id":1}"#).unwrap_err();
        assert!(e.message.contains("op"), "{e}");
    }

    fn sample_ind() -> Individual {
        Individual {
            genome: vec![3, -1, 4, 1],
            objectives: vec![0.16000000000000003, -2.5],
            violation: 0.0,
            rank: 0,
            crowding: 1.75,
        }
    }

    /// Unranked sentinel rank and boundary-individual crowding: the two
    /// extremes a snapshot must carry losslessly.
    fn edge_ind() -> Individual {
        Individual {
            genome: vec![0],
            objectives: vec![f64::INFINITY],
            violation: 12.5,
            rank: usize::MAX,
            crowding: f64::INFINITY,
        }
    }

    fn sample_snapshot() -> IslandSnapshot {
        IslandSnapshot {
            island: 2,
            rng: [u64::MAX, 0, 1, 0x9E3779B97F4A7C15],
            evaluations: 132,
            pop: vec![sample_ind(), edge_ind()],
        }
    }

    #[test]
    fn dist_requests_round_trip() {
        let reqs = vec![
            Request::ShardAssign {
                id: 11,
                spec: ExperimentSpec::exp1().to_json(),
                islands: vec![1, 2],
                base_gen: 4,
                restore: vec![sample_snapshot()],
            },
            Request::ShardAssign {
                id: 12,
                spec: ExperimentSpec::exp1().to_json(),
                islands: vec![0],
                base_gen: 0,
                restore: vec![],
            },
            Request::RunIslands { id: 11, upto_gen: 6 },
            Request::EliteExchange {
                id: 11,
                generation: 6,
                incoming: vec![IncomingMigrants {
                    island: 1,
                    sources: vec![(0, vec![sample_ind()]), (2, vec![])],
                }],
            },
            Request::ShardFront { id: 11 },
        ];
        for r in reqs {
            let line = r.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Request::parse(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn dist_frames_round_trip() {
        let frames = vec![
            Frame::ShardAssigned { id: 11, islands: vec![1, 2] },
            Frame::EliteExchange {
                id: 11,
                generation: 6,
                shards: vec![
                    ShardElites { island: 1, elites: vec![sample_ind()] },
                    ShardElites { island: 2, elites: vec![] },
                ],
            },
            Frame::MigrationApplied {
                id: 11,
                generation: 6,
                shards: vec![ShardMigration {
                    island: 1,
                    accepted: vec![(0, 2), (2, 0)],
                    stats: ShardStats {
                        evaluations: 92,
                        best_err: f64::INFINITY,
                        feasible: 0,
                        pop_size: 10,
                    },
                    state: sample_snapshot(),
                }],
            },
            Frame::ShardFront {
                id: 11,
                shards: vec![ShardPop {
                    island: 2,
                    evaluations: 132,
                    pop: vec![sample_ind(), edge_ind()],
                }],
            },
            Frame::WorkerHeartbeat { id: 11, generation: 5 },
        ];
        for f in frames {
            let line = f.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Frame::parse(&line).unwrap(), f, "{line}");
        }
    }

    #[test]
    fn param_ops_round_trip_bitwise() {
        // Denormal, negative zero and precision-heavy values: the f32
        // tensor codec must be bit-for-bit or replicated beacon sets
        // would diverge from the coordinator's.
        let tensors = vec![vec![1.0f32, -0.0, f32::MIN_POSITIVE, 0.1, 1.0e-40], vec![3.25]];
        let qc = QuantConfig {
            w_bits: vec![Bits::B2, Bits::B16],
            a_bits: vec![Bits::B8, Bits::B4],
        };
        let reqs = vec![
            Request::ParamPush {
                id: 11,
                index: 1,
                name: "beacon1[W2A8 ...]".into(),
                tensors: tensors.clone(),
                qc: qc.clone(),
            },
            Request::ParamFetch { id: 11, index: 1 },
        ];
        for r in reqs {
            let line = r.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            let back = Request::parse(&line).unwrap();
            assert_eq!(back, r, "{line}");
            if let Request::ParamPush { tensors: t2, .. } = &back {
                for (a, b) in tensors.iter().flatten().zip(t2.iter().flatten()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
        let frames = vec![
            Frame::ParamPushed { id: 11, index: 1 },
            Frame::ParamSet { id: 11, index: 1, name: "beacon1[W2A8 ...]".into(), tensors },
        ];
        for f in frames {
            let line = f.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Frame::parse(&line).unwrap(), f, "{line}");
        }
    }

    #[test]
    fn param_push_validates() {
        let e = Request::parse(r#"{"op":"param_push","id":1,"name":"b","qc":{}}"#).unwrap_err();
        assert!(e.message.contains("index"), "{e}");
        let e = Request::parse(r#"{"op":"param_push","id":1,"index":1,"qc":{}}"#).unwrap_err();
        assert!(e.message.contains("name"), "{e}");
        // A value no f32 produced must be rejected, not silently rounded.
        let e = Request::parse(
            r#"{"op":"param_push","id":1,"index":1,"name":"b","tensors":[[0.3000000000000001]],"qc":{"w_bits":[8],"a_bits":[8]}}"#,
        )
        .unwrap_err();
        assert!(e.message.contains("not an f32"), "{e}");
        // Bit widths outside the searchable domain are typed errors.
        let e = Request::parse(
            r#"{"op":"param_push","id":1,"index":1,"name":"b","tensors":[[1.5]],"qc":{"w_bits":[3],"a_bits":[8]}}"#,
        )
        .unwrap_err();
        assert!(e.message.contains("3-bit"), "{e}");
        let e = Request::parse(
            r#"{"op":"param_push","id":1,"index":1,"name":"b","tensors":[[1.5]],"qc":{"w_bits":[8,8],"a_bits":[8]}}"#,
        )
        .unwrap_err();
        assert!(e.message.contains("equal-length"), "{e}");
        let e = Request::parse(r#"{"op":"param_fetch","id":1}"#).unwrap_err();
        assert!(e.message.contains("index"), "{e}");
    }

    #[test]
    fn snapshot_codec_is_lossless_at_the_extremes() {
        // u64 RNG words would lose low bits through an f64, so they ride
        // as decimal strings; usize::MAX rank survives via the
        // saturating cast and +inf crowding via the Infinity spelling.
        let s = sample_snapshot();
        let back = snapshot_from_json(&snapshot_to_json(&s)).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.rng[0], u64::MAX);
        assert_eq!(back.pop[1].rank, usize::MAX);
        assert!(back.pop[1].crowding.is_infinite());
    }

    #[test]
    fn shard_assign_validates() {
        let e = Request::parse(r#"{"op":"shard_assign","id":1,"spec":{}}"#).unwrap_err();
        assert!(e.message.contains("islands"), "{e}");
        let e = Request::parse(r#"{"op":"shard_assign","id":1,"islands":[0]}"#).unwrap_err();
        assert!(e.message.contains("spec"), "{e}");
        let e = Request::parse(r#"{"op":"run_islands","id":1}"#).unwrap_err();
        assert!(e.message.contains("upto_gen"), "{e}");
    }

    #[test]
    fn fractional_or_negative_ids_are_rejected_not_truncated() {
        // `{"id":3.9}` must NOT become a cancel for request 3.
        for bad in [r#"{"op":"cancel","id":3.9}"#, r#"{"op":"cancel","id":-1}"#] {
            let e = Request::parse(bad).unwrap_err();
            assert!(e.message.contains("id"), "{e}");
        }
        assert_eq!(
            Request::parse(r#"{"op":"cancel","id":3}"#).unwrap(),
            Request::Cancel { id: 3 }
        );
    }
}
