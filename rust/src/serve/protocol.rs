//! Serve-mode wire protocol: line-delimited JSON over TCP, std-only.
//!
//! Every message is one JSON object on one line. Client → server messages
//! carry an `"op"` discriminator, server → client frames an `"event"`:
//!
//! ```text
//! → {"op":"search","id":1,"spec":{...ExperimentSpec JSON...}}
//! ← {"event":"started","id":1,"name":"exp2-silago","num_vars":8,...}
//! ← {"event":"generation","id":1,"generation":0,"best_err":0.17,...}
//! ← {"event":"front","id":1,"rows":[...],"cache_hits":120,...}
//! → {"op":"cancel","id":1}          (any time while 1 is in flight)
//! ← {"event":"error","id":1,"kind":"cancelled","message":"..."}
//! → {"op":"stats"}                  → {"event":"stats",...}
//! → {"op":"ping"}                   → {"event":"pong"}
//! → {"op":"shutdown"}               → {"event":"bye"}   (server stops)
//! ```
//!
//! Error frames carry the typed [`SearchError::kind`] string, so clients
//! match on failure classes without parsing messages; `"protocol"` marks
//! malformed input, `"busy"` the per-connection in-flight cap, and
//! `"panic"` the serve-layer backstop (none takes the connection down).
//! Numbers round-trip losslessly: the JSON codec emits
//! shortest-round-trip floats and `NaN`/`Infinity` spellings its parser
//! (and Python's json module) accepts, which is what makes served
//! fronts bitwise-comparable to offline runs. Caveat for foreign
//! clients: the non-finite spellings are a deliberate deviation from
//! RFC 8259 (matching Python's default), so a strict parser must treat
//! `NaN`/`Infinity` tokens the way Python's json module does — they
//! only ever appear in numeric positions like a generation's `best_err`
//! before any feasible solution exists.

use crate::coordinator::{SearchEvent, SearchOutcome, SolutionRow};
use crate::util::json::{obj, Json};

/// Client → server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Run a search; `spec` is raw `ExperimentSpec` JSON (parsed server
    /// side so validation errors come back typed, tagged with `id`).
    Search { id: u64, spec: Json },
    /// Cancel the in-flight search with this id (same connection).
    Cancel { id: u64 },
    /// Snapshot of the shared service counters.
    Stats,
    Ping,
    /// Stop the server once outstanding work is cancelled.
    Shutdown,
}

/// Parse failure; carries the request id when one could be extracted so
/// the error frame can still be correlated.
#[derive(Debug)]
pub struct ProtocolError {
    pub id: Option<u64>,
    pub message: String,
}

impl std::fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for ProtocolError {}

/// Extract a request/frame id: must be a non-negative integer small
/// enough to survive the f64 wire representation. A fractional or
/// negative id must NOT silently truncate — `{"id":3.9}` targeting
/// request 3 would be a cross-request correlation bug.
fn get_u64(j: &Json, key: &str) -> Option<u64> {
    j.get(key)
        .and_then(Json::as_f64)
        .filter(|n| n.fract() == 0.0 && *n >= 0.0 && *n <= 9.0e15)
        .map(|n| n as u64)
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Search { id, spec } => obj(vec![
                ("op", "search".into()),
                ("id", (*id as usize).into()),
                ("spec", spec.clone()),
            ]),
            Request::Cancel { id } => {
                obj(vec![("op", "cancel".into()), ("id", (*id as usize).into())])
            }
            Request::Stats => obj(vec![("op", "stats".into())]),
            Request::Ping => obj(vec![("op", "ping".into())]),
            Request::Shutdown => obj(vec![("op", "shutdown".into())]),
        }
    }

    /// One wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(line: &str) -> Result<Request, ProtocolError> {
        let j = Json::parse(line.trim())
            .map_err(|e| ProtocolError { id: None, message: format!("bad frame: {e}") })?;
        let id = get_u64(&j, "id");
        let op = j.get("op").and_then(Json::as_str).ok_or_else(|| ProtocolError {
            id,
            message: "frame missing 'op'".into(),
        })?;
        let need_id = |id: Option<u64>| {
            id.ok_or_else(|| ProtocolError {
                id: None,
                message: format!("'{op}' needs a numeric 'id'"),
            })
        };
        match op {
            "search" => {
                let spec = j
                    .get("spec")
                    .cloned()
                    .ok_or_else(|| ProtocolError { id, message: "'search' needs a 'spec'".into() })?;
                Ok(Request::Search { id: need_id(id)?, spec })
            }
            "cancel" => Ok(Request::Cancel { id: need_id(id)? }),
            "stats" => Ok(Request::Stats),
            "ping" => Ok(Request::Ping),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(ProtocolError { id, message: format!("unknown op '{other}'") }),
        }
    }
}

/// One per-platform metric entry of a front row.
#[derive(Debug, Clone, PartialEq)]
pub struct HwEntry {
    pub platform: String,
    pub speedup: f64,
    pub energy_uj: Option<f64>,
}

/// One Pareto solution as served over the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontRow {
    /// `QuantConfig::display_wa` rendering (e.g. `W4A8 ...`).
    pub config: String,
    pub wer_v: f64,
    pub wer_t: f64,
    pub cp_r: f64,
    pub size_mb: f64,
    pub param_set: String,
    pub hw: Vec<HwEntry>,
}

impl FrontRow {
    pub fn from_row(row: &SolutionRow) -> FrontRow {
        FrontRow {
            config: row.qc.display_wa(),
            wer_v: row.wer_v,
            wer_t: row.wer_t,
            cp_r: row.cp_r,
            size_mb: row.size_mb,
            param_set: row.param_set.clone(),
            hw: row
                .hw
                .iter()
                .map(|h| HwEntry {
                    platform: h.platform.clone(),
                    speedup: h.speedup,
                    energy_uj: h.energy_uj,
                })
                .collect(),
        }
    }

    fn to_json(&self) -> Json {
        let hw: Vec<Json> = self
            .hw
            .iter()
            .map(|h| {
                obj(vec![
                    ("platform", h.platform.as_str().into()),
                    ("speedup", h.speedup.into()),
                    ("energy_uj", h.energy_uj.map_or(Json::Null, Json::Num)),
                ])
            })
            .collect();
        obj(vec![
            ("config", self.config.as_str().into()),
            ("wer_v", self.wer_v.into()),
            ("wer_t", self.wer_t.into()),
            ("cp_r", self.cp_r.into()),
            ("size_mb", self.size_mb.into()),
            ("param_set", self.param_set.as_str().into()),
            ("hw", Json::Arr(hw)),
        ])
    }

    fn from_json(j: &Json) -> Result<FrontRow, ProtocolError> {
        let field = |key: &str| {
            j.get(key).and_then(Json::as_f64).ok_or_else(|| ProtocolError {
                id: None,
                message: format!("row missing '{key}'"),
            })
        };
        Ok(FrontRow {
            config: j.get("config").and_then(Json::as_str).unwrap_or_default().to_string(),
            wer_v: field("wer_v")?,
            wer_t: field("wer_t")?,
            cp_r: field("cp_r")?,
            size_mb: field("size_mb")?,
            param_set: j.get("param_set").and_then(Json::as_str).unwrap_or_default().to_string(),
            hw: j
                .get("hw")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|h| HwEntry {
                    platform: h
                        .get("platform")
                        .and_then(Json::as_str)
                        .unwrap_or_default()
                        .to_string(),
                    speedup: h.get("speedup").and_then(Json::as_f64).unwrap_or(f64::NAN),
                    energy_uj: h.get("energy_uj").and_then(Json::as_f64),
                })
                .collect(),
        })
    }
}

/// Server-level counter snapshot (the `stats` reply).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerStats {
    pub executions: usize,
    pub cache_hits: usize,
    pub unique_solutions: usize,
    /// The shared result cache was poisoned by a worker panic.
    pub poisoned: bool,
    /// Search requests accepted since the server started.
    pub requests: usize,
    /// Searches currently in flight.
    pub active: usize,
    /// Whether the server evaluates through the hermetic surrogate.
    pub surrogate: bool,
}

/// Server → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Started {
        id: u64,
        name: String,
        num_vars: usize,
        objectives: Vec<String>,
        threads: usize,
        islands: usize,
    },
    Generation {
        id: u64,
        generation: usize,
        evaluations: usize,
        best_err: f64,
        feasible: usize,
        pop_size: usize,
        island: Option<usize>,
    },
    Beacon { id: u64, name: String, retrain_steps: usize },
    Migration { id: u64, generation: usize, from: usize, to: usize, accepted: usize },
    /// Terminal success frame of one search request.
    Front {
        id: u64,
        objectives: Vec<String>,
        rows: Vec<FrontRow>,
        evaluations: usize,
        /// Executions / cache hits during this request's window (deltas
        /// of the shared service counters: cross-request hits count —
        /// the reuse signal — and concurrent requests' activity is
        /// included; exact when requests are serial).
        exec_calls: usize,
        cache_hits: usize,
        wall_secs: f64,
        hypervolume: Option<f64>,
    },
    /// Terminal failure frame (`kind` is `SearchError::kind`, plus
    /// `"protocol"` and `"panic"`); `id` is absent when a malformed line
    /// could not be correlated.
    Error { id: Option<u64>, kind: String, message: String },
    Stats(ServerStats),
    Pong,
    Bye,
}

/// Translate a streaming `SearchEvent` into the wire frame for `id`.
/// `Finished` is skipped — the terminal `front` frame carries its data.
pub fn event_frame(id: u64, event: &SearchEvent) -> Option<Frame> {
    Some(match event {
        SearchEvent::Started { name, num_vars, objectives, threads, islands } => Frame::Started {
            id,
            name: name.clone(),
            num_vars: *num_vars,
            objectives: objectives.clone(),
            threads: *threads,
            islands: *islands,
        },
        SearchEvent::Generation(log) => Frame::Generation {
            id,
            generation: log.generation,
            evaluations: log.evaluations,
            best_err: log.best_err,
            feasible: log.feasible,
            pop_size: log.pop_size,
            island: log.island,
        },
        SearchEvent::BeaconCreated { name, retrain_steps } => {
            Frame::Beacon { id, name: name.clone(), retrain_steps: *retrain_steps }
        }
        SearchEvent::Migration { generation, from, to, accepted } => Frame::Migration {
            id,
            generation: *generation,
            from: *from,
            to: *to,
            accepted: *accepted,
        },
        SearchEvent::Finished { .. } => return None,
    })
}

/// The terminal success frame for a finished request.
pub fn front_frame(id: u64, outcome: &SearchOutcome) -> Frame {
    Frame::Front {
        id,
        objectives: outcome.objective_names.clone(),
        rows: outcome.rows.iter().map(FrontRow::from_row).collect(),
        evaluations: outcome.evaluations,
        exec_calls: outcome.exec_calls,
        cache_hits: outcome.cache_hits,
        wall_secs: outcome.wall_secs,
        hypervolume: outcome.front_hypervolume,
    }
}

impl Frame {
    pub fn to_json(&self) -> Json {
        let uid = |id: u64| Json::Num(id as f64);
        match self {
            Frame::Started { id, name, num_vars, objectives, threads, islands } => obj(vec![
                ("event", "started".into()),
                ("id", uid(*id)),
                ("name", name.as_str().into()),
                ("num_vars", (*num_vars).into()),
                (
                    "objectives",
                    Json::Arr(objectives.iter().map(|o| o.as_str().into()).collect()),
                ),
                ("threads", (*threads).into()),
                ("islands", (*islands).into()),
            ]),
            Frame::Generation { id, generation, evaluations, best_err, feasible, pop_size, island } => {
                obj(vec![
                    ("event", "generation".into()),
                    ("id", uid(*id)),
                    ("generation", (*generation).into()),
                    ("evaluations", (*evaluations).into()),
                    ("best_err", (*best_err).into()),
                    ("feasible", (*feasible).into()),
                    ("pop_size", (*pop_size).into()),
                    ("island", island.map_or(Json::Null, |i| i.into())),
                ])
            }
            Frame::Beacon { id, name, retrain_steps } => obj(vec![
                ("event", "beacon".into()),
                ("id", uid(*id)),
                ("name", name.as_str().into()),
                ("retrain_steps", (*retrain_steps).into()),
            ]),
            Frame::Migration { id, generation, from, to, accepted } => obj(vec![
                ("event", "migration".into()),
                ("id", uid(*id)),
                ("generation", (*generation).into()),
                ("from", (*from).into()),
                ("to", (*to).into()),
                ("accepted", (*accepted).into()),
            ]),
            Frame::Front {
                id,
                objectives,
                rows,
                evaluations,
                exec_calls,
                cache_hits,
                wall_secs,
                hypervolume,
            } => obj(vec![
                ("event", "front".into()),
                ("id", uid(*id)),
                (
                    "objectives",
                    Json::Arr(objectives.iter().map(|o| o.as_str().into()).collect()),
                ),
                ("rows", Json::Arr(rows.iter().map(FrontRow::to_json).collect())),
                ("evaluations", (*evaluations).into()),
                ("exec_calls", (*exec_calls).into()),
                ("cache_hits", (*cache_hits).into()),
                ("wall_secs", (*wall_secs).into()),
                ("hypervolume", hypervolume.map_or(Json::Null, Json::Num)),
            ]),
            Frame::Error { id, kind, message } => obj(vec![
                ("event", "error".into()),
                ("id", id.map_or(Json::Null, |i| Json::Num(i as f64))),
                ("kind", kind.as_str().into()),
                ("message", message.as_str().into()),
            ]),
            Frame::Stats(s) => obj(vec![
                ("event", "stats".into()),
                ("executions", s.executions.into()),
                ("cache_hits", s.cache_hits.into()),
                ("unique_solutions", s.unique_solutions.into()),
                ("poisoned", s.poisoned.into()),
                ("requests", s.requests.into()),
                ("active", s.active.into()),
                ("surrogate", s.surrogate.into()),
            ]),
            Frame::Pong => obj(vec![("event", "pong".into())]),
            Frame::Bye => obj(vec![("event", "bye".into())]),
        }
    }

    /// One wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    pub fn parse(line: &str) -> Result<Frame, ProtocolError> {
        let j = Json::parse(line.trim())
            .map_err(|e| ProtocolError { id: None, message: format!("bad frame: {e}") })?;
        let event = j.get("event").and_then(Json::as_str).ok_or_else(|| ProtocolError {
            id: get_u64(&j, "id"),
            message: "frame missing 'event'".into(),
        })?;
        let id = || {
            get_u64(&j, "id").ok_or_else(|| ProtocolError {
                id: None,
                message: format!("'{event}' frame missing 'id'"),
            })
        };
        let num = |key: &str| {
            j.get(key).and_then(Json::as_usize).ok_or_else(|| ProtocolError {
                id: get_u64(&j, "id"),
                message: format!("'{event}' frame missing '{key}'"),
            })
        };
        let strings = |key: &str| -> Vec<String> {
            j.get(key)
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect()
        };
        Ok(match event {
            "started" => Frame::Started {
                id: id()?,
                name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                num_vars: num("num_vars")?,
                objectives: strings("objectives"),
                threads: num("threads")?,
                islands: num("islands")?,
            },
            "generation" => Frame::Generation {
                id: id()?,
                generation: num("generation")?,
                evaluations: num("evaluations")?,
                best_err: j.get("best_err").and_then(Json::as_f64).unwrap_or(f64::NAN),
                feasible: num("feasible")?,
                pop_size: num("pop_size")?,
                island: j.get("island").and_then(Json::as_usize),
            },
            "beacon" => Frame::Beacon {
                id: id()?,
                name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
                retrain_steps: num("retrain_steps")?,
            },
            "migration" => Frame::Migration {
                id: id()?,
                generation: num("generation")?,
                from: num("from")?,
                to: num("to")?,
                accepted: num("accepted")?,
            },
            "front" => Frame::Front {
                id: id()?,
                objectives: strings("objectives"),
                rows: j
                    .get("rows")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(FrontRow::from_json)
                    .collect::<Result<_, _>>()?,
                evaluations: num("evaluations")?,
                exec_calls: num("exec_calls")?,
                cache_hits: num("cache_hits")?,
                wall_secs: j.get("wall_secs").and_then(Json::as_f64).unwrap_or(0.0),
                hypervolume: j.get("hypervolume").and_then(Json::as_f64),
            },
            "error" => Frame::Error {
                id: get_u64(&j, "id"),
                kind: j.get("kind").and_then(Json::as_str).unwrap_or("unknown").to_string(),
                message: j.get("message").and_then(Json::as_str).unwrap_or_default().to_string(),
            },
            "stats" => Frame::Stats(ServerStats {
                executions: num("executions")?,
                cache_hits: num("cache_hits")?,
                unique_solutions: num("unique_solutions")?,
                poisoned: j.get("poisoned").and_then(Json::as_bool).unwrap_or(false),
                requests: num("requests")?,
                active: num("active")?,
                surrogate: j.get("surrogate").and_then(Json::as_bool).unwrap_or(false),
            }),
            "pong" => Frame::Pong,
            "bye" => Frame::Bye,
            other => {
                return Err(ProtocolError {
                    id: get_u64(&j, "id"),
                    message: format!("unknown event '{other}'"),
                })
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExperimentSpec;

    #[test]
    fn requests_round_trip() {
        let reqs = vec![
            Request::Search { id: 3, spec: ExperimentSpec::exp1().to_json() },
            Request::Cancel { id: 7 },
            Request::Stats,
            Request::Ping,
            Request::Shutdown,
        ];
        for r in reqs {
            let line = r.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Request::parse(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn frames_round_trip() {
        let frames = vec![
            Frame::Started {
                id: 1,
                name: "exp".into(),
                num_vars: 8,
                objectives: vec!["WER_V".into(), "-speedup@silago".into()],
                threads: 4,
                islands: 1,
            },
            Frame::Generation {
                id: 1,
                generation: 2,
                evaluations: 40,
                best_err: 0.1625,
                feasible: 9,
                pop_size: 10,
                island: Some(2),
            },
            // No feasible solution yet: best_err is +Infinity and must
            // survive the wire (regression for the json emitter).
            Frame::Generation {
                id: 1,
                generation: 0,
                evaluations: 10,
                best_err: f64::INFINITY,
                feasible: 0,
                pop_size: 10,
                island: None,
            },
            Frame::Beacon { id: 1, name: "W2A8...".into(), retrain_steps: 200 },
            Frame::Migration { id: 1, generation: 5, from: 0, to: 1, accepted: 2 },
            Frame::Front {
                id: 1,
                objectives: vec!["WER_V".into()],
                rows: vec![FrontRow {
                    config: "W4A4 ...".into(),
                    wer_v: 0.17250000000000001,
                    wer_t: 0.18,
                    cp_r: 7.9,
                    size_mb: 0.61,
                    param_set: "baseline".into(),
                    hw: vec![HwEntry {
                        platform: "silago".into(),
                        speedup: 3.25,
                        energy_uj: None,
                    }],
                }],
                evaluations: 400,
                exec_calls: 120,
                cache_hits: 280,
                wall_secs: 1.25,
                hypervolume: Some(0.82),
            },
            Frame::Error { id: Some(4), kind: "cancelled".into(), message: "search cancelled".into() },
            Frame::Error { id: None, kind: "protocol".into(), message: "bad frame".into() },
            Frame::Stats(ServerStats {
                executions: 10,
                cache_hits: 5,
                unique_solutions: 8,
                poisoned: false,
                requests: 2,
                active: 1,
                surrogate: true,
            }),
            Frame::Pong,
            Frame::Bye,
        ];
        for f in frames {
            let line = f.to_line();
            assert!(!line.contains('\n'), "one frame per line: {line}");
            assert_eq!(Frame::parse(&line).unwrap(), f, "{line}");
        }
    }

    #[test]
    fn floats_round_trip_bitwise() {
        // Shortest-round-trip float formatting is what makes a served
        // front bitwise-comparable to the offline run that produced it.
        for v in [0.1, 1.0 / 3.0, 0.16000000000000003, 123456.789012345] {
            let f = Frame::Generation {
                id: 0,
                generation: 0,
                evaluations: 0,
                best_err: v,
                feasible: 0,
                pop_size: 0,
                island: None,
            };
            match Frame::parse(&f.to_line()).unwrap() {
                Frame::Generation { best_err, .. } => {
                    assert_eq!(best_err.to_bits(), v.to_bits())
                }
                other => panic!("wrong frame {other:?}"),
            }
        }
    }

    #[test]
    fn malformed_lines_yield_protocol_errors_with_best_effort_ids() {
        assert!(Request::parse("{").is_err());
        assert!(Request::parse("[]").is_err());
        let e = Request::parse(r#"{"op":"warp","id":9}"#).unwrap_err();
        assert_eq!(e.id, Some(9), "id extracted even for unknown ops");
        let e = Request::parse(r#"{"op":"search"}"#).unwrap_err();
        assert!(e.message.contains("spec"), "{e}");
        let e = Request::parse(r#"{"id":1}"#).unwrap_err();
        assert!(e.message.contains("op"), "{e}");
    }

    #[test]
    fn fractional_or_negative_ids_are_rejected_not_truncated() {
        // `{"id":3.9}` must NOT become a cancel for request 3.
        for bad in [r#"{"op":"cancel","id":3.9}"#, r#"{"op":"cancel","id":-1}"#] {
            let e = Request::parse(bad).unwrap_err();
            assert!(e.message.contains("id"), "{e}");
        }
        assert_eq!(
            Request::parse(r#"{"op":"cancel","id":3}"#).unwrap(),
            Request::Cancel { id: 3 }
        );
    }
}
