//! Minimal blocking client for the serve protocol — used by the example
//! driver, the serve tests and the CI smoke job, and small enough to
//! transliterate into any language that can speak line-delimited JSON.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::protocol::{Frame, FrontRow, PlatformInfo, Request, ServerStats};
use crate::coordinator::ExperimentSpec;
use crate::hw::manifest::PlatformManifest;

/// Client-side failure classes.
#[derive(Debug)]
pub enum ClientError {
    Io(std::io::Error),
    /// The server sent something the protocol module cannot parse, or
    /// closed the connection mid-request.
    Protocol(String),
    /// The server reported a search failure (typed `kind` — e.g.
    /// `invalid_spec`, `cancelled`, `poisoned` — plus the message).
    Server { kind: String, message: String },
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Protocol(msg) => write!(f, "protocol: {msg}"),
            ClientError::Server { kind, message } => write!(f, "server error [{kind}]: {message}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

/// The terminal result of one search request.
#[derive(Debug, Clone)]
pub struct SearchReply {
    pub id: u64,
    pub objectives: Vec<String>,
    pub rows: Vec<FrontRow>,
    pub evaluations: usize,
    /// Executions / cache hits during THIS request on the server's shared
    /// cache (hits on entries other requests populated count — the
    /// cross-request reuse signal).
    pub exec_calls: usize,
    pub cache_hits: usize,
    pub wall_secs: f64,
    pub hypervolume: Option<f64>,
    /// Generation frames streamed before the front arrived.
    pub generations: usize,
}

/// One connection to a `mohaq serve` server.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
}

impl ServeClient {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<ServeClient> {
        let writer = TcpStream::connect(addr)?;
        let reader = BufReader::new(writer.try_clone()?);
        Ok(ServeClient { reader, writer, next_id: 1 })
    }

    /// Retry `connect` until `timeout` elapses — for drivers that race a
    /// freshly spawned server process (the CI smoke job).
    pub fn connect_retry(addr: &str, timeout: Duration) -> std::io::Result<ServeClient> {
        let deadline = Instant::now() + timeout;
        loop {
            match ServeClient::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) if Instant::now() >= deadline => return Err(e),
                Err(_) => std::thread::sleep(Duration::from_millis(100)),
            }
        }
    }

    fn send(&mut self, request: &Request) -> std::io::Result<()> {
        let mut line = request.to_line();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()
    }

    fn read_frame(&mut self) -> Result<Frame, ClientError> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Err(ClientError::Protocol("server closed the connection".into()));
        }
        Frame::parse(&line).map_err(|e| ClientError::Protocol(e.message))
    }

    pub fn ping(&mut self) -> Result<(), ClientError> {
        self.send(&Request::Ping)?;
        match self.read_frame()? {
            Frame::Pong => Ok(()),
            other => Err(ClientError::Protocol(format!("expected pong, got {other:?}"))),
        }
    }

    /// Cumulative server counters: eval executions, cache hits, resident
    /// memo occupancy (`unique_solutions`), eviction counts, and the
    /// cache-poisoned marker. With `mohaq serve --store DIR`, a restarted
    /// server answers its first repeated request from the reloaded memo —
    /// `cache_hits` here is how warm-start coverage is observed.
    pub fn stats(&mut self) -> Result<ServerStats, ClientError> {
        self.send(&Request::Stats)?;
        match self.read_frame()? {
            Frame::Stats(s) => Ok(s),
            other => Err(ClientError::Protocol(format!("expected stats, got {other:?}"))),
        }
    }

    /// Alias of [`ServeClient::stats`] (the historical name).
    pub fn server_stats(&mut self) -> Result<ServerStats, ClientError> {
        self.stats()
    }

    /// Ask the server to stop; resolves once the server confirms.
    pub fn shutdown(&mut self) -> Result<(), ClientError> {
        self.send(&Request::Shutdown)?;
        match self.read_frame()? {
            Frame::Bye => Ok(()),
            other => Err(ClientError::Protocol(format!("expected bye, got {other:?}"))),
        }
    }

    /// Register a platform manifest for THIS connection (tenant-scoped:
    /// other connections never see it). Returns the registered name; a
    /// rejected manifest — invalid, or colliding with a server-side
    /// platform — comes back as `ClientError::Server { kind: "manifest" }`.
    pub fn register_platform(
        &mut self,
        manifest: &PlatformManifest,
    ) -> Result<String, ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Request::RegisterPlatform { id, manifest: manifest.to_json() })?;
        match self.read_frame()? {
            Frame::PlatformRegistered { id: fid, name } if fid == id => Ok(name),
            Frame::Error { id: fid, kind, message } if fid == Some(id) || fid.is_none() => {
                Err(ClientError::Server { kind, message })
            }
            other => {
                Err(ClientError::Protocol(format!("expected platform_registered, got {other:?}")))
            }
        }
    }

    /// List the platforms resolvable on this connection: the server's
    /// global registry plus this connection's tenant manifests.
    pub fn platforms(&mut self) -> Result<Vec<PlatformInfo>, ClientError> {
        self.send(&Request::Platforms)?;
        match self.read_frame()? {
            Frame::Platforms { platforms } => Ok(platforms),
            other => Err(ClientError::Protocol(format!("expected platforms, got {other:?}"))),
        }
    }

    /// Read one replicated parameter set back from a worker server:
    /// `(name, tensors)` on the lossless f32 codec. The verification leg
    /// of beacon replication — the dist tests use it to prove a worker's
    /// param table matches the coordinator's bit-for-bit. Heartbeat
    /// frames (a shard may be replicating concurrently) are skipped.
    pub fn param_fetch(&mut self, index: usize) -> Result<(String, Vec<Vec<f32>>), ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Request::ParamFetch { id, index })?;
        loop {
            match self.read_frame()? {
                Frame::ParamSet { id: fid, name, tensors, .. } if fid == id => {
                    return Ok((name, tensors))
                }
                Frame::Error { id: fid, kind, message } if fid == Some(id) || fid.is_none() => {
                    return Err(ClientError::Server { kind, message })
                }
                Frame::WorkerHeartbeat { .. } => {}
                other => {
                    return Err(ClientError::Protocol(format!("expected param_set, got {other:?}")))
                }
            }
        }
    }

    /// Run a search to completion, discarding progress frames.
    pub fn search(&mut self, spec: &ExperimentSpec) -> Result<SearchReply, ClientError> {
        self.search_with(spec, |_| false)
    }

    /// Run a search, observing every streamed frame. `on_frame` returning
    /// `true` sends a `cancel` for this request (once); the call then
    /// resolves with the server's verdict — normally a
    /// `ClientError::Server { kind: "cancelled", .. }`.
    pub fn search_with(
        &mut self,
        spec: &ExperimentSpec,
        mut on_frame: impl FnMut(&Frame) -> bool,
    ) -> Result<SearchReply, ClientError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Request::Search { id, spec: spec.to_json() })?;
        let mut cancelled = false;
        let mut generations = 0usize;
        loop {
            match self.read_frame()? {
                Frame::Front {
                    id: fid,
                    objectives,
                    rows,
                    evaluations,
                    exec_calls,
                    cache_hits,
                    wall_secs,
                    hypervolume,
                } if fid == id => {
                    return Ok(SearchReply {
                        id,
                        objectives,
                        rows,
                        evaluations,
                        exec_calls,
                        cache_hits,
                        wall_secs,
                        hypervolume,
                        generations,
                    })
                }
                Frame::Error { id: fid, kind, message } if fid == Some(id) => {
                    return Err(ClientError::Server { kind, message })
                }
                frame => {
                    if matches!(frame, Frame::Generation { .. }) {
                        generations += 1;
                    }
                    if on_frame(&frame) && !cancelled {
                        cancelled = true;
                        self.send(&Request::Cancel { id })?;
                    }
                }
            }
        }
    }
}
