//! Fast non-dominated sorting (Deb et al. 2002, §III-A) with
//! constrained-domination, plus per-front crowding assignment.

use super::individual::Individual;
use crate::pareto::{constrained_dominates, crowding_distances};

/// Assign `rank` to every individual and return the fronts (indices into
/// `pop`), best front first. O(m n^2) as in the paper.
pub fn fast_nondominated_sort(pop: &mut [Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // S_p
    let mut dom_count = vec![0usize; n]; // n_p
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut first = Vec::new();

    for p in 0..n {
        for q in 0..n {
            if p == q {
                continue;
            }
            if constrained_dominates(
                &pop[p].objectives,
                pop[p].violation,
                &pop[q].objectives,
                pop[q].violation,
            ) {
                dominated_by[p].push(q);
            } else if constrained_dominates(
                &pop[q].objectives,
                pop[q].violation,
                &pop[p].objectives,
                pop[p].violation,
            ) {
                dom_count[p] += 1;
            }
        }
        if dom_count[p] == 0 {
            pop[p].rank = 0;
            first.push(p);
        }
    }
    fronts.push(first);

    let mut i = 0;
    while !fronts[i].is_empty() {
        let mut next = Vec::new();
        for &p in &fronts[i] {
            for &q in &dominated_by[p] {
                dom_count[q] -= 1;
                if dom_count[q] == 0 {
                    pop[q].rank = i + 1;
                    next.push(q);
                }
            }
        }
        i += 1;
        fronts.push(next);
    }
    fronts.pop(); // drop the trailing empty front
    fronts
}

/// Assign crowding distances front-by-front.
pub fn assign_crowding(pop: &mut [Individual], fronts: &[Vec<usize>]) {
    for front in fronts {
        let pts: Vec<Vec<f64>> = front.iter().map(|&i| pop[i].objectives.clone()).collect();
        let d = crowding_distances(&pts);
        for (k, &i) in front.iter().enumerate() {
            pop[i].crowding = d[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(objs: &[f64], violation: f64) -> Individual {
        Individual {
            genome: vec![],
            objectives: objs.to_vec(),
            violation,
            rank: usize::MAX,
            crowding: 0.0,
        }
    }

    #[test]
    fn ranks_three_layer_population() {
        let mut pop = vec![
            ind(&[1.0, 1.0], 0.0), // front 0
            ind(&[2.0, 2.0], 0.0), // front 1
            ind(&[3.0, 3.0], 0.0), // front 2
            ind(&[0.5, 3.5], 0.0), // front 0 (trade-off)
        ];
        let fronts = fast_nondominated_sort(&mut pop);
        assert_eq!(fronts.len(), 3);
        assert_eq!(pop[0].rank, 0);
        assert_eq!(pop[3].rank, 0);
        assert_eq!(pop[1].rank, 1);
        assert_eq!(pop[2].rank, 2);
    }

    #[test]
    fn infeasible_fall_behind() {
        let mut pop = vec![
            ind(&[5.0, 5.0], 0.0), // feasible, should be front 0
            ind(&[1.0, 1.0], 2.0), // infeasible despite better objectives
            ind(&[1.0, 1.0], 1.0), // infeasible, smaller violation
        ];
        let fronts = fast_nondominated_sort(&mut pop);
        assert_eq!(pop[0].rank, 0);
        assert_eq!(pop[2].rank, 1);
        assert_eq!(pop[1].rank, 2);
        assert_eq!(fronts[0], vec![0]);
    }

    #[test]
    fn single_front_when_all_tradeoff() {
        let mut pop = vec![
            ind(&[1.0, 4.0], 0.0),
            ind(&[2.0, 3.0], 0.0),
            ind(&[3.0, 2.0], 0.0),
            ind(&[4.0, 1.0], 0.0),
        ];
        let fronts = fast_nondominated_sort(&mut pop);
        assert_eq!(fronts.len(), 1);
        assert!(pop.iter().all(|p| p.rank == 0));
    }

    #[test]
    fn crowding_assigned_per_front() {
        let mut pop = vec![
            ind(&[1.0, 4.0], 0.0),
            ind(&[2.0, 3.0], 0.0),
            ind(&[3.0, 2.0], 0.0),
            ind(&[4.0, 1.0], 0.0),
            ind(&[5.0, 5.0], 0.0), // second front
        ];
        let fronts = fast_nondominated_sort(&mut pop);
        assign_crowding(&mut pop, &fronts);
        assert!(pop[0].crowding.is_infinite());
        assert!(pop[3].crowding.is_infinite());
        assert!(pop[1].crowding.is_finite());
        assert!(pop[4].crowding.is_infinite()); // singleton front
    }
}
