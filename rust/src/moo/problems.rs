//! Standard multi-objective test problems (integer-discretized) used to
//! validate the NSGA-II engine independently of MOHAQ, mirroring how the
//! original NSGA-II paper was evaluated.

use super::parallel::SyncProblem;
use super::problem::{Evaluation, Problem};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZdtVariant {
    Zdt1,
    Zdt2,
    Zdt3,
}

/// ZDT suite over genes g_i in {0..resolution} mapped to x_i in [0,1].
pub struct Zdt {
    variant: ZdtVariant,
    num_vars: usize,
    resolution: i64,
}

impl Zdt {
    pub fn new(variant: ZdtVariant, num_vars: usize, resolution: i64) -> Self {
        assert!(num_vars >= 2);
        Zdt { variant, num_vars, resolution }
    }

    fn decode(&self, genome: &[i64]) -> Vec<f64> {
        genome.iter().map(|&g| g as f64 / self.resolution as f64).collect()
    }

    /// Pure evaluation — shared by the `Problem` and `SyncProblem` impls.
    fn score(&self, genome: &[i64]) -> Evaluation {
        let x = self.decode(genome);
        let n = x.len();
        let f1 = x[0];
        let g = 1.0 + 9.0 * x[1..].iter().sum::<f64>() / (n - 1) as f64;
        let f2 = match self.variant {
            ZdtVariant::Zdt1 => g * (1.0 - (f1 / g).sqrt()),
            ZdtVariant::Zdt2 => g * (1.0 - (f1 / g).powi(2)),
            ZdtVariant::Zdt3 => {
                g * (1.0 - (f1 / g).sqrt() - (f1 / g) * (10.0 * std::f64::consts::PI * f1).sin())
            }
        };
        Evaluation { objectives: vec![f1, f2], violation: 0.0 }
    }
}

impl Problem for Zdt {
    fn num_vars(&self) -> usize {
        self.num_vars
    }

    fn num_objectives(&self) -> usize {
        2
    }

    fn var_range(&self, _i: usize) -> (i64, i64) {
        (0, self.resolution)
    }

    fn evaluate(&mut self, genome: &[i64]) -> Evaluation {
        self.score(genome)
    }
}

impl SyncProblem for Zdt {
    fn vars(&self) -> usize {
        self.num_vars
    }

    fn objectives(&self) -> usize {
        2
    }

    fn gene_range(&self, _i: usize) -> (i64, i64) {
        (0, self.resolution)
    }

    fn eval(&self, genome: &[i64]) -> Evaluation {
        self.score(genome)
    }
}

/// DTLZ2 with 3 objectives — exercises the 3-D crowding/sorting paths used
/// by the SiLago experiment (error, speedup, energy).
pub struct Dtlz2 {
    num_vars: usize,
    resolution: i64,
}

impl Dtlz2 {
    pub fn new(num_vars: usize, resolution: i64) -> Self {
        assert!(num_vars >= 3);
        Dtlz2 { num_vars, resolution }
    }
}

impl Problem for Dtlz2 {
    fn num_vars(&self) -> usize {
        self.num_vars
    }

    fn num_objectives(&self) -> usize {
        3
    }

    fn var_range(&self, _i: usize) -> (i64, i64) {
        (0, self.resolution)
    }

    fn evaluate(&mut self, genome: &[i64]) -> Evaluation {
        let x: Vec<f64> =
            genome.iter().map(|&g| g as f64 / self.resolution as f64).collect();
        let k = &x[2..];
        let g: f64 = k.iter().map(|&v| (v - 0.5) * (v - 0.5)).sum();
        let half_pi = std::f64::consts::FRAC_PI_2;
        let f1 = (1.0 + g) * (x[0] * half_pi).cos() * (x[1] * half_pi).cos();
        let f2 = (1.0 + g) * (x[0] * half_pi).cos() * (x[1] * half_pi).sin();
        let f3 = (1.0 + g) * (x[0] * half_pi).sin();
        Evaluation { objectives: vec![f1, f2, f3], violation: 0.0 }
    }
}

/// A constrained toy problem: minimize (x, y) subject to x + y >= bound.
/// Exercises the constrained-domination path.
pub struct ConstrainedSum {
    pub bound: i64,
}

impl Problem for ConstrainedSum {
    fn num_vars(&self) -> usize {
        2
    }

    fn num_objectives(&self) -> usize {
        2
    }

    fn var_range(&self, _i: usize) -> (i64, i64) {
        (0, 100)
    }

    fn evaluate(&mut self, genome: &[i64]) -> Evaluation {
        let (x, y) = (genome[0] as f64, genome[1] as f64);
        let violation = (self.bound as f64 - (x + y)).max(0.0);
        Evaluation { objectives: vec![x, y], violation }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moo::nsga2::{Nsga2, Nsga2Config};

    #[test]
    fn zdt1_front_shape() {
        let mut p = Zdt::new(ZdtVariant::Zdt1, 4, 100);
        // x rest = 0 => g = 1 => f2 = 1 - sqrt(f1)
        let e = p.evaluate(&[25, 0, 0, 0]);
        assert!((e.objectives[0] - 0.25).abs() < 1e-12);
        assert!((e.objectives[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dtlz2_on_sphere_when_g_zero() {
        let mut p = Dtlz2::new(4, 2); // resolution 2 => x in {0, .5, 1}
        let e = p.evaluate(&[0, 0, 1, 1]); // k vars = 0.5 => g = 0
        let norm: f64 = e.objectives.iter().map(|v| v * v).sum();
        assert!((norm - 1.0).abs() < 1e-9);
    }

    #[test]
    fn constrained_search_ends_feasible() {
        let mut p = ConstrainedSum { bound: 80 };
        let mut algo = Nsga2::new(Nsga2Config {
            pop_size: 20,
            initial_pop_size: 40,
            generations: 30,
            seed: 23,
            ..Default::default()
        });
        let pop = algo.run(&mut p, |_| {});
        let set = Nsga2::pareto_set(&pop);
        assert!(!set.is_empty());
        for ind in &set {
            assert!(ind.genome[0] + ind.genome[1] >= 80, "{:?}", ind.genome);
            // Near the constraint boundary (mutation keeps some slack).
            assert!(ind.genome[0] + ind.genome[1] <= 100, "{:?}", ind.genome);
        }
    }
}
