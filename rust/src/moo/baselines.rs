//! Baseline search strategies for the ablation benches: pure random search
//! and a weighted-sum single-objective GA. The paper argues MOOP beats
//! single-objective formulations (§1); bench_moo quantifies that on our
//! problems via hypervolume at equal evaluation budgets.

use super::individual::Individual;
use super::problem::Problem;
use crate::util::rng::Rng;

/// Evaluate `budget` uniform-random genomes; returns all evaluated
/// individuals (callers extract the front).
pub fn random_search(problem: &mut dyn Problem, budget: usize, seed: u64) -> Vec<Individual> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(budget);
    for _ in 0..budget {
        let genome: Vec<i64> = (0..problem.num_vars())
            .map(|i| {
                let (lo, hi) = problem.var_range(i);
                rng.range(lo, hi)
            })
            .collect();
        let e = problem.evaluate(&genome);
        let mut ind = Individual::new(genome);
        ind.objectives = e.objectives;
        ind.violation = e.violation;
        out.push(ind);
    }
    out
}

/// Single-objective GA on a fixed weighted sum of the objectives
/// (normalized weights). Returns every evaluated individual.
pub fn weighted_sum_ga(
    problem: &mut dyn Problem,
    weights: &[f64],
    pop_size: usize,
    generations: usize,
    seed: u64,
) -> Vec<Individual> {
    assert_eq!(weights.len(), problem.num_objectives());
    let mut rng = Rng::new(seed);
    let score = |ind: &Individual| -> f64 {
        let s: f64 = ind.objectives.iter().zip(weights).map(|(o, w)| o * w).sum();
        s + ind.violation * 1e6 // heavy penalty for infeasibility
    };

    let mut history: Vec<Individual> = Vec::new();
    let mut pop: Vec<Individual> = (0..pop_size)
        .map(|_| {
            let genome: Vec<i64> = (0..problem.num_vars())
                .map(|i| {
                    let (lo, hi) = problem.var_range(i);
                    rng.range(lo, hi)
                })
                .collect();
            let e = problem.evaluate(&genome);
            let mut ind = Individual::new(genome);
            ind.objectives = e.objectives;
            ind.violation = e.violation;
            ind
        })
        .collect();
    history.extend(pop.iter().cloned());

    for _ in 0..generations {
        let mut next = Vec::with_capacity(pop_size);
        for _ in 0..pop_size {
            // Tournament of 2 on the scalar score.
            let a = &pop[rng.below(pop.len())];
            let b = &pop[rng.below(pop.len())];
            let parent1 = if score(a) <= score(b) { a } else { b };
            let c = &pop[rng.below(pop.len())];
            let d = &pop[rng.below(pop.len())];
            let parent2 = if score(c) <= score(d) { c } else { d };
            let n = parent1.genome.len();
            let mut genome: Vec<i64> = (0..n)
                .map(|i| if rng.bool(0.5) { parent1.genome[i] } else { parent2.genome[i] })
                .collect();
            let pm = 1.0 / n.max(1) as f64;
            for (i, g) in genome.iter_mut().enumerate() {
                if rng.bool(pm) {
                    let (lo, hi) = problem.var_range(i);
                    *g = rng.range(lo, hi);
                }
            }
            let e = problem.evaluate(&genome);
            let mut ind = Individual::new(genome);
            ind.objectives = e.objectives;
            ind.violation = e.violation;
            next.push(ind);
        }
        history.extend(next.iter().cloned());
        // Elitist replacement: keep best pop_size of parents+children.
        pop.extend(next);
        pop.sort_by(|a, b| score(a).partial_cmp(&score(b)).unwrap());
        pop.truncate(pop_size);
    }
    history
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moo::problems::{Zdt, ZdtVariant};
    use crate::pareto::pareto_front_indices;

    #[test]
    fn random_search_respects_budget_and_ranges() {
        let mut p = Zdt::new(ZdtVariant::Zdt1, 5, 32);
        let all = random_search(&mut p, 100, 7);
        assert_eq!(all.len(), 100);
        for ind in &all {
            for &g in &ind.genome {
                assert!((0..=32).contains(&g));
            }
        }
    }

    #[test]
    fn weighted_sum_improves_over_random_on_its_scalar() {
        let mut p = Zdt::new(ZdtVariant::Zdt1, 8, 64);
        let w = [0.5, 0.5];
        let ga = weighted_sum_ga(&mut p, &w, 20, 20, 3);
        let mut p2 = Zdt::new(ZdtVariant::Zdt1, 8, 64);
        let rnd = random_search(&mut p2, ga.len(), 3);
        let best = |set: &[Individual]| {
            set.iter()
                .map(|i| i.objectives[0] * 0.5 + i.objectives[1] * 0.5)
                .fold(f64::INFINITY, f64::min)
        };
        assert!(best(&ga) <= best(&rnd));
    }

    #[test]
    fn random_front_is_nonempty() {
        let mut p = Zdt::new(ZdtVariant::Zdt1, 5, 32);
        let all = random_search(&mut p, 50, 11);
        let pts: Vec<Vec<f64>> = all.iter().map(|i| i.objectives.clone()).collect();
        assert!(!pareto_front_indices(&pts).is_empty());
    }
}
