//! Thread-parallel population evaluation for side-effect-free problems.
//!
//! `SyncProblem` is the `&self` (shared-state) sibling of `Problem`: any
//! problem whose evaluation is a pure function of the genome can implement
//! it and gain multi-threaded generation evaluation through the `Parallel`
//! adapter for free. Because `util::pool::map_parallel` returns results in
//! input order, a `Parallel`-wrapped run is bitwise-identical to the
//! 1-thread run at the same seed — only the wall clock changes. Method
//! names deliberately differ from `Problem`'s so a type can implement both
//! without call-site ambiguity.

use super::problem::{Evaluation, Problem};
use crate::util::pool::map_parallel_chunked;

/// A multi-objective problem whose evaluation needs only `&self`.
pub trait SyncProblem: Send + Sync {
    fn vars(&self) -> usize;
    fn objectives(&self) -> usize;
    /// Inclusive gene range for variable `i`.
    fn gene_range(&self, i: usize) -> (i64, i64);
    fn eval(&self, genome: &[i64]) -> Evaluation;

    fn names(&self) -> Vec<String> {
        (0..self.objectives()).map(|i| format!("f{i}")).collect()
    }
}

/// Adapter presenting a `SyncProblem` as a `Problem` whose generations are
/// evaluated across `threads` workers.
pub struct Parallel<'a, P: SyncProblem + ?Sized> {
    pub inner: &'a P,
    pub threads: usize,
}

impl<'a, P: SyncProblem + ?Sized> Parallel<'a, P> {
    pub fn new(inner: &'a P, threads: usize) -> Self {
        Parallel { inner, threads }
    }

    /// One worker per core (`util::pool::default_threads`, which honors
    /// the `MOHAQ_THREADS` override).
    pub fn auto(inner: &'a P) -> Self {
        Parallel { inner, threads: crate::util::pool::default_threads() }
    }
}

impl<P: SyncProblem + ?Sized> Problem for Parallel<'_, P> {
    fn num_vars(&self) -> usize {
        self.inner.vars()
    }

    fn num_objectives(&self) -> usize {
        self.inner.objectives()
    }

    fn var_range(&self, i: usize) -> (i64, i64) {
        self.inner.gene_range(i)
    }

    fn evaluate(&mut self, genome: &[i64]) -> Evaluation {
        self.inner.eval(genome)
    }

    fn evaluate_batch(&mut self, genomes: &[Vec<i64>]) -> Vec<Evaluation> {
        let inner = self.inner;
        // Micro-batch: claim ~4 chunks per worker rather than one atomic
        // claim per genome — same results (input order), less contention
        // when eval is cheap (e.g. cache-hit-dominated generations).
        let chunk = genomes.len().div_ceil(self.threads.max(1) * 4).max(1);
        map_parallel_chunked(self.threads, genomes, chunk, |_, c| {
            c.iter().map(|g| inner.eval(g)).collect()
        })
    }

    fn objective_names(&self) -> Vec<String> {
        self.inner.names()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A pure quadratic toy problem.
    struct Toy;

    impl SyncProblem for Toy {
        fn vars(&self) -> usize {
            4
        }
        fn objectives(&self) -> usize {
            2
        }
        fn gene_range(&self, _i: usize) -> (i64, i64) {
            (0, 16)
        }
        fn eval(&self, genome: &[i64]) -> Evaluation {
            let s: i64 = genome.iter().sum();
            let q: i64 = genome.iter().map(|g| g * g).sum();
            Evaluation { objectives: vec![s as f64, -(q as f64)], violation: 0.0 }
        }
    }

    #[test]
    fn batch_matches_sequential_for_any_thread_count() {
        let genomes: Vec<Vec<i64>> = (0..50)
            .map(|i| (0..4).map(|j| (i * 7 + j * 3) % 17).collect())
            .collect();
        let mut one = Parallel::new(&Toy, 1);
        let mut many = Parallel::new(&Toy, 8);
        assert_eq!(one.evaluate_batch(&genomes), many.evaluate_batch(&genomes));
    }
}
