//! Multi-objective optimization engine: NSGA-II (built from scratch — the
//! paper uses PYMOO's implementation; ours follows the same Deb-2002
//! algorithm), test problems, and single-objective/random baselines.

pub mod baselines;
pub mod individual;
pub mod island;
pub mod nsga2;
pub mod parallel;
pub mod problem;
pub mod problems;
pub mod sort;

pub use individual::Individual;
pub use island::{IslandConfig, IslandEvent, IslandModel, IslandShard, IslandSnapshot, Topology};
pub use nsga2::{GenerationStats, Nsga2, Nsga2Config};
pub use parallel::{Parallel, SyncProblem};
pub use problem::{Evaluation, Problem};
