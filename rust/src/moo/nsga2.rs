//! NSGA-II (Deb, Pratap, Agarwal, Meyarivan 2002) over integer genomes.
//!
//! This is the search engine behind every MOHAQ experiment. It follows the
//! paper's setup (§5): an over-sized initial population (40) followed by
//! small generations (10), binary tournament mating selection on
//! (constrained rank, crowding), uniform crossover and per-gene
//! random-reset mutation — the PYMOO defaults the paper kept — and
//! front-wise (mu+lambda) survival with crowding-based front splitting.

use super::individual::Individual;
use super::problem::Problem;
use super::sort::{assign_crowding, fast_nondominated_sort};
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Nsga2Config {
    /// Individuals per generation (paper: 10).
    pub pop_size: usize,
    /// Individuals in generation 0 (paper: 40).
    pub initial_pop_size: usize,
    /// Number of generations AFTER the initial one (paper: 60 or 15).
    pub generations: usize,
    pub crossover_prob: f64,
    /// Per-gene mutation probability; None = 1/num_vars.
    pub mutation_prob: Option<f64>,
    pub seed: u64,
}

impl Default for Nsga2Config {
    fn default() -> Self {
        Nsga2Config {
            pop_size: 10,
            initial_pop_size: 40,
            generations: 60,
            crossover_prob: 0.9,
            mutation_prob: None,
            seed: 0x5eed,
        }
    }
}

/// Per-generation progress snapshot passed to the observer callback.
pub struct GenerationStats<'a> {
    pub generation: usize,
    pub evaluations: usize,
    pub population: &'a [Individual],
}

pub struct Nsga2 {
    pub config: Nsga2Config,
    rng: Rng,
    evaluations: usize,
}

impl Nsga2 {
    pub fn new(config: Nsga2Config) -> Self {
        let rng = Rng::new(config.seed);
        Nsga2 { config, rng, evaluations: 0 }
    }

    /// Engine over an externally forked RNG stream. The island model gives
    /// each sub-population its own `Rng::fork` stream so K islands are
    /// reproducible as a set, independent of scheduling (`config.seed` is
    /// ignored in favor of `rng`).
    pub fn with_rng(config: Nsga2Config, rng: Rng) -> Self {
        Nsga2 { config, rng, evaluations: 0 }
    }

    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    /// Credit externally performed evaluations (island model: generations
    /// are evaluated in one cross-island batch, outside this engine).
    pub fn add_evaluations(&mut self, n: usize) {
        self.evaluations += n;
    }

    /// The engine's current RNG state — with `with_rng(cfg,
    /// Rng::from_state(..))` + `add_evaluations` this checkpoints an
    /// engine mid-search (island snapshot/restore across processes).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    fn random_genome(&mut self, problem: &dyn Problem) -> Vec<i64> {
        (0..problem.num_vars())
            .map(|i| {
                let (lo, hi) = problem.var_range(i);
                self.rng.range(lo, hi)
            })
            .collect()
    }

    /// Evaluate a batch of genomes through the problem's (possibly
    /// parallel) batch path and wrap them as individuals. Genome creation
    /// never consumes RNG state during evaluation, so batching whole
    /// generations is stream-identical to the old one-at-a-time loop.
    fn evaluate_all(&mut self, problem: &mut dyn Problem, genomes: Vec<Vec<i64>>) -> Vec<Individual> {
        let evals = problem.evaluate_batch(&genomes);
        debug_assert_eq!(evals.len(), genomes.len());
        self.evaluations += genomes.len();
        genomes
            .into_iter()
            .zip(evals)
            .map(|(genome, e)| {
                debug_assert_eq!(e.objectives.len(), problem.num_objectives());
                Individual::evaluated(genome, e)
            })
            .collect()
    }

    // ---- stepping API (the island model drives these externally) --------

    /// Random genomes for generation 0 (`initial_pop_size` of them).
    pub fn seed_genomes(&mut self, problem: &dyn Problem) -> Vec<Vec<i64>> {
        (0..self.config.initial_pop_size)
            .map(|_| self.random_genome(problem))
            .collect()
    }

    /// One generation of children bred from `pop` (`pop_size` of them).
    pub fn offspring_genomes(
        &mut self,
        problem: &dyn Problem,
        pop: &[Individual],
    ) -> Vec<Vec<i64>> {
        (0..self.config.pop_size)
            .map(|_| self.make_child(problem, pop))
            .collect()
    }

    /// Public (mu+lambda) survival over an evaluated pool — the island
    /// model evaluates genomes in cross-island batches and feeds the
    /// results back through this.
    pub fn select_survivors(
        &mut self,
        pool: Vec<Individual>,
        target: usize,
    ) -> Vec<Individual> {
        self.survive(pool, target)
    }

    /// Binary tournament on (feasibility, rank, crowding).
    fn select<'a>(&mut self, pop: &'a [Individual]) -> &'a Individual {
        let a = &pop[self.rng.below(pop.len())];
        let b = &pop[self.rng.below(pop.len())];
        if a.tournament_better(b) {
            a
        } else {
            b
        }
    }

    /// Uniform crossover + random-reset mutation; returns the bare genome
    /// (evaluation happens batched, once the whole generation exists).
    fn make_child(&mut self, problem: &dyn Problem, pop: &[Individual]) -> Vec<i64> {
        let p1 = self.select(pop).genome.clone();
        let p2 = self.select(pop).genome.clone();
        let n = p1.len();
        let mut genome = if self.rng.bool(self.config.crossover_prob) {
            (0..n)
                .map(|i| if self.rng.bool(0.5) { p1[i] } else { p2[i] })
                .collect()
        } else {
            p1
        };
        let pm = self.config.mutation_prob.unwrap_or(1.0 / n.max(1) as f64);
        for (i, g) in genome.iter_mut().enumerate() {
            if self.rng.bool(pm) {
                let (lo, hi) = problem.var_range(i);
                *g = self.rng.range(lo, hi);
            }
        }
        genome
    }

    /// (mu+lambda) survival: fill from best fronts; split the boundary
    /// front by crowding distance (descending).
    fn survive(&mut self, mut pool: Vec<Individual>, target: usize) -> Vec<Individual> {
        let fronts = fast_nondominated_sort(&mut pool);
        assign_crowding(&mut pool, &fronts);
        let mut keep: Vec<usize> = Vec::with_capacity(target);
        for front in &fronts {
            if keep.len() + front.len() <= target {
                keep.extend(front.iter().copied());
            } else {
                let mut boundary: Vec<usize> = front.clone();
                boundary.sort_by(|&a, &b| {
                    pool[b].crowding.partial_cmp(&pool[a].crowding).unwrap()
                });
                boundary.truncate(target - keep.len());
                keep.extend(boundary);
                break;
            }
        }
        let mut keep_sorted = keep;
        keep_sorted.sort_unstable();
        let mut out = Vec::with_capacity(keep_sorted.len());
        // Drain pool preserving the selected set (indices are unique).
        for (idx, ind) in pool.into_iter().enumerate() {
            if keep_sorted.binary_search(&idx).is_ok() {
                out.push(ind);
            }
        }
        out
    }

    /// Run the search; returns the final population (evaluated, ranked).
    /// `observer` fires after every generation (progress logs, beacon
    /// telemetry, search checkpoints).
    pub fn run(
        &mut self,
        problem: &mut dyn Problem,
        mut observer: impl FnMut(&GenerationStats),
    ) -> Vec<Individual> {
        // Generation 0: the paper's enlarged initial population, evaluated
        // as one batch (the problem may fan it out across threads).
        let genomes = self.seed_genomes(problem);
        let mut pop = self.evaluate_all(problem, genomes);
        pop = self.survive(pop, self.config.pop_size.min(self.config.initial_pop_size));
        observer(&GenerationStats { generation: 0, evaluations: self.evaluations, population: &pop });

        for gen in 1..=self.config.generations {
            // A tripped fuse / cancellation makes every further evaluation
            // a sentinel; stop the loop instead of spinning through the
            // remaining schedule (the caller discards the population).
            if problem.aborted() {
                break;
            }
            let children = self.offspring_genomes(problem, &pop);
            let offspring = self.evaluate_all(problem, children);
            let mut pool = pop;
            pool.extend(offspring);
            pop = self.survive(pool, self.config.pop_size);
            observer(&GenerationStats { generation: gen, evaluations: self.evaluations, population: &pop });
        }
        pop
    }

    /// Final non-dominated feasible subset — the Pareto set the designer
    /// sees (paper Fig. 4 output).
    pub fn pareto_set(pop: &[Individual]) -> Vec<Individual> {
        let mut feasible: Vec<Individual> =
            pop.iter().filter(|i| i.feasible()).cloned().collect();
        if feasible.is_empty() {
            return vec![];
        }
        let fronts = fast_nondominated_sort(&mut feasible);
        let mut out: Vec<Individual> =
            fronts[0].iter().map(|&i| feasible[i].clone()).collect();
        // Deduplicate identical genomes (uniform crossover can repeat).
        out.sort_by(|a, b| a.genome.cmp(&b.genome));
        out.dedup_by(|a, b| a.genome == b.genome);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moo::problems::{Zdt, ZdtVariant};
    use crate::pareto::hypervolume::hypervolume_2d;

    fn run_zdt(variant: ZdtVariant, gens: usize) -> Vec<Individual> {
        let mut problem = Zdt::new(variant, 12, 64);
        let mut algo = Nsga2::new(Nsga2Config {
            pop_size: 40,
            initial_pop_size: 40,
            generations: gens,
            seed: 17,
            ..Default::default()
        });
        let pop = algo.run(&mut problem, |_| {});
        Nsga2::pareto_set(&pop)
    }

    #[test]
    fn zdt1_converges_toward_front() {
        let set = run_zdt(ZdtVariant::Zdt1, 60);
        assert!(set.len() >= 5, "pareto set too small: {}", set.len());
        let pts: Vec<Vec<f64>> = set.iter().map(|i| i.objectives.clone()).collect();
        let hv = hypervolume_2d(&pts, &[1.1, 1.1]);
        // Ideal ZDT1 front hv(ref=1.1,1.1) ~ 0.87; random search gets far less.
        assert!(hv > 0.60, "hypervolume {hv}");
    }

    #[test]
    fn zdt3_handles_disconnected_front() {
        let set = run_zdt(ZdtVariant::Zdt3, 60);
        let pts: Vec<Vec<f64>> = set.iter().map(|i| i.objectives.clone()).collect();
        let hv = hypervolume_2d(&pts, &[1.1, 1.1]);
        assert!(hv > 0.60, "hypervolume {hv}");
    }

    #[test]
    fn respects_gene_ranges() {
        let mut problem = Zdt::new(ZdtVariant::Zdt2, 6, 16);
        let mut algo = Nsga2::new(Nsga2Config {
            pop_size: 8,
            initial_pop_size: 16,
            generations: 10,
            seed: 3,
            ..Default::default()
        });
        let pop = algo.run(&mut problem, |_| {});
        for ind in &pop {
            for &g in &ind.genome {
                assert!((0..=16).contains(&g), "gene {g} out of range");
            }
        }
    }

    #[test]
    fn observer_sees_every_generation() {
        let mut problem = Zdt::new(ZdtVariant::Zdt1, 4, 8);
        let mut algo = Nsga2::new(Nsga2Config {
            pop_size: 6,
            initial_pop_size: 10,
            generations: 5,
            seed: 1,
            ..Default::default()
        });
        let mut seen = Vec::new();
        algo.run(&mut problem, |s| seen.push(s.generation));
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(algo.evaluations(), 10 + 5 * 6);
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let a = run_zdt(ZdtVariant::Zdt1, 10);
        let b = run_zdt(ZdtVariant::Zdt1, 10);
        let ga: Vec<_> = a.iter().map(|i| i.genome.clone()).collect();
        let gb: Vec<_> = b.iter().map(|i| i.genome.clone()).collect();
        assert_eq!(ga, gb);
    }

    #[test]
    fn population_size_maintained() {
        let mut problem = Zdt::new(ZdtVariant::Zdt1, 4, 8);
        let mut algo = Nsga2::new(Nsga2Config {
            pop_size: 10,
            initial_pop_size: 40,
            generations: 3,
            seed: 5,
            ..Default::default()
        });
        let pop = algo.run(&mut problem, |s| {
            assert_eq!(s.population.len(), 10, "gen {}", s.generation);
        });
        assert_eq!(pop.len(), 10);
    }
}
