//! GA individual: genome + evaluation + NSGA-II bookkeeping.

use super::problem::Evaluation;

#[derive(Debug, Clone, PartialEq)]
pub struct Individual {
    pub genome: Vec<i64>,
    pub objectives: Vec<f64>,
    pub violation: f64,
    /// Non-domination rank (0 = best front), assigned by sort::fast_nondominated_sort.
    pub rank: usize,
    /// Crowding distance within its front.
    pub crowding: f64,
}

impl Individual {
    pub fn new(genome: Vec<i64>) -> Self {
        Individual {
            genome,
            objectives: Vec::new(),
            violation: 0.0,
            rank: usize::MAX,
            crowding: 0.0,
        }
    }

    /// Wrap an externally evaluated genome (island model / batched paths).
    pub fn evaluated(genome: Vec<i64>, eval: Evaluation) -> Self {
        let mut ind = Individual::new(genome);
        ind.objectives = eval.objectives;
        ind.violation = eval.violation;
        ind
    }

    pub fn feasible(&self) -> bool {
        self.violation <= 0.0
    }

    /// Binary-tournament comparison key (Deb 2002): constrained-domination
    /// rank first, then crowding distance (larger wins).
    pub fn tournament_better(&self, other: &Individual) -> bool {
        match (self.feasible(), other.feasible()) {
            (true, false) => true,
            (false, true) => false,
            (false, false) => self.violation < other.violation,
            (true, true) => {
                if self.rank != other.rank {
                    self.rank < other.rank
                } else {
                    self.crowding > other.crowding
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(rank: usize, crowding: f64, violation: f64) -> Individual {
        Individual {
            genome: vec![],
            objectives: vec![],
            violation,
            rank,
            crowding,
        }
    }

    #[test]
    fn feasible_beats_infeasible() {
        assert!(ind(5, 0.0, 0.0).tournament_better(&ind(0, 9.0, 1.0)));
    }

    #[test]
    fn lower_rank_wins() {
        assert!(ind(0, 0.0, 0.0).tournament_better(&ind(1, 9.0, 0.0)));
    }

    #[test]
    fn crowding_breaks_rank_ties() {
        assert!(ind(1, 2.0, 0.0).tournament_better(&ind(1, 1.0, 0.0)));
        assert!(!ind(1, 1.0, 0.0).tournament_better(&ind(1, 2.0, 0.0)));
    }

    #[test]
    fn smaller_violation_wins_among_infeasible() {
        assert!(ind(0, 0.0, 0.5).tournament_better(&ind(0, 0.0, 1.0)));
    }
}
