//! The optimization-problem abstraction consumed by the GA engines.

/// Result of evaluating one candidate genome.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    /// Objective values, ALL minimized (negate maximization objectives,
    /// as the paper does for speedup — §4.2).
    pub objectives: Vec<f64>,
    /// Total constraint violation; <= 0 means feasible. The paper's
    /// feasibility area (error <= baseline + 8pp) and SRAM-size constraint
    /// both land here.
    pub violation: f64,
}

impl Evaluation {
    pub fn feasible(&self) -> bool {
        self.violation <= 0.0
    }
}

/// An integer-genome multi-objective problem (the paper encodes precisions
/// as discrete values 1..4 — §4.2; ZDT test problems discretize [0,1]).
///
/// `evaluate` takes `&mut self` so implementations can cache results or
/// mutate search-time state (the beacon list in MOHAQ's Algorithm 1 grows
/// *during* evaluation).
pub trait Problem {
    fn num_vars(&self) -> usize;
    fn num_objectives(&self) -> usize;
    /// Inclusive gene range for variable `i`.
    fn var_range(&self, i: usize) -> (i64, i64);
    fn evaluate(&mut self, genome: &[i64]) -> Evaluation;

    /// Whether further evaluation is pointless (a failure fuse tripped or
    /// the search was cancelled). Engines poll this between generations
    /// and stop the loop early — a long-lived server must not spin
    /// through thousands of remaining sentinel generations after a
    /// cancellation. Default: never.
    fn aborted(&self) -> bool {
        false
    }

    /// Evaluate one generation's worth of genomes. The engine always calls
    /// this (never `evaluate` directly), so implementations that can fan
    /// evaluation out — `coordinator::MohaqProblem` across its PJRT thread
    /// pool, `moo::parallel::Parallel` for any `SyncProblem` — override it.
    /// Results MUST come back in input order and be independent of any
    /// internal scheduling, or seed determinism breaks.
    fn evaluate_batch(&mut self, genomes: &[Vec<i64>]) -> Vec<Evaluation> {
        genomes.iter().map(|g| self.evaluate(g)).collect()
    }

    /// Optional human-readable objective names (report tables).
    fn objective_names(&self) -> Vec<String> {
        (0..self.num_objectives()).map(|i| format!("f{i}")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feasibility_threshold() {
        let e = Evaluation { objectives: vec![1.0], violation: 0.0 };
        assert!(e.feasible());
        let e = Evaluation { objectives: vec![1.0], violation: 1e-9 };
        assert!(!e.feasible());
    }
}
