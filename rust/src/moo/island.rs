//! Island-model NSGA-II: K independent sub-populations, each on its own
//! `Rng::fork` stream, exchanging elites on a fixed topology every M
//! generations (coarse-grained parallel GA, Cantú-Paz style).
//!
//! Scaling rationale: the paper's search uses populations of 10/40 because
//! candidate evaluation is the bottleneck. The archipelago multiplies the
//! population per wall-clock generation — every generation of every island
//! is concatenated into ONE `Problem::evaluate_batch` call, so the K*pop
//! genomes fan out across the coordinator's whole thread pool and share
//! one PTQ cache (duplicate genomes bred on different islands are deduped
//! by `MohaqProblem` and memoized by `EvalService`).
//!
//! Determinism contract: everything outside `evaluate_batch` is sequential
//! and pure — island RNG streams are a function of (seed, island index),
//! migration snapshots elites *before* any replacement, and elite/victim
//! selection breaks ties on the genome (a total order). Because
//! `evaluate_batch` must return order-independent values (see
//! `moo::problem`), the merged front is bitwise-identical for any worker
//! thread count at a fixed (seed, K, topology).

use super::individual::Individual;
use super::nsga2::{GenerationStats, Nsga2, Nsga2Config};
use super::problem::Problem;
use super::sort::{assign_crowding, fast_nondominated_sort};
use crate::pareto::hypervolume::hypervolume;
use crate::util::rng::Rng;

/// Migration topology: who sends elites to whom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Island i receives from island (i - 1) mod K.
    Ring,
    /// Every island receives from every other island.
    FullyConnected,
}

impl Topology {
    /// Canonical config-file identifier.
    pub fn id(&self) -> &'static str {
        match self {
            Topology::Ring => "ring",
            Topology::FullyConnected => "full",
        }
    }

    /// Parse a config-file identifier (aliases accepted).
    pub fn from_id(id: &str) -> Option<Topology> {
        Some(match id {
            "ring" => Topology::Ring,
            "full" | "fully_connected" | "fully-connected" => Topology::FullyConnected,
            _ => return None,
        })
    }

    /// Islands that send migrants TO island `to` in a K-island archipelago.
    pub fn sources(&self, k: usize, to: usize) -> Vec<usize> {
        match self {
            Topology::Ring => {
                if k <= 1 {
                    Vec::new()
                } else {
                    vec![(to + k - 1) % k]
                }
            }
            Topology::FullyConnected => (0..k).filter(|&s| s != to).collect(),
        }
    }
}

/// Archipelago shape + migration policy.
#[derive(Debug, Clone, PartialEq)]
pub struct IslandConfig {
    /// Number of independent sub-populations (K).
    pub islands: usize,
    /// Exchange elites every M generations.
    pub migration_interval: usize,
    pub topology: Topology,
    /// Elites each source island sends per migration event.
    pub migrants: usize,
}

impl Default for IslandConfig {
    fn default() -> Self {
        IslandConfig {
            islands: 4,
            migration_interval: 5,
            topology: Topology::Ring,
            migrants: 2,
        }
    }
}

impl IslandConfig {
    /// Shared validation (spec builder, CLI). `pop_size` is the per-island
    /// population the migrants replace into.
    pub fn validate(&self, pop_size: usize) -> Result<(), String> {
        if self.islands == 0 {
            return Err("islands must be >= 1".into());
        }
        if self.migration_interval == 0 {
            return Err("migration_interval must be >= 1".into());
        }
        if self.migrants == 0 {
            return Err("migrants must be >= 1".into());
        }
        if self.migrants >= pop_size {
            return Err(format!(
                "migrants ({}) must be smaller than the island population ({pop_size})",
                self.migrants
            ));
        }
        Ok(())
    }
}

/// Progress notifications from `IslandModel::run`, in order. Within a
/// generation, migrations (if due) are reported before the islands'
/// generation summaries.
pub enum IslandEvent<'a> {
    /// One island finished a generation.
    Generation { island: usize, stats: GenerationStats<'a> },
    /// Elites were copied from island `from` into island `to`
    /// (`accepted` counts migrants not already present on the target).
    Migration { generation: usize, from: usize, to: usize, accepted: usize },
}

/// K lockstep NSGA-II engines over one shared `Problem`.
pub struct IslandModel {
    pub config: IslandConfig,
    islands: Vec<Nsga2>,
    evaluations: usize,
}

impl IslandModel {
    /// `ga` is the PER-ISLAND configuration (pop_size individuals per
    /// island per generation); `ga.seed` seeds the whole archipelago.
    pub fn new(ga: Nsga2Config, config: IslandConfig) -> IslandModel {
        assert!(config.islands > 0, "island model needs at least one island");
        let mut base = Rng::new(ga.seed);
        let islands = base
            .split(config.islands)
            .into_iter()
            .map(|rng| Nsga2::with_rng(ga.clone(), rng))
            .collect();
        IslandModel { config, islands, evaluations: 0 }
    }

    /// Total evaluations across all islands.
    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    pub fn num_islands(&self) -> usize {
        self.islands.len()
    }

    /// Evaluate every island's pending genomes as ONE problem batch and
    /// hand each island back its slice (input order is preserved, so this
    /// is scheduling-independent whenever `evaluate_batch` is).
    fn evaluate_groups(
        &mut self,
        problem: &mut dyn Problem,
        groups: Vec<Vec<Vec<i64>>>,
    ) -> Vec<Vec<Individual>> {
        evaluate_island_groups(&mut self.islands, &mut self.evaluations, problem, groups)
    }

    /// Run the archipelago; returns the concatenation of the final island
    /// populations (feed it to `Nsga2::pareto_set` / `merged_front` for
    /// the deduplicated non-dominated merge).
    pub fn run(
        &mut self,
        problem: &mut dyn Problem,
        observer: impl FnMut(&IslandEvent),
    ) -> Vec<Individual> {
        self.run_with_checkpoints(problem, observer, None)
    }

    /// [`IslandModel::run`] with a checkpoint sink: at every migration
    /// boundary (post-exchange, after the generation events) the sink
    /// receives `(generation, snapshots)` — one [`IslandSnapshot`] per
    /// island, exactly the state [`IslandShard::restore`] resumes
    /// bitwise. `None` skips snapshotting entirely (no population
    /// clones on the plain path).
    pub fn run_with_checkpoints(
        &mut self,
        problem: &mut dyn Problem,
        mut observer: impl FnMut(&IslandEvent),
        mut checkpoint: Option<&mut dyn FnMut(usize, &[IslandSnapshot])>,
    ) -> Vec<Individual> {
        let k = self.islands.len();
        let (target0, pop_size, generations) = {
            let c = &self.islands[0].config;
            (c.pop_size.min(c.initial_pop_size), c.pop_size, c.generations)
        };

        // Generation 0: every island's enlarged initial population in one
        // cross-island batch.
        let mut seeds: Vec<Vec<Vec<i64>>> = Vec::with_capacity(k);
        for isl in &mut self.islands {
            seeds.push(isl.seed_genomes(&*problem));
        }
        let evaluated = self.evaluate_groups(problem, seeds);
        let mut pops: Vec<Vec<Individual>> = Vec::with_capacity(k);
        for (i, group) in evaluated.into_iter().enumerate() {
            pops.push(self.islands[i].select_survivors(group, target0));
        }
        for (i, pop) in pops.iter().enumerate() {
            observer(&IslandEvent::Generation {
                island: i,
                stats: GenerationStats {
                    generation: 0,
                    evaluations: self.islands[i].evaluations(),
                    population: pop,
                },
            });
        }

        for gen in 1..=generations {
            // Stop the archipelago early once the problem's fuse tripped
            // (evaluation failure or cancellation) — everything after
            // would be sentinel work the caller discards.
            if problem.aborted() {
                break;
            }
            let mut children: Vec<Vec<Vec<i64>>> = Vec::with_capacity(k);
            for (isl, pop) in self.islands.iter_mut().zip(&pops) {
                children.push(isl.offspring_genomes(&*problem, pop));
            }
            let offspring = self.evaluate_groups(problem, children);
            for (i, off) in offspring.into_iter().enumerate() {
                let mut pool = std::mem::take(&mut pops[i]);
                pool.extend(off);
                pops[i] = self.islands[i].select_survivors(pool, pop_size);
            }
            let boundary = k > 1 && gen % self.config.migration_interval == 0;
            if boundary {
                self.migrate(&mut pops, gen, &mut observer);
            }
            for (i, pop) in pops.iter().enumerate() {
                observer(&IslandEvent::Generation {
                    island: i,
                    stats: GenerationStats {
                        generation: gen,
                        evaluations: self.islands[i].evaluations(),
                        population: pop,
                    },
                });
            }
            if boundary {
                if let Some(sink) = checkpoint.as_deref_mut() {
                    sink(gen, &self.snapshot_at(&pops));
                }
            }
        }
        pops.into_iter().flatten().collect()
    }

    /// Snapshot every island against the given populations — the
    /// checkpoint payload (post-migration state; the engine RNG at this
    /// point is exactly the pre-offspring state of the next generation).
    fn snapshot_at(&self, pops: &[Vec<Individual>]) -> Vec<IslandSnapshot> {
        pops.iter()
            .enumerate()
            .map(|(i, pop)| IslandSnapshot {
                island: i,
                rng: self.islands[i].rng_state(),
                evaluations: self.islands[i].evaluations(),
                pop: pop.clone(),
            })
            .collect()
    }

    /// One migration round. Elites are snapshotted from every island
    /// BEFORE any replacement, so the exchange is computed from the
    /// pre-migration state and the topology's iteration order can never
    /// influence what is sent (determinism contract).
    fn migrate(
        &self,
        pops: &mut [Vec<Individual>],
        generation: usize,
        observer: &mut impl FnMut(&IslandEvent),
    ) {
        let k = pops.len();
        let elites: Vec<Vec<Individual>> = pops
            .iter()
            .map(|p| select_elites(p, self.config.migrants))
            .collect();
        for to in 0..k {
            for from in self.config.topology.sources(k, to) {
                let accepted = inject(&mut pops[to], &elites[from]);
                if accepted > 0 {
                    observer(&IslandEvent::Migration { generation, from, to, accepted });
                }
            }
        }
    }
}

/// Shared group-evaluation step: flatten per-island genome groups into ONE
/// `evaluate_batch` call, credit each engine with its own slice, and hand
/// the evaluated individuals back per island. `total` accrues the batch
/// size (the model/shard-level evaluation counter).
fn evaluate_island_groups(
    engines: &mut [Nsga2],
    total: &mut usize,
    problem: &mut dyn Problem,
    groups: Vec<Vec<Vec<i64>>>,
) -> Vec<Vec<Individual>> {
    let counts: Vec<usize> = groups.iter().map(Vec::len).collect();
    let flat: Vec<Vec<i64>> = groups.into_iter().flatten().collect();
    *total += flat.len();
    let evals = problem.evaluate_batch(&flat);
    debug_assert_eq!(evals.len(), flat.len());
    let mut remaining: Vec<Individual> = flat
        .into_iter()
        .zip(evals)
        .map(|(g, e)| Individual::evaluated(g, e))
        .collect();
    let mut out = Vec::with_capacity(counts.len());
    for (i, &c) in counts.iter().enumerate() {
        let tail = remaining.split_off(c);
        engines[i].add_evaluations(remaining.len());
        out.push(std::mem::replace(&mut remaining, tail));
    }
    out
}

/// Serializable checkpoint of one island at a generation boundary:
/// everything a process needs to resume the island's stream exactly —
/// engine RNG state, the engine's evaluation counter, and the ranked
/// population. Captured post-migration, so replaying from a snapshot
/// reproduces the remainder of the search bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct IslandSnapshot {
    /// Global island index within the archipelago.
    pub island: usize,
    /// Engine RNG state (`Nsga2::rng_state`).
    pub rng: [u64; 4],
    /// Engine-level evaluation counter.
    pub evaluations: usize,
    /// Current (evaluated, ranked) population.
    pub pop: Vec<Individual>,
}

/// A subset of an archipelago's islands, steppable one generation at a
/// time with explicit elite exchange — the unit a distributed worker runs
/// (`dist::`). Island RNG streams are a pure function of (seed, K, island
/// index), so a shard recreates exactly the engines `IslandModel` would
/// have used for those indices; because `evaluate_batch` values must be
/// order-independent pure functions of the genome (see `moo::problem`),
/// splitting the cross-island batches per shard cannot change any value,
/// and a full exchange schedule reproduces the single-process archipelago
/// bit for bit.
pub struct IslandShard {
    pub config: IslandConfig,
    /// Global island indices this shard owns (strictly ascending).
    indices: Vec<usize>,
    engines: Vec<Nsga2>,
    pops: Vec<Vec<Individual>>,
    generation: usize,
    seeded: bool,
    evaluations: usize,
}

impl IslandShard {
    /// A fresh shard owning the islands at `indices` (strictly ascending
    /// global indices into a `config.islands`-island archipelago). `ga` is
    /// the per-island configuration, identical on every shard.
    pub fn new(ga: Nsga2Config, config: IslandConfig, indices: &[usize]) -> Result<Self, String> {
        if indices.is_empty() {
            return Err("shard needs at least one island".into());
        }
        for w in indices.windows(2) {
            if w[1] <= w[0] {
                return Err("shard island indices must be strictly ascending".into());
            }
        }
        let k = config.islands;
        if *indices.last().unwrap() >= k {
            return Err(format!(
                "island index {} out of range for {k} islands",
                indices.last().unwrap()
            ));
        }
        // Recreate the archipelago's full fork set and keep our subset:
        // the streams must match IslandModel::new positionally.
        let mut base = Rng::new(ga.seed);
        let engines: Vec<Nsga2> = base
            .split(k)
            .into_iter()
            .enumerate()
            .filter(|(i, _)| indices.contains(i))
            .map(|(_, rng)| Nsga2::with_rng(ga.clone(), rng))
            .collect();
        let n = engines.len();
        Ok(IslandShard {
            config,
            indices: indices.to_vec(),
            engines,
            pops: vec![Vec::new(); n],
            generation: 0,
            seeded: false,
            evaluations: 0,
        })
    }

    /// Rebuild a shard from per-island snapshots taken at generation
    /// `generation` (post-migration). The restored shard continues the
    /// search exactly where the snapshots stopped.
    pub fn restore(
        ga: Nsga2Config,
        config: IslandConfig,
        generation: usize,
        snapshots: Vec<IslandSnapshot>,
    ) -> Result<Self, String> {
        if snapshots.is_empty() {
            return Err("shard needs at least one island snapshot".into());
        }
        let k = config.islands;
        let mut indices = Vec::with_capacity(snapshots.len());
        let mut engines = Vec::with_capacity(snapshots.len());
        let mut pops = Vec::with_capacity(snapshots.len());
        let mut evaluations = 0usize;
        for s in snapshots {
            if indices.last().is_some_and(|&last| s.island <= last) {
                return Err("shard island snapshots must be strictly ascending".into());
            }
            if s.island >= k {
                return Err(format!("island index {} out of range for {k} islands", s.island));
            }
            let mut engine = Nsga2::with_rng(ga.clone(), Rng::from_state(s.rng));
            engine.add_evaluations(s.evaluations);
            evaluations += s.evaluations;
            indices.push(s.island);
            engines.push(engine);
            pops.push(s.pop);
        }
        Ok(IslandShard {
            config,
            indices,
            engines,
            pops,
            generation,
            seeded: true,
            evaluations,
        })
    }

    pub fn generation(&self) -> usize {
        self.generation
    }

    pub fn seeded(&self) -> bool {
        self.seeded
    }

    /// Global indices of the islands this shard owns.
    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Current populations, positionally matching `indices()`.
    pub fn pops(&self) -> &[Vec<Individual>] {
        &self.pops
    }

    /// Engine-level evaluation counter of local island `local`.
    pub fn engine_evaluations(&self, local: usize) -> usize {
        self.engines[local].evaluations()
    }

    /// Evaluations performed by this shard (its share of the archipelago
    /// budget; restored shards carry their history forward).
    pub fn evaluations(&self) -> usize {
        self.evaluations
    }

    /// Generation 0: every local island's enlarged initial population in
    /// one cross-island batch (mirrors `IslandModel::run`).
    pub fn seed(&mut self, problem: &mut dyn Problem) {
        debug_assert!(!self.seeded, "shard already seeded");
        let target0 = {
            let c = &self.engines[0].config;
            c.pop_size.min(c.initial_pop_size)
        };
        let mut seeds: Vec<Vec<Vec<i64>>> = Vec::with_capacity(self.engines.len());
        for engine in &mut self.engines {
            seeds.push(engine.seed_genomes(&*problem));
        }
        let evaluated =
            evaluate_island_groups(&mut self.engines, &mut self.evaluations, problem, seeds);
        for (i, group) in evaluated.into_iter().enumerate() {
            self.pops[i] = self.engines[i].select_survivors(group, target0);
        }
        self.seeded = true;
    }

    /// Advance every local island one generation (offspring bred first so
    /// the engine RNG streams match the lockstep archipelago, then ONE
    /// cross-island evaluation batch, then (mu+lambda) survival). Returns
    /// the new generation number. Elite exchange is the caller's job, at
    /// the same boundaries `IslandModel::run` uses.
    pub fn step(&mut self, problem: &mut dyn Problem) -> usize {
        debug_assert!(self.seeded, "seed the shard before stepping");
        let pop_size = self.engines[0].config.pop_size;
        let mut children: Vec<Vec<Vec<i64>>> = Vec::with_capacity(self.engines.len());
        for (engine, pop) in self.engines.iter_mut().zip(&self.pops) {
            children.push(engine.offspring_genomes(&*problem, pop));
        }
        let offspring =
            evaluate_island_groups(&mut self.engines, &mut self.evaluations, problem, children);
        for (i, off) in offspring.into_iter().enumerate() {
            let mut pool = std::mem::take(&mut self.pops[i]);
            pool.extend(off);
            self.pops[i] = self.engines[i].select_survivors(pool, pop_size);
        }
        self.generation += 1;
        self.generation
    }

    /// Pre-migration elites of every local island: `(global index,
    /// migrants)` pairs, selected by the same deterministic quality order
    /// the single-process exchange uses. Pure — does not touch RNG state.
    pub fn elites(&self) -> Vec<(usize, Vec<Individual>)> {
        self.indices
            .iter()
            .zip(&self.pops)
            .map(|(&g, p)| (g, select_elites(p, self.config.migrants)))
            .collect()
    }

    /// Inject migrants into global island `island` (replacing its worst,
    /// skipping genomes already present, then re-ranking). Returns the
    /// accepted count, or `None` if this shard does not own the island.
    /// Callers must apply source groups in the topology's global order.
    pub fn inject(&mut self, island: usize, incoming: &[Individual]) -> Option<usize> {
        let local = self.indices.iter().position(|&g| g == island)?;
        Some(inject(&mut self.pops[local], incoming))
    }

    /// Checkpoint every local island (positionally matching `indices()`).
    pub fn snapshot(&self) -> Vec<IslandSnapshot> {
        self.indices
            .iter()
            .enumerate()
            .map(|(local, &island)| IslandSnapshot {
                island,
                rng: self.engines[local].rng_state(),
                evaluations: self.engines[local].evaluations(),
                pop: self.pops[local].clone(),
            })
            .collect()
    }
}

/// Deduplicated non-dominated feasible merge of island populations — the
/// front the session reports. Equivalent to `Nsga2::pareto_set` over the
/// concatenated populations.
pub fn merged_front(pops: &[Vec<Individual>]) -> Vec<Individual> {
    let all: Vec<Individual> = pops.iter().flatten().cloned().collect();
    Nsga2::pareto_set(&all)
}

/// Hypervolume of a front against a nadir-derived reference point (the
/// worst objective value per dimension, padded by 10% of the span).
/// `None` for empty fronts and for dimensions the exact algorithms do not
/// cover (only 2-D and 3-D are wired).
pub fn front_hypervolume(front: &[Individual]) -> Option<f64> {
    if front.is_empty() {
        return None;
    }
    let m = front[0].objectives.len();
    if m != 2 && m != 3 {
        return None;
    }
    let mut reference = vec![f64::NEG_INFINITY; m];
    let mut best = vec![f64::INFINITY; m];
    for ind in front {
        for (d, &v) in ind.objectives.iter().enumerate() {
            reference[d] = reference[d].max(v);
            best[d] = best[d].min(v);
        }
    }
    for d in 0..m {
        let span = reference[d] - best[d];
        reference[d] += (span * 0.1).max(1e-9);
    }
    let pts: Vec<Vec<f64>> = front.iter().map(|i| i.objectives.clone()).collect();
    Some(hypervolume(&pts, &reference))
}

/// Deterministic quality order: feasible first, then rank, then crowding
/// (descending), with the genome as a total-order tie-break.
fn quality(a: &Individual, b: &Individual) -> std::cmp::Ordering {
    b.feasible()
        .cmp(&a.feasible())
        .then(a.rank.cmp(&b.rank))
        .then(
            b.crowding
                .partial_cmp(&a.crowding)
                .unwrap_or(std::cmp::Ordering::Equal),
        )
        .then_with(|| a.genome.cmp(&b.genome))
}

/// The island's `n` best individuals under the deterministic order.
fn select_elites(pop: &[Individual], n: usize) -> Vec<Individual> {
    let mut idx: Vec<usize> = (0..pop.len()).collect();
    idx.sort_by(|&x, &y| quality(&pop[x], &pop[y]));
    idx.into_iter().take(n).map(|i| pop[i].clone()).collect()
}

/// Replace the worst individuals of `pop` with `incoming` elites (skipping
/// genomes already present), then re-rank the island: migrant ranks and
/// crowding were computed on their home island and are stale here.
/// Returns the number of migrants accepted.
fn inject(pop: &mut [Individual], incoming: &[Individual]) -> usize {
    let fresh: Vec<Individual> = incoming
        .iter()
        .filter(|m| !pop.iter().any(|p| p.genome == m.genome))
        .cloned()
        .collect();
    if fresh.is_empty() {
        return 0;
    }
    let m = fresh.len().min(pop.len());
    let mut order: Vec<usize> = (0..pop.len()).collect();
    order.sort_by(|&x, &y| quality(&pop[x], &pop[y]));
    for (&slot, ind) in order[pop.len() - m..].iter().zip(fresh) {
        pop[slot] = ind;
    }
    let fronts = fast_nondominated_sort(pop);
    assign_crowding(pop, &fronts);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moo::problems::{Zdt, ZdtVariant};
    use crate::pareto::hypervolume::hypervolume_2d;

    fn ga(seed: u64, gens: usize) -> Nsga2Config {
        Nsga2Config {
            pop_size: 8,
            initial_pop_size: 12,
            generations: gens,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn topology_sources() {
        assert_eq!(Topology::Ring.sources(4, 0), vec![3]);
        assert_eq!(Topology::Ring.sources(4, 2), vec![1]);
        assert!(Topology::Ring.sources(1, 0).is_empty());
        assert_eq!(Topology::FullyConnected.sources(3, 1), vec![0, 2]);
        assert_eq!(Topology::from_id("ring"), Some(Topology::Ring));
        assert_eq!(Topology::from_id("full"), Some(Topology::FullyConnected));
        assert_eq!(Topology::from_id("torus"), None);
        for t in [Topology::Ring, Topology::FullyConnected] {
            assert_eq!(Topology::from_id(t.id()), Some(t));
        }
    }

    #[test]
    fn config_validation() {
        assert!(IslandConfig::default().validate(10).is_ok());
        assert!(IslandConfig { islands: 0, ..Default::default() }.validate(10).is_err());
        let c = IslandConfig { migration_interval: 0, ..Default::default() };
        assert!(c.validate(10).is_err());
        assert!(IslandConfig { migrants: 0, ..Default::default() }.validate(10).is_err());
        assert!(IslandConfig { migrants: 10, ..Default::default() }.validate(10).is_err());
    }

    #[test]
    fn run_is_deterministic_and_emits_migrations() {
        let run = || {
            let mut problem = Zdt::new(ZdtVariant::Zdt1, 6, 32);
            let cfg = IslandConfig {
                islands: 3,
                migration_interval: 2,
                topology: Topology::Ring,
                migrants: 2,
            };
            let mut model = IslandModel::new(ga(9, 10), cfg);
            let mut migrations = 0usize;
            let pop = model.run(&mut problem, |e| {
                if let IslandEvent::Migration { .. } = e {
                    migrations += 1;
                }
            });
            let genomes: Vec<Vec<i64>> = pop.iter().map(|i| i.genome.clone()).collect();
            (genomes, migrations, model.evaluations())
        };
        let (a, ma, ea) = run();
        let (b, mb, eb) = run();
        assert_eq!(a, b, "same seed must reproduce the archipelago");
        assert_eq!(ma, mb);
        assert!(ma > 0, "ring migration should fire");
        assert_eq!(ea, eb);
        assert_eq!(ea, 3 * (12 + 10 * 8), "per-island budget accounting");
    }

    #[test]
    fn merged_front_never_loses_hypervolume_vs_any_island() {
        let mut problem = Zdt::new(ZdtVariant::Zdt3, 8, 32);
        let mut model = IslandModel::new(ga(4, 15), IslandConfig::default());
        let mut finals: Vec<Vec<Individual>> = vec![Vec::new(); 4];
        let pop = model.run(&mut problem, |e| {
            if let IslandEvent::Generation { island, stats } = e {
                if stats.generation == 15 {
                    finals[*island] = stats.population.to_vec();
                }
            }
        });
        let merged = Nsga2::pareto_set(&pop);
        assert!(!merged.is_empty());
        let hv = |front: &[Individual]| {
            let pts: Vec<Vec<f64>> = front.iter().map(|i| i.objectives.clone()).collect();
            hypervolume_2d(&pts, &[1.1, 7.0])
        };
        let merged_hv = hv(&merged);
        for island_pop in &finals {
            assert!(!island_pop.is_empty(), "observer missed a final population");
            let front = Nsga2::pareto_set(island_pop);
            assert!(
                merged_hv + 1e-12 >= hv(&front),
                "merged front lost hypervolume vs a constituent island"
            );
        }
        // Merge is a front: mutually non-dominated, genome-deduplicated.
        for a in &merged {
            for b in &merged {
                if a.genome != b.genome {
                    assert!(!crate::pareto::dominates(&a.objectives, &b.objectives));
                }
            }
        }
        let mut genomes: Vec<&Vec<i64>> = merged.iter().map(|i| &i.genome).collect();
        genomes.sort();
        genomes.dedup();
        assert_eq!(genomes.len(), merged.len(), "duplicate genome in merged front");
    }

    #[test]
    fn merged_front_helper_matches_pareto_set_of_concatenation() {
        let mut problem = Zdt::new(ZdtVariant::Zdt2, 6, 16);
        let mut model = IslandModel::new(ga(11, 6), IslandConfig::default());
        let pop = model.run(&mut problem, |_| {});
        let via_pop = Nsga2::pareto_set(&pop);
        // Rebuild per-island groups of equal size and merge through the
        // helper; both paths must agree.
        let per = pop.len() / 4;
        let groups: Vec<Vec<Individual>> = pop.chunks(per).map(|c| c.to_vec()).collect();
        let via_helper = merged_front(&groups);
        let key = |f: &[Individual]| {
            f.iter().map(|i| i.genome.clone()).collect::<Vec<_>>()
        };
        assert_eq!(key(&via_pop), key(&via_helper));
    }

    /// Bitwise identity key: genome + objective/violation/crowding bits +
    /// rank — everything the merge and the wire codec must preserve.
    fn pop_key(pop: &[Individual]) -> Vec<(Vec<i64>, Vec<u64>, u64, usize, u64)> {
        pop.iter()
            .map(|i| {
                (
                    i.genome.clone(),
                    i.objectives.iter().map(|v| v.to_bits()).collect(),
                    i.violation.to_bits(),
                    i.rank,
                    i.crowding.to_bits(),
                )
            })
            .collect()
    }

    /// Coordinator-style driver: run `parts` as independent shards (each
    /// on its OWN problem instance, like worker processes), performing the
    /// global elite exchange at every boundary, and return the final
    /// populations concatenated in global island order.
    fn run_sharded(parts: &[Vec<usize>], ga_cfg: Nsga2Config, cfg: IslandConfig) -> Vec<Individual> {
        let gens = ga_cfg.generations;
        let k = cfg.islands;
        let mut shards: Vec<IslandShard> = parts
            .iter()
            .map(|p| IslandShard::new(ga_cfg.clone(), cfg.clone(), p).unwrap())
            .collect();
        let mut problems: Vec<Zdt> =
            parts.iter().map(|_| Zdt::new(ZdtVariant::Zdt1, 6, 32)).collect();
        for (s, p) in shards.iter_mut().zip(&mut problems) {
            s.seed(p);
        }
        for gen in 1..=gens {
            for (s, p) in shards.iter_mut().zip(&mut problems) {
                s.step(p);
            }
            if k > 1 && gen % cfg.migration_interval == 0 {
                let mut elites: Vec<Vec<Individual>> = vec![Vec::new(); k];
                for s in &shards {
                    for (g, e) in s.elites() {
                        elites[g] = e;
                    }
                }
                for to in 0..k {
                    for from in cfg.topology.sources(k, to) {
                        for s in shards.iter_mut() {
                            if s.inject(to, &elites[from]).is_some() {
                                break;
                            }
                        }
                    }
                }
            }
        }
        let mut by_island: Vec<(usize, Vec<Individual>)> = Vec::new();
        for s in &shards {
            for (local, &g) in s.indices().iter().enumerate() {
                by_island.push((g, s.pops()[local].clone()));
            }
        }
        by_island.sort_by_key(|(g, _)| *g);
        by_island.into_iter().flat_map(|(_, p)| p).collect()
    }

    #[test]
    fn shards_reproduce_island_model_bitwise() {
        for topology in [Topology::Ring, Topology::FullyConnected] {
            let cfg = IslandConfig {
                islands: 3,
                migration_interval: 2,
                topology,
                migrants: 2,
            };
            let mut problem = Zdt::new(ZdtVariant::Zdt1, 6, 32);
            let mut model = IslandModel::new(ga(9, 10), cfg.clone());
            let reference = model.run(&mut problem, |_| {});

            // One shard covering everything, and a genuinely split pair.
            for parts in [vec![vec![0, 1, 2]], vec![vec![0], vec![1, 2]]] {
                let sharded = run_sharded(&parts, ga(9, 10), cfg.clone());
                assert_eq!(
                    pop_key(&reference),
                    pop_key(&sharded),
                    "sharded run diverged ({topology:?}, {} shard(s))",
                    parts.len()
                );
            }
        }
    }

    #[test]
    fn shard_snapshot_restore_resumes_bitwise() {
        let cfg = IslandConfig {
            islands: 3,
            migration_interval: 2,
            topology: Topology::FullyConnected,
            migrants: 2,
        };
        let ga_cfg = ga(21, 6);
        let mut problem = Zdt::new(ZdtVariant::Zdt1, 6, 32);
        let mut model = IslandModel::new(ga_cfg.clone(), cfg.clone());
        let reference = model.run(&mut problem, |_| {});

        // Run split shards, but checkpoint + rebuild BOTH shards at the
        // gen-4 boundary (post-exchange) — the coordinator's re-shard path.
        let parts: Vec<Vec<usize>> = vec![vec![0, 1], vec![2]];
        let k = cfg.islands;
        let mut shards: Vec<IslandShard> = parts
            .iter()
            .map(|p| IslandShard::new(ga_cfg.clone(), cfg.clone(), p).unwrap())
            .collect();
        let mut problems: Vec<Zdt> =
            parts.iter().map(|_| Zdt::new(ZdtVariant::Zdt1, 6, 32)).collect();
        for (s, p) in shards.iter_mut().zip(&mut problems) {
            s.seed(p);
        }
        let exchange = |shards: &mut Vec<IslandShard>, cfg: &IslandConfig| {
            let mut elites: Vec<Vec<Individual>> = vec![Vec::new(); k];
            for s in shards.iter() {
                for (g, e) in s.elites() {
                    elites[g] = e;
                }
            }
            for to in 0..k {
                for from in cfg.topology.sources(k, to) {
                    for s in shards.iter_mut() {
                        if s.inject(to, &elites[from]).is_some() {
                            break;
                        }
                    }
                }
            }
        };
        for gen in 1..=ga_cfg.generations {
            for (s, p) in shards.iter_mut().zip(&mut problems) {
                s.step(p);
            }
            if gen % cfg.migration_interval == 0 {
                exchange(&mut shards, &cfg);
            }
            if gen == 4 {
                // Re-shard: islands {0,1} and {2} swap to {0} and {1,2},
                // rebuilt purely from snapshots.
                let mut snaps: Vec<IslandSnapshot> =
                    shards.iter().flat_map(|s| s.snapshot()).collect();
                snaps.sort_by_key(|s| s.island);
                let tail = snaps.split_off(1);
                shards = vec![
                    IslandShard::restore(ga_cfg.clone(), cfg.clone(), gen, snaps).unwrap(),
                    IslandShard::restore(ga_cfg.clone(), cfg.clone(), gen, tail).unwrap(),
                ];
                problems = vec![
                    Zdt::new(ZdtVariant::Zdt1, 6, 32),
                    Zdt::new(ZdtVariant::Zdt1, 6, 32),
                ];
            }
        }
        let mut by_island: Vec<(usize, Vec<Individual>)> = Vec::new();
        for s in &shards {
            for (local, &g) in s.indices().iter().enumerate() {
                by_island.push((g, s.pops()[local].clone()));
            }
        }
        by_island.sort_by_key(|(g, _)| *g);
        let resumed: Vec<Individual> = by_island.into_iter().flat_map(|(_, p)| p).collect();
        assert_eq!(pop_key(&reference), pop_key(&resumed), "restore diverged from lockstep run");
        let evals: usize = shards.iter().map(IslandShard::evaluations).sum();
        assert_eq!(evals, 3 * (12 + 6 * 8), "restored shards must carry the budget forward");
    }

    #[test]
    fn shard_construction_validates() {
        let cfg = IslandConfig { islands: 3, ..Default::default() };
        assert!(IslandShard::new(ga(1, 5), cfg.clone(), &[]).is_err());
        assert!(IslandShard::new(ga(1, 5), cfg.clone(), &[1, 1]).is_err());
        assert!(IslandShard::new(ga(1, 5), cfg.clone(), &[2, 1]).is_err());
        assert!(IslandShard::new(ga(1, 5), cfg.clone(), &[3]).is_err());
        let shard = IslandShard::new(ga(1, 5), cfg.clone(), &[0, 2]).unwrap();
        assert_eq!(shard.indices(), &[0, 2]);
        assert!(!shard.seeded());
        assert_eq!(shard.generation(), 0);
        assert!(IslandShard::restore(ga(1, 5), cfg, 0, Vec::new()).is_err());
    }

    #[test]
    fn front_hypervolume_scores_2d_fronts_only() {
        let mk = |objs: Vec<Vec<f64>>| {
            objs.into_iter()
                .map(|o| {
                    let mut i = Individual::new(vec![]);
                    i.objectives = o;
                    i
                })
                .collect::<Vec<Individual>>()
        };
        assert!(front_hypervolume(&[]).is_none());
        let f = mk(vec![vec![0.0, 1.0], vec![1.0, 0.0]]);
        let hv = front_hypervolume(&f).unwrap();
        assert!(hv > 0.0, "hv {hv}");
        let f4 = mk(vec![vec![0.0; 4]]);
        assert!(front_hypervolume(&f4).is_none());
    }
}
