//! Rust mirror of the MMSE clip search (python/compile/quantize.py) —
//! used for cross-language consistency tests and by the fig5/beacon
//! tooling when it needs to re-derive clips for ad-hoc tensors.

use crate::quant::Bits;

/// Symmetric linear fake quantization (same semantics as the L1 kernel).
pub fn fake_quant(x: f32, clip: f64, bits: Bits) -> f32 {
    if bits == Bits::B32 {
        return x;
    }
    let levels = 2f64.powi(bits.bits() as i32 - 1);
    let delta = clip / levels;
    let q = (x as f64 / delta).round().clamp(-levels, levels - 1.0);
    (q * delta) as f32
}

/// Grid-search the clip threshold minimizing quantization MSE — identical
/// grid (60 points over (0, max|x|]) to the Python calibration.
pub fn mmse_clip(xs: &[f32], bits: Bits, n_grid: usize) -> f64 {
    let amax = xs.iter().fold(0f32, |m, &v| m.max(v.abs())) as f64;
    if amax == 0.0 || xs.is_empty() {
        return 1e-8;
    }
    let mut best = (amax, f64::INFINITY);
    for k in 1..=n_grid {
        let clip = amax * k as f64 / n_grid as f64;
        let mse: f64 = xs
            .iter()
            .map(|&v| {
                let e = (v - fake_quant(v, clip, bits)) as f64;
                e * e
            })
            .sum::<f64>()
            / xs.len() as f64;
        if mse < best.1 {
            best = (clip, mse);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_prop;
    use crate::util::rng::Rng;

    #[test]
    fn fq_is_idempotent() {
        check_prop(
            "fq_idempotent",
            500,
            |r: &mut Rng| (r.normal() as f32 * 2.0, 1.0 + r.f64() * 3.0),
            |&(x, clip)| {
                for bits in [Bits::B2, Bits::B4, Bits::B8, Bits::B16] {
                    let once = fake_quant(x, clip, bits);
                    let twice = fake_quant(once, clip, bits);
                    if (once - twice).abs() > 1e-6 {
                        return Err(format!("not idempotent at {bits}: {once} vs {twice}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fq_error_bounded_by_half_delta_inside_clip() {
        check_prop(
            "fq_error_bound",
            500,
            |r: &mut Rng| (r.f64() as f32 * 0.9, 1.0f64), // x in [0, 0.9), clip 1
            |&(x, clip)| {
                for bits in [Bits::B4, Bits::B8] {
                    let delta = clip / 2f64.powi(bits.bits() as i32 - 1);
                    let err = (x - fake_quant(x, clip, bits)).abs() as f64;
                    if err > delta / 2.0 + 1e-9 {
                        return Err(format!("err {err} > delta/2 {}", delta / 2.0));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn mmse_clips_inside_tail_for_normal_data() {
        // Gaussian-ish weights: at 4 bits the MSE-optimal clip sits well
        // inside the max (≈2.6σ for normal data — the paper's outlier
        // observation, §2.3); at 16 bits it covers nearly the full range.
        let mut rng = Rng::new(123);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.normal() as f32).collect();
        let amax = xs.iter().fold(0f32, |m, &v| m.max(v.abs())) as f64;
        let clip4 = mmse_clip(&xs, Bits::B4, 60);
        assert!(clip4 < 0.85 * amax, "clip4={clip4} amax={amax}");
        let clip16 = mmse_clip(&xs, Bits::B16, 60);
        assert!(clip16 > clip4, "clip16={clip16} clip4={clip4}");
    }

    #[test]
    fn mmse_of_empty_or_zero_is_epsilon() {
        assert_eq!(mmse_clip(&[], Bits::B4, 60), 1e-8);
        assert_eq!(mmse_clip(&[0.0, 0.0], Bits::B4, 60), 1e-8);
    }

    #[test]
    fn quantized_values_on_grid() {
        check_prop(
            "fq_on_grid",
            300,
            |r: &mut Rng| r.normal() as f32,
            |&x| {
                let clip = 1.5;
                let bits = Bits::B4;
                let delta = clip / 8.0;
                let q = fake_quant(x, clip, bits) as f64;
                let steps = q / delta;
                if (steps - steps.round()).abs() > 1e-9 {
                    return Err(format!("{q} not on grid delta={delta}"));
                }
                if !(-8.0 * delta..=7.0 * delta).contains(&q) {
                    return Err(format!("{q} outside clip range"));
                }
                Ok(())
            },
        );
    }
}
