//! Quantization-domain types: precision enum, genome encode/decode
//! (paper §4.2: discrete gene values 1..4 for 2/4/8/16 bits), and the
//! resolution of genomes against calibration tables into the runtime
//! (Δ, qmin, qmax, enabled) rows the AOT executable consumes.

pub mod mmse;

use std::collections::BTreeMap;

/// A supported precision. B32 is the float baseline (quantization off) —
/// never searched, only used for baseline rows of the report tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Bits {
    B2,
    B4,
    B8,
    B16,
    B32,
}

impl Bits {
    pub fn bits(&self) -> u32 {
        match self {
            Bits::B2 => 2,
            Bits::B4 => 4,
            Bits::B8 => 8,
            Bits::B16 => 16,
            Bits::B32 => 32,
        }
    }

    /// Paper gene encoding (§4.2): 2-bit -> 1, 4-bit -> 2, 8-bit -> 3,
    /// 16-bit -> 4.
    pub fn to_gene(&self) -> i64 {
        match self {
            Bits::B2 => 1,
            Bits::B4 => 2,
            Bits::B8 => 3,
            Bits::B16 => 4,
            Bits::B32 => panic!("B32 is not searchable"),
        }
    }

    pub fn from_gene(g: i64) -> Option<Bits> {
        match g {
            1 => Some(Bits::B2),
            2 => Some(Bits::B4),
            3 => Some(Bits::B8),
            4 => Some(Bits::B16),
            _ => None,
        }
    }

    pub fn from_bits(b: u32) -> Option<Bits> {
        match b {
            2 => Some(Bits::B2),
            4 => Some(Bits::B4),
            8 => Some(Bits::B8),
            16 => Some(Bits::B16),
            32 => Some(Bits::B32),
            _ => None,
        }
    }

    /// log2 of the precision — the beacon distance metric operates on
    /// these (§4.3: "compare the log2 of the precision values").
    pub fn log2(&self) -> f64 {
        (self.bits() as f64).log2()
    }

    pub const SEARCHABLE: [Bits; 4] = [Bits::B2, Bits::B4, Bits::B8, Bits::B16];

    /// Dense index 0..COUNT over ALL precisions (searchable + B32) —
    /// the row index of [`QparamTable`].
    pub fn index(&self) -> usize {
        match self {
            Bits::B2 => 0,
            Bits::B4 => 1,
            Bits::B8 => 2,
            Bits::B16 => 3,
            Bits::B32 => 4,
        }
    }

    /// Number of distinct precisions (`index()` range).
    pub const COUNT: usize = 5;
}

impl std::fmt::Display for Bits {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.bits())
    }
}

/// A full mixed-precision assignment: weight + activation bits per layer.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub w_bits: Vec<Bits>,
    pub a_bits: Vec<Bits>,
}

impl QuantConfig {
    pub fn uniform(n_layers: usize, w: Bits, a: Bits) -> QuantConfig {
        QuantConfig { w_bits: vec![w; n_layers], a_bits: vec![a; n_layers] }
    }

    pub fn num_layers(&self) -> usize {
        self.w_bits.len()
    }

    /// Decode the 2L-gene genome of experiments 1/3 (paper §4.2): genes
    /// [w_0, a_0, w_1, a_1, ...] — weight and activation per layer.
    pub fn from_genome_wa(genome: &[i64]) -> Option<QuantConfig> {
        if genome.len() % 2 != 0 {
            return None;
        }
        let n = genome.len() / 2;
        let mut w = Vec::with_capacity(n);
        let mut a = Vec::with_capacity(n);
        for i in 0..n {
            w.push(Bits::from_gene(genome[2 * i])?);
            a.push(Bits::from_gene(genome[2 * i + 1])?);
        }
        Some(QuantConfig { w_bits: w, a_bits: a })
    }

    /// Decode the L-gene genome of the SiLago experiment (W = A per layer).
    pub fn from_genome_tied(genome: &[i64]) -> Option<QuantConfig> {
        let bits: Option<Vec<Bits>> =
            genome.iter().map(|&g| Bits::from_gene(g)).collect();
        let bits = bits?;
        Some(QuantConfig { w_bits: bits.clone(), a_bits: bits })
    }

    pub fn to_genome_wa(&self) -> Vec<i64> {
        let mut g = Vec::with_capacity(2 * self.w_bits.len());
        for i in 0..self.w_bits.len() {
            g.push(self.w_bits[i].to_gene());
            g.push(self.a_bits[i].to_gene());
        }
        g
    }

    /// Beacon distance (paper §4.3): sum over layers of |log2 w_bits
    /// difference| — activations are deliberately excluded ("the precision
    /// of the weights is more important ... we only used the weights
    /// precisions in the distance computation").
    pub fn beacon_distance(&self, other: &QuantConfig) -> f64 {
        self.w_bits
            .iter()
            .zip(&other.w_bits)
            .map(|(a, b)| (a.log2() - b.log2()).abs())
            .sum()
    }

    /// Compact display like the paper tables: "8/16 4/16 ..." per layer.
    pub fn display_wa(&self) -> String {
        self.w_bits
            .iter()
            .zip(&self.a_bits)
            .map(|(w, a)| format!("{w}/{a}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Per-(layer, bits) clip thresholds loaded from calibration.json.
pub type ClipTable = BTreeMap<String, BTreeMap<u32, f64>>;

/// A runtime quant-parameter row: [delta, qmin, qmax, enabled] — must stay
/// bit-identical in meaning to python/compile/quantize.py::qparams_row.
pub fn qparams_row(clip: f64, bits: Bits) -> [f32; 4] {
    if bits == Bits::B32 {
        return [1.0, -1.0, 1.0, 0.0];
    }
    let levels = 2f64.powi(bits.bits() as i32 - 1);
    [
        (clip / levels) as f32,
        (-levels) as f32,
        (levels - 1.0) as f32,
        1.0,
    ]
}

/// Resolve a QuantConfig to the flattened wq/aq matrices ((L,4) row-major)
/// fed to the AOT executable.
///
/// Test-only oracle: the runtime resolves through the dense
/// [`QparamTable`] everywhere (built once at `Artifacts` load); this
/// string-keyed walk of the raw clip tables survives only to pin the
/// table bitwise-identical to the original formulation
/// (`dense_table_matches_btreemap_resolution_prop`).
#[cfg(test)]
pub fn resolve_qparams(
    qc: &QuantConfig,
    layer_names: &[String],
    w_clips: &ClipTable,
    a_clips: &ClipTable,
) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
    anyhow::ensure!(
        qc.num_layers() == layer_names.len(),
        "config has {} layers, model has {}",
        qc.num_layers(),
        layer_names.len()
    );
    let mut wq = Vec::with_capacity(qc.num_layers() * 4);
    let mut aq = Vec::with_capacity(qc.num_layers() * 4);
    for (i, name) in layer_names.iter().enumerate() {
        let lookup = |table: &ClipTable, bits: Bits| -> anyhow::Result<f64> {
            if bits == Bits::B32 {
                return Ok(1.0);
            }
            table
                .get(name)
                .and_then(|m| m.get(&bits.bits()))
                .copied()
                .ok_or_else(|| {
                    anyhow::anyhow!("no clip for layer {name} bits {bits}")
                })
        };
        wq.extend(qparams_row(lookup(w_clips, qc.w_bits[i])?, qc.w_bits[i]));
        aq.extend(qparams_row(lookup(a_clips, qc.a_bits[i])?, qc.a_bits[i]));
    }
    Ok((wq, aq))
}

/// Dense precomputed qparam rows: `[layer][bits] -> (Δ, qmin, qmax, en)`.
///
/// The eval hot path used to re-resolve every candidate through two
/// string-keyed nested `BTreeMap` lookups per layer (the test-only
/// `resolve_qparams` oracle); this table folds the calibration clips
/// into ready-made rows ONCE at `Artifacts` load, so per-candidate
/// resolution is O(L) array indexing with no hashing, no string compares
/// and no BTree walks. Rows are bitwise-identical to what
/// `resolve_qparams` produces (both go through `qparams_row`). A `None`
/// entry means the calibration table has no clip for that (layer, bits);
/// resolving through it reports the same error `resolve_qparams` would,
/// at the same (lazy) point.
#[derive(Debug, Clone)]
pub struct QparamTable {
    /// `rows[layer * Bits::COUNT + bits.index()]`, weights then acts.
    w_rows: Vec<Option<[f32; 4]>>,
    a_rows: Vec<Option<[f32; 4]>>,
    /// Layer names kept only for error messages.
    layer_names: Vec<String>,
}

impl QparamTable {
    /// Fold the clip tables into dense rows. Missing clips become `None`
    /// entries (errors stay lazy, matching `resolve_qparams`); B32 rows
    /// are always present — quantization disabled needs no clip.
    pub fn build(layer_names: &[String], w_clips: &ClipTable, a_clips: &ClipTable) -> QparamTable {
        let row_of = |clips: &ClipTable, name: &String, bits: Bits| -> Option<[f32; 4]> {
            if bits == Bits::B32 {
                return Some(qparams_row(1.0, Bits::B32));
            }
            clips
                .get(name)
                .and_then(|m| m.get(&bits.bits()))
                .map(|&clip| qparams_row(clip, bits))
        };
        let mut w_rows = Vec::with_capacity(layer_names.len() * Bits::COUNT);
        let mut a_rows = Vec::with_capacity(layer_names.len() * Bits::COUNT);
        for name in layer_names {
            for bits in [Bits::B2, Bits::B4, Bits::B8, Bits::B16, Bits::B32] {
                w_rows.push(row_of(w_clips, name, bits));
                a_rows.push(row_of(a_clips, name, bits));
            }
        }
        QparamTable { w_rows, a_rows, layer_names: layer_names.to_vec() }
    }

    pub fn num_layers(&self) -> usize {
        self.layer_names.len()
    }

    fn row(rows: &[Option<[f32; 4]>], names: &[String], layer: usize, bits: Bits) -> anyhow::Result<[f32; 4]> {
        rows[layer * Bits::COUNT + bits.index()].ok_or_else(|| {
            anyhow::anyhow!("no clip for layer {} bits {bits}", names[layer])
        })
    }

    /// Append one candidate's (L,4) wq/aq rows to `wq`/`aq` — the packing
    /// primitive shared by single-candidate and batched resolution.
    pub fn resolve_into(
        &self,
        qc: &QuantConfig,
        wq: &mut Vec<f32>,
        aq: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            qc.num_layers() == self.num_layers(),
            "config has {} layers, model has {}",
            qc.num_layers(),
            self.num_layers()
        );
        for i in 0..qc.num_layers() {
            wq.extend(Self::row(&self.w_rows, &self.layer_names, i, qc.w_bits[i])?);
            aq.extend(Self::row(&self.a_rows, &self.layer_names, i, qc.a_bits[i])?);
        }
        Ok(())
    }

    /// Resolve one candidate to its flattened (L,4) wq/aq matrices —
    /// drop-in for `resolve_qparams`, minus the BTreeMap walks.
    pub fn resolve(&self, qc: &QuantConfig) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let mut wq = Vec::with_capacity(qc.num_layers() * 4);
        let mut aq = Vec::with_capacity(qc.num_layers() * 4);
        self.resolve_into(qc, &mut wq, &mut aq)?;
        Ok((wq, aq))
    }

    /// Resolve M candidates into one packed pair of (M, L, 4) row-major
    /// matrices (candidate m occupies `[m*L*4 .. (m+1)*L*4]`): a single
    /// host allocation for a whole evaluation batch.
    pub fn resolve_packed(&self, qcs: &[QuantConfig]) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let stride = self.num_layers() * 4;
        let mut wq = Vec::with_capacity(qcs.len() * stride);
        let mut aq = Vec::with_capacity(qcs.len() * stride);
        for qc in qcs {
            self.resolve_into(qc, &mut wq, &mut aq)?;
        }
        Ok((wq, aq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_prop;
    use crate::util::rng::Rng;

    #[test]
    fn gene_encoding_roundtrip() {
        for b in Bits::SEARCHABLE {
            assert_eq!(Bits::from_gene(b.to_gene()), Some(b));
        }
        assert_eq!(Bits::from_gene(0), None);
        assert_eq!(Bits::from_gene(5), None);
    }

    #[test]
    fn genome_wa_roundtrip_prop() {
        check_prop(
            "genome_wa_roundtrip",
            200,
            |r: &mut Rng| {
                let n = 1 + r.below(12);
                (0..2 * n).map(|_| r.range(1, 4)).collect::<Vec<i64>>()
            },
            |genome| {
                let qc = QuantConfig::from_genome_wa(genome)
                    .ok_or("decode failed".to_string())?;
                if qc.to_genome_wa() == *genome {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".to_string())
                }
            },
        );
    }

    #[test]
    fn tied_genome_ties_wa() {
        let qc = QuantConfig::from_genome_tied(&[1, 2, 3, 4]).unwrap();
        assert_eq!(qc.w_bits, qc.a_bits);
        assert_eq!(qc.w_bits, vec![Bits::B2, Bits::B4, Bits::B8, Bits::B16]);
    }

    #[test]
    fn beacon_distance_matches_paper_metric() {
        // log2 scale: |log2(16)-log2(2)| = 3 per layer.
        let a = QuantConfig::uniform(8, Bits::B16, Bits::B16);
        let b = QuantConfig::uniform(8, Bits::B2, Bits::B16);
        assert_eq!(a.beacon_distance(&b), 24.0);
        // Activations don't contribute.
        let c = QuantConfig::uniform(8, Bits::B16, Bits::B2);
        assert_eq!(a.beacon_distance(&c), 0.0);
    }

    #[test]
    fn beacon_distance_is_metric_prop() {
        let gen_cfg = |r: &mut Rng| {
            QuantConfig::from_genome_tied(
                &(0..8).map(|_| r.range(1, 4)).collect::<Vec<i64>>(),
            )
            .unwrap()
        };
        check_prop(
            "beacon_distance_metric",
            200,
            |r: &mut Rng| (gen_cfg(r), gen_cfg(r), gen_cfg(r)),
            |(a, b, c)| {
                let (dab, dba) = (a.beacon_distance(b), b.beacon_distance(a));
                if (dab - dba).abs() > 1e-12 {
                    return Err("not symmetric".into());
                }
                if a.beacon_distance(a) != 0.0 {
                    return Err("self-distance nonzero".into());
                }
                let (dac, dbc) = (a.beacon_distance(c), b.beacon_distance(c));
                if dac > dab + dbc + 1e-12 {
                    return Err("triangle inequality violated".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn qparams_row_matches_python_formula() {
        // python: qparams_row(clip=2.0, bits=4) == [0.25, -8, 7, 1]
        let row = qparams_row(2.0, Bits::B4);
        assert_eq!(row, [0.25, -8.0, 7.0, 1.0]);
        let row = qparams_row(1.0, Bits::B2);
        assert_eq!(row, [0.5, -2.0, 1.0, 1.0]);
        let row = qparams_row(3.0, Bits::B32);
        assert_eq!(row, [1.0, -1.0, 1.0, 0.0]);
    }

    #[test]
    fn resolve_uses_per_layer_clips() {
        let mut w_clips = ClipTable::new();
        let mut a_clips = ClipTable::new();
        for (i, name) in ["A", "B"].iter().enumerate() {
            let mut m = BTreeMap::new();
            for bits in [2u32, 4, 8, 16] {
                m.insert(bits, 1.0 + i as f64);
            }
            w_clips.insert(name.to_string(), m.clone());
            a_clips.insert(name.to_string(), m);
        }
        let qc = QuantConfig {
            w_bits: vec![Bits::B4, Bits::B8],
            a_bits: vec![Bits::B16, Bits::B2],
        };
        let names = vec!["A".to_string(), "B".to_string()];
        let (wq, aq) = resolve_qparams(&qc, &names, &w_clips, &a_clips).unwrap();
        assert_eq!(wq.len(), 8);
        assert_eq!(wq[0], 1.0 / 8.0); // layer A, 4-bit, clip 1.0
        assert_eq!(wq[4], 2.0 / 128.0); // layer B, 8-bit, clip 2.0
        assert_eq!(aq[1], -32768.0); // layer A act 16-bit qmin
        assert_eq!(aq[6], 1.0); // layer B act 2-bit qmax
    }

    #[test]
    fn resolve_fails_on_missing_layer() {
        let qc = QuantConfig::uniform(1, Bits::B4, Bits::B4);
        let names = vec!["X".to_string()];
        let err = resolve_qparams(&qc, &names, &ClipTable::new(), &ClipTable::new());
        assert!(err.is_err());
    }

    fn clip_fixture(n: usize) -> (Vec<String>, ClipTable, ClipTable) {
        let names: Vec<String> = (0..n).map(|i| format!("L{i}")).collect();
        let table = |scale: f64| -> ClipTable {
            names
                .iter()
                .enumerate()
                .map(|(i, name)| {
                    (
                        name.clone(),
                        [2u32, 4, 8, 16]
                            .iter()
                            .map(|&b| (b, scale + i as f64 * 0.25 + b as f64 * 0.01))
                            .collect(),
                    )
                })
                .collect()
        };
        (names, table(1.0), table(2.0))
    }

    #[test]
    fn dense_table_matches_btreemap_resolution_prop() {
        // The hot path swaps resolve_qparams for the precomputed table;
        // this pins the two bitwise-identical over random genomes
        // (including B32 report rows, which need no clip).
        let (names, w_clips, a_clips) = clip_fixture(8);
        let table = QparamTable::build(&names, &w_clips, &a_clips);
        check_prop(
            "dense_table_matches_resolve",
            200,
            |r: &mut Rng| {
                let pick = |r: &mut Rng| match r.below(5) {
                    0 => Bits::B2,
                    1 => Bits::B4,
                    2 => Bits::B8,
                    3 => Bits::B16,
                    _ => Bits::B32,
                };
                QuantConfig {
                    w_bits: (0..8).map(|_| pick(r)).collect(),
                    a_bits: (0..8).map(|_| pick(r)).collect(),
                }
            },
            |qc| {
                let slow = resolve_qparams(qc, &names, &w_clips, &a_clips)
                    .map_err(|e| e.to_string())?;
                let fast = table.resolve(qc).map_err(|e| e.to_string())?;
                if slow == fast {
                    Ok(())
                } else {
                    Err("table rows diverge from resolve_qparams".into())
                }
            },
        );
    }

    #[test]
    fn packed_resolution_is_per_candidate_concatenation() {
        let (names, w_clips, a_clips) = clip_fixture(4);
        let table = QparamTable::build(&names, &w_clips, &a_clips);
        let qcs = vec![
            QuantConfig::uniform(4, Bits::B2, Bits::B16),
            QuantConfig::uniform(4, Bits::B8, Bits::B4),
            QuantConfig::uniform(4, Bits::B2, Bits::B16),
        ];
        let (wq, aq) = table.resolve_packed(&qcs).unwrap();
        let stride = 4 * 4;
        assert_eq!(wq.len(), 3 * stride);
        for (m, qc) in qcs.iter().enumerate() {
            let (w1, a1) = table.resolve(qc).unwrap();
            assert_eq!(&wq[m * stride..(m + 1) * stride], &w1[..]);
            assert_eq!(&aq[m * stride..(m + 1) * stride], &a1[..]);
        }
    }

    #[test]
    fn dense_table_reports_missing_clips_lazily() {
        // Building from empty tables succeeds (B32 rows need no clip);
        // resolving a searchable precision reports the same error
        // resolve_qparams would.
        let names = vec!["X".to_string()];
        let table = QparamTable::build(&names, &ClipTable::new(), &ClipTable::new());
        assert!(table.resolve(&QuantConfig::uniform(1, Bits::B32, Bits::B32)).is_ok());
        let err = table.resolve(&QuantConfig::uniform(1, Bits::B4, Bits::B4)).unwrap_err();
        assert!(err.to_string().contains("no clip for layer X"), "{err}");
        // Layer-count mismatch is caught up front.
        assert!(table.resolve(&QuantConfig::uniform(2, Bits::B4, Bits::B4)).is_err());
    }
}
