//! Candidate-solution evaluation service: the error objective.
//!
//! Wraps the AOT inference executable. A candidate (QuantConfig) is
//! resolved through the dense precomputed [`crate::quant::QparamTable`]
//! into runtime (Δ,qmin,qmax,en) rows, then the executable runs over the
//! validation subsets; the error objective is the MAX subset error (paper
//! §4.2's variance-reduction trick). Results are memoized per
//! (parameter-set, genome) — NSGA-II revisits genomes often with pop 10 x
//! 60 generations.
//!
//! The hot path is BATCHED: [`EvalService::val_error_batch`] scores M
//! candidates with one cache round trip, one packed (M, L, 4) qparam
//! resolution, and (on PJRT) one wq/aq upload per unique candidate over
//! data batches that were uploaded once at construction. Per-candidate
//! [`EvalService::val_error`] remains and is bitwise-identical.
//!
//! The service is `Send + Sync`: the result cache, execution counters and
//! parameter-set table all use interior mutability, so one instance can
//! score candidates from every worker of the coordinator's thread pool
//! concurrently (the `SearchSession` dedupes in-flight genomes, keeping
//! execution counts thread-count-independent).
//!
//! Parameter sets: index 0 is the baseline pre-trained model; beacon
//! retraining registers additional sets (paper §4.3). All sets stay
//! resident on the PJRT device so per-eval upload cost is only the quant
//! params + data batch.
//!
//! Two engines share this surface:
//!   * [`EvalService::new`] — the PJRT path over the AOT executable;
//!   * [`EvalService::surrogate`] — a hermetic closed-form error model
//!     (no runtime, no artifacts on disk) with the same cache, counters
//!     and determinism contract. Serve mode and CI fall back to it when
//!     no bundle is present, so the full search/serve stack exercises
//!     end to end offline.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::params::{LocalParamStore, ParamStore, ParamUploader, ReplicatedParamStore};
use crate::quant::{Bits, QuantConfig};
use crate::runtime::{scalar_f32, Artifacts, DeviceTensor, Executor, Input, Runtime, Split};

// The parameter-set table itself lives in `crate::params` now; the old
// `crate::eval::ParamSet` path keeps working.
pub use crate::params::ParamSet;

/// Memo key for one (parameter set, genome) pair.
///
/// The hot variant packs each gene into 2 bits (4 searchable precisions)
/// behind a length-marker bit — one `u64` per side — so building a key
/// costs ZERO heap allocations. The previous key type,
/// `(usize, Vec<Bits>, Vec<Bits>)`, cloned both gene vectors on EVERY
/// lookup, cache hit or not. B32 genes (report-table rows, never searched)
/// and models beyond 31 layers don't fit 2 bits/gene in a u64; they take
/// the allocating wide fallback, so correctness never depends on packing.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum CacheKey {
    Packed(usize, u64, u64),
    Wide(usize, Vec<Bits>, Vec<Bits>),
}

impl CacheKey {
    pub fn new(set: usize, qc: &QuantConfig) -> CacheKey {
        match (pack_genes(&qc.w_bits), pack_genes(&qc.a_bits)) {
            (Some(w), Some(a)) => CacheKey::Packed(set, w, a),
            _ => CacheKey::Wide(set, qc.w_bits.clone(), qc.a_bits.clone()),
        }
    }

    /// The parameter-set index this key scores against (eviction hooks
    /// purge a whole set's entries by matching on this).
    pub fn set(&self) -> usize {
        match self {
            CacheKey::Packed(s, _, _) | CacheKey::Wide(s, _, _) => *s,
        }
    }
}

/// 2 bits per searchable gene, shifted in under a leading marker bit
/// ([B2] -> 0b1_00, [B2,B2] -> 0b1_00_00): genomes of different lengths
/// can never collide. `None` when the genome doesn't fit (B32 gene or
/// more than 31 layers) — callers fall back to `CacheKey::Wide`.
fn pack_genes(bits: &[Bits]) -> Option<u64> {
    if bits.len() > 31 {
        return None;
    }
    let mut packed: u64 = 1;
    for b in bits {
        let code = match b {
            Bits::B2 => 0u64,
            Bits::B4 => 1,
            Bits::B8 => 2,
            Bits::B16 => 3,
            Bits::B32 => return None,
        };
        packed = (packed << 2) | code;
    }
    Some(packed)
}

/// Default memo bound: ~1M entries. A `(CacheKey, f64)` pair is tens of
/// bytes, so the default caps the memo at tens of MB — far above any
/// single search (pop x generations ~ 10^3..10^4 uniques) but finite for
/// a months-long serve process absorbing unbounded tenants.
pub const DEFAULT_CACHE_CAP: usize = 1 << 20;

/// The two-generation memo state behind the lock: `hot` takes inserts
/// and promotions, `cold` holds the previous generation. When `hot`
/// reaches half the cap, `cold` is discarded (those entries were not
/// touched for a full generation) and `hot` rotates into its place — an
/// O(1)-amortized LRU approximation with no per-entry bookkeeping.
struct CacheInner<K, V> {
    hot: HashMap<K, V>,
    cold: HashMap<K, V>,
    /// Target bound on total resident entries (hot + cold).
    cap: usize,
    /// Entries discarded by rotation or purges, cumulative.
    evictions: usize,
}

impl<K: std::hash::Hash + Eq, V> CacheInner<K, V> {
    /// Rotate once `hot` fills its half of the budget. Each generation
    /// holds at most `max(1, cap/2)` entries, so residency never exceeds
    /// `cap` (+1 transiently during an insert).
    fn maybe_rotate(&mut self) {
        if self.hot.len() >= (self.cap / 2).max(1) {
            self.evictions += self.cold.len();
            self.cold = std::mem::take(&mut self.hot);
        }
    }
}

/// Shared bounded memo map behind a poison-aware mutex. A worker that
/// panics while holding the lock poisons it; every later access returns
/// a typed error (carrying the "poisoned" marker `SearchSession` maps to
/// `SearchError::Poisoned`) instead of raising a second panic inside the
/// worker pool.
///
/// Residency is bounded by a configurable cap (default
/// [`DEFAULT_CACHE_CAP`]) with two-generation rotation: entries that go
/// a full generation without being read are discarded. Lookups promote
/// cold hits, so the working set of a live search never rotates out
/// mid-run.
pub struct ResultCache<K, V> {
    inner: Mutex<CacheInner<K, V>>,
}

impl<K: std::hash::Hash + Eq, V: Clone> ResultCache<K, V> {
    pub fn new() -> ResultCache<K, V> {
        ResultCache::with_capacity(DEFAULT_CACHE_CAP)
    }

    pub fn with_capacity(cap: usize) -> ResultCache<K, V> {
        ResultCache {
            inner: Mutex::new(CacheInner {
                hot: HashMap::new(),
                cold: HashMap::new(),
                cap: cap.max(1),
                evictions: 0,
            }),
        }
    }

    fn guard(&self) -> Result<std::sync::MutexGuard<'_, CacheInner<K, V>>> {
        self.inner.lock().map_err(|_| {
            anyhow::anyhow!("eval cache poisoned: a worker panicked while holding the lock")
        })
    }

    /// Change the residency bound. Shrinking takes effect lazily, at the
    /// next rotation — no eager mass eviction on the caller's thread.
    pub fn set_capacity(&self, cap: usize) -> Result<()> {
        self.guard()?.cap = cap.max(1);
        Ok(())
    }

    pub fn get(&self, key: &K) -> Result<Option<V>> {
        let mut g = self.guard()?;
        if let Some(v) = g.hot.get(key) {
            return Ok(Some(v.clone()));
        }
        // Promote cold hits so a live working set survives rotation.
        if let Some((k, v)) = g.cold.remove_entry(key) {
            let out = v.clone();
            g.hot.insert(k, v);
            g.maybe_rotate();
            return Ok(Some(out));
        }
        Ok(None)
    }

    pub fn insert(&self, key: K, value: V) -> Result<()> {
        let mut g = self.guard()?;
        g.cold.remove(&key);
        g.hot.insert(key, value);
        g.maybe_rotate();
        Ok(())
    }

    /// Bulk lookup: one lock acquisition for a whole evaluation batch
    /// (the per-candidate path pays one per genome). Results line up with
    /// `keys` by index.
    pub fn get_many(&self, keys: &[K]) -> Result<Vec<Option<V>>> {
        let mut g = self.guard()?;
        let mut out = Vec::with_capacity(keys.len());
        for key in keys {
            if let Some(v) = g.hot.get(key) {
                out.push(Some(v.clone()));
            } else if let Some((k, v)) = g.cold.remove_entry(key) {
                out.push(Some(v.clone()));
                g.hot.insert(k, v);
                g.maybe_rotate();
            } else {
                out.push(None);
            }
        }
        Ok(out)
    }

    /// Bulk insert under a single lock acquisition.
    pub fn insert_many(&self, entries: Vec<(K, V)>) -> Result<()> {
        let mut g = self.guard()?;
        for (k, v) in entries {
            g.cold.remove(&k);
            g.hot.insert(k, v);
            g.maybe_rotate();
        }
        Ok(())
    }

    /// Drop every entry whose key fails the predicate (eviction hooks
    /// purge a retired parameter set's entries this way). Removed entries
    /// count as evictions.
    pub fn retain(&self, mut keep: impl FnMut(&K) -> bool) -> Result<()> {
        let mut g = self.guard()?;
        let before = g.hot.len() + g.cold.len();
        g.hot.retain(|k, _| keep(k));
        g.cold.retain(|k, _| keep(k));
        g.evictions += before - (g.hot.len() + g.cold.len());
        Ok(())
    }

    /// Entries discarded so far (rotation + purges), or `None` when the
    /// lock is poisoned.
    pub fn evictions(&self) -> Option<usize> {
        self.inner.lock().map(|g| g.evictions).ok()
    }

    /// Resident entry count, or `None` when the lock is poisoned.
    /// Reporting `Some(0)` for a poisoned cache made post-incident
    /// `EvalStats` lie ("0 unique solutions" after thousands of
    /// evaluations); the marker lets stats carry the poisoning
    /// explicitly.
    pub fn len(&self) -> Option<usize> {
        self.inner.lock().map(|g| g.hot.len() + g.cold.len()).ok()
    }

    /// Whether a worker panicked while holding the lock.
    pub fn poisoned(&self) -> bool {
        self.inner.is_poisoned()
    }

    /// Snapshot every resident entry (hot + cold) under one lock
    /// acquisition — the eval-store export path. Order is unspecified
    /// (HashMap iteration); durable formats must sort their serialized
    /// form themselves.
    pub fn entries(&self) -> Result<Vec<(K, V)>>
    where
        K: Clone,
    {
        let g = self.guard()?;
        let mut out = Vec::with_capacity(g.hot.len() + g.cold.len());
        out.extend(g.hot.iter().map(|(k, v)| (k.clone(), v.clone())));
        out.extend(g.cold.iter().map(|(k, v)| (k.clone(), v.clone())));
        Ok(out)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == Some(0)
    }

    /// Poison the lock by panicking while holding it — the regression
    /// hook for the typed `SearchError::Poisoned` path. Test-only; the
    /// panic it catches is confined to this call.
    #[doc(hidden)]
    pub fn poison_for_test(&self) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = self.inner.lock();
            panic!("poisoning eval cache");
        }));
    }
}

impl<K: std::hash::Hash + Eq, V: Clone> Default for ResultCache<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// Cumulative service counters. With a shared service (serve mode, session
/// reuse) these are CROSS-REQUEST totals; `SearchOutcome` reports per-run
/// deltas next to a snapshot of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalStats {
    pub executions: usize,
    pub cache_hits: usize,
    /// Distinct (param-set, genome) keys memoized and still resident;
    /// 0 while `poisoned`.
    pub unique_solutions: usize,
    /// Memo entries discarded so far (capacity rotation + param-set
    /// purges); 0 while `poisoned`.
    pub evictions: usize,
    /// Parameter sets retired through `EvalService::evict_param_set`.
    pub param_sets_evicted: usize,
    /// True when the result cache was poisoned by a worker panic —
    /// `unique_solutions` can no longer be trusted (post-incident stats
    /// must not silently read as "empty cache").
    pub poisoned: bool,
}

/// How candidate errors are produced.
enum Engine {
    /// The AOT inference executable on a PJRT client. Every (x, y) batch
    /// of every validation subset and the test split is uploaded ONCE at
    /// service construction and stays device-resident — per-candidate
    /// evaluation moves only the (L,4) qparam rows across the host
    /// boundary (and batched evaluation amortizes even that packing).
    Pjrt {
        /// Shared with the param store's uploader (registered sets become
        /// device-resident through the same executor).
        exec: Arc<Executor>,
        /// `val_data[subset][batch]` = pre-uploaded (x, y) device pair.
        val_data: Vec<Vec<(DeviceTensor, DeviceTensor)>>,
        test_data: Vec<(DeviceTensor, DeviceTensor)>,
    },
    /// Hermetic closed-form error model (see `surrogate_val_error`).
    Surrogate,
}

impl Engine {
    /// Build the PJRT engine: compile nothing (the executor is handed in
    /// compiled), upload every data batch once.
    fn pjrt(exec: Executor, arts: &Artifacts) -> Result<Engine> {
        let exec = Arc::new(exec);
        let (b, t, f) = (arts.batch, arts.seq_len, arts.feat_dim);
        let upload_split = |split: &Split| -> Result<Vec<(DeviceTensor, DeviceTensor)>> {
            (0..split.num_batches(b))
                .map(|k| {
                    let (x, y) = split.batch(k, b, t, f);
                    Ok((
                        exec.upload(&Input::F32(x, vec![b as i64, t as i64, f as i64]))?,
                        exec.upload(&Input::I32(y, vec![b as i64, t as i64]))?,
                    ))
                })
                .collect()
        };
        let val_data =
            arts.val_subsets.iter().map(upload_split).collect::<Result<Vec<_>>>()?;
        let test_data = upload_split(&arts.test)?;
        Ok(Engine::Pjrt { exec, val_data, test_data })
    }
}

pub struct EvalService {
    pub arts: Arc<Artifacts>,
    engine: Engine,
    /// The parameter-set table (`crate::params`). Behind the trait so
    /// the same service runs over the plain local table or a replicated
    /// one (fleet workers) without the evaluation paths knowing.
    params: Arc<dyn ParamStore>,
    cache: ResultCache<CacheKey, f64>,
    executions: AtomicUsize,
    cache_hits: AtomicUsize,
    param_sets_evicted: AtomicUsize,
}

impl EvalService {
    pub fn new(rt: &Runtime, arts: Arc<Artifacts>) -> Result<EvalService> {
        // Two lowerings of the SAME computation exist in the bundle:
        // `infer` (Pallas kernels, the TPU-shaped artifact) and
        // `infer_ref` (XLA-native ops). pytest proves them numerically
        // equivalent; on CPU PJRT the native lowering is ~4.6x faster
        // (EXPERIMENTS.md §Perf L2), so it is the default here.
        // MOHAQ_INFER_GRAPH=pallas forces the kernel graph.
        let which = match std::env::var("MOHAQ_INFER_GRAPH").as_deref() {
            Ok("pallas") => "infer",
            Ok("ref") => "infer_ref",
            _ => "infer_ref",
        };
        let exec = rt.load(arts.hlo_path(which).or_else(|_| arts.hlo_path("infer"))?)?;
        let engine = Engine::pjrt(exec, &arts)?;
        EvalService::with_engine(arts, engine)
    }

    /// Hermetic engine: candidate errors come from a deterministic
    /// closed-form model of PTQ degradation instead of the AOT executable
    /// (no PJRT, no files). Same cache, counters, and `Send + Sync`
    /// contract — the search and serve stacks cannot tell the difference,
    /// which is exactly what lets CI drive them end to end offline.
    pub fn surrogate(arts: Arc<Artifacts>) -> Result<EvalService> {
        EvalService::with_engine(arts, Engine::Surrogate)
    }

    /// Hermetic surrogate service whose parameter sets live behind a
    /// [`ReplicatedParamStore`] authority — the dependency-injection
    /// hook the store-equivalence property tests and the replicated
    /// session path use. Same contract as [`EvalService::surrogate`].
    pub fn surrogate_replicated(arts: Arc<Artifacts>) -> Result<EvalService> {
        EvalService::with_store(arts, Engine::Surrogate, |up| {
            Arc::new(ReplicatedParamStore::authority(Arc::new(LocalParamStore::new(up))))
        })
    }

    fn with_engine(arts: Arc<Artifacts>, engine: Engine) -> Result<EvalService> {
        EvalService::with_store(arts, engine, |up| Arc::new(LocalParamStore::new(up)))
    }

    /// Construct over a caller-chosen store. The store receives this
    /// engine's device uploader (PJRT engines keep every registered set
    /// device-resident; surrogates need none), then the baseline set is
    /// registered as id 0 — every engine/store combination starts from
    /// the same table.
    fn with_store(
        arts: Arc<Artifacts>,
        engine: Engine,
        make_store: impl FnOnce(Option<ParamUploader>) -> Arc<dyn ParamStore>,
    ) -> Result<EvalService> {
        let uploader = match &engine {
            Engine::Pjrt { exec, .. } => {
                Some(device_uploader(exec.clone(), arts.clone()))
            }
            Engine::Surrogate => None,
        };
        let svc = EvalService {
            arts: arts.clone(),
            engine,
            params: make_store(uploader),
            cache: ResultCache::new(),
            executions: AtomicUsize::new(0),
            cache_hits: AtomicUsize::new(0),
            param_sets_evicted: AtomicUsize::new(0),
        };
        let baseline = arts.weights.clone();
        svc.add_param_set("baseline", baseline)?;
        Ok(svc)
    }

    /// Whether this service evaluates through the hermetic surrogate.
    pub fn is_surrogate(&self) -> bool {
        matches!(self.engine, Engine::Surrogate)
    }

    /// The parameter-set table this service evaluates against. The
    /// beacon finalize path registers sets through this, and the fleet
    /// wraps it in replica/authority roles (`crate::params`).
    pub fn param_store(&self) -> Arc<dyn ParamStore> {
        self.params.clone()
    }

    /// Register a parameter set (e.g. a retrained beacon); returns its id.
    pub fn add_param_set(&self, name: &str, host: Vec<Vec<f32>>) -> Result<usize> {
        anyhow::ensure!(
            host.len() == self.arts.tensors.len(),
            "param set has {} tensors, artifact expects {}",
            host.len(),
            self.arts.tensors.len()
        );
        self.params.add(name, host)
    }

    /// Retire a beacon parameter set: free its host and device memory
    /// (tombstoning the slot so later sets keep their indices) and purge
    /// its memoized results. Index 0 — the baseline every search scores
    /// against — is not evictable. Evaluating against a retired set is a
    /// typed error, so callers must only retire sets whose searches have
    /// fully reported (the serve opt-in does this after rows are built).
    /// Eviction goes through the service (never the raw store): the memo
    /// purge and the eviction counter live here, next to the cache.
    pub fn evict_param_set(&self, idx: usize) -> Result<()> {
        if self.params.evict(idx)? {
            self.cache.retain(|k| k.set() != idx)?;
            self.param_sets_evicted.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    pub fn param_set(&self, idx: usize) -> Result<Arc<ParamSet>> {
        self.params.get(idx)
    }

    /// Bound the result memo (entries, not bytes); see
    /// [`ResultCache::set_capacity`].
    pub fn set_cache_capacity(&self, cap: usize) -> Result<()> {
        self.cache.set_capacity(cap)
    }

    pub fn num_param_sets(&self) -> Result<usize> {
        self.params.len()
    }

    /// Poison the parameter-set lock by panicking while holding it — the
    /// regression hook mirroring `ResultCache::poison_for_test`.
    #[doc(hidden)]
    pub fn poison_param_sets_for_test(&self) {
        self.params.poison_for_test();
    }

    /// Snapshot the resident memo — the eval-store export path. One lock
    /// acquisition; order is unspecified (the store sorts its serialized
    /// form for file determinism).
    pub fn export_entries(&self) -> Result<Vec<(CacheKey, f64)>> {
        self.cache.entries()
    }

    /// Bulk-load memo entries — the eval-store import path. The
    /// configured capacity still bounds residency through normal
    /// rotation, so a store larger than `--cache-cap` cannot blow the
    /// budget.
    pub fn import_entries(&self, entries: Vec<(CacheKey, f64)>) -> Result<()> {
        self.cache.insert_many(entries)
    }

    /// Live (non-evicted) parameter sets with their indices, ascending —
    /// the eval-store export path. Index 0 (the baseline) is included;
    /// the store skips persisting its tensors and re-derives it from the
    /// artifacts on load.
    pub fn snapshot_param_sets(&self) -> Result<Vec<(usize, Arc<ParamSet>)>> {
        self.params.snapshot()
    }

    pub fn stats(&self) -> EvalStats {
        EvalStats {
            executions: self.executions.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            unique_solutions: self.cache.len().unwrap_or(0),
            evictions: self.cache.evictions().unwrap_or(0),
            param_sets_evicted: self.param_sets_evicted.load(Ordering::Relaxed),
            poisoned: self.cache.poisoned(),
        }
    }

    /// Deterministic closed-form PTQ error for the surrogate engine.
    ///
    /// Shaped after the empirical behavior of the real pipeline: the error
    /// starts at the 16-bit baseline and each layer adds a penalty that
    /// shrinks quadratically with precision (quantization MSE ~ 2^-2b),
    /// weighted by the layer's share of the model size. Weight precision
    /// dominates; activations contribute ~30%. A small FNV-hash term keyed
    /// by (set, genome) breaks ties so fronts stay diverse. Pure function
    /// of its inputs — bitwise identical across runs, threads, platforms.
    fn surrogate_val_error(&self, qc: &QuantConfig, set: usize) -> f64 {
        let model = &self.arts.model;
        let total_bits = model.baseline_size_bits() as f64;
        let penalty = |b: Bits| -> f64 {
            match b {
                Bits::B2 => 0.50,
                Bits::B4 => 0.12,
                Bits::B8 => 0.02,
                Bits::B16 => 0.002,
                Bits::B32 => 0.0,
            }
        };
        let mut err = self.arts.baseline.val_err_16bit;
        for (i, (wb, ab)) in qc.w_bits.iter().zip(&qc.a_bits).enumerate() {
            let frac = model.layers[i].matrix_weights() as f64 * 32.0 / total_bits;
            err += frac * (penalty(*wb) + 0.3 * penalty(*ab));
        }
        // FNV-1a over (set, genes): deterministic jitter in [0, 0.002).
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        };
        mix(set as u64);
        for (wb, ab) in qc.w_bits.iter().zip(&qc.a_bits) {
            mix(wb.bits() as u64);
            mix(ab.bits() as u64 + 97);
        }
        err + (h % 1000) as f64 * 2.0e-6
    }

    /// Surrogate "execution" for one split: errors from the closed-form
    /// model (counted so cache-hit accounting and the stats surface behave
    /// identically to the PJRT path).
    fn surrogate_run(&self, qc: &QuantConfig, set: usize, num_seqs: usize) -> (f64, f64, f64) {
        self.executions.fetch_add(1, Ordering::Relaxed);
        let err = self.surrogate_val_error(qc, set);
        let total = num_seqs.max(1) as f64;
        (err * total, total, err * 3.0)
    }

    /// Upload one candidate's already-resolved (L,4) wq/aq rows.
    fn upload_qparams(
        &self,
        exec: &Executor,
        wq: &[f32],
        aq: &[f32],
    ) -> Result<(DeviceTensor, DeviceTensor)> {
        let l = self.arts.layer_names.len() as i64;
        Ok((
            exec.upload(&Input::F32(wq, vec![l, 4]))?,
            exec.upload(&Input::F32(aq, vec![l, 4]))?,
        ))
    }

    /// (err_count, total, loss_sum) over a split's pre-uploaded batches —
    /// every input (params, qparams, data) is device-resident, so the only
    /// host traffic per execution is the three scalar outputs.
    fn pjrt_run(
        &self,
        exec: &Executor,
        qp: &(DeviceTensor, DeviceTensor),
        set: usize,
        data: &[(DeviceTensor, DeviceTensor)],
    ) -> Result<(f64, f64, f64)> {
        // Arc clone only — the lock is NOT held across executions, so
        // beacon registrations from the sequential phase never contend
        // with in-flight parallel evaluations.
        let params = self.param_set(set)?;
        let (mut err, mut total, mut loss) = (0.0, 0.0, 0.0);
        for (x, y) in data {
            let mut bufs: Vec<&DeviceTensor> =
                Vec::with_capacity(params.device_bufs().len() + 4);
            bufs.extend(params.device_bufs().iter());
            bufs.extend([&qp.0, &qp.1, x, y]);
            let out = exec
                .run_device(&bufs)
                .with_context(|| format!("infer exec, set {set}"))?;
            err += scalar_f32(&out[0])? as f64;
            total += scalar_f32(&out[1])? as f64;
            loss += scalar_f32(&out[2])? as f64;
            self.executions.fetch_add(1, Ordering::Relaxed);
        }
        Ok((err, total, loss))
    }

    /// Worst-subset error for one candidate, no cache involved — the
    /// shared kernel of `val_error` and `val_error_batch` (the batch path
    /// MUST be bitwise-identical to the sequential one, so both funnel
    /// every miss through this).
    fn uncached_val_error(
        &self,
        qc: &QuantConfig,
        set: usize,
        qp: Option<&(DeviceTensor, DeviceTensor)>,
    ) -> Result<f64> {
        match &self.engine {
            Engine::Surrogate => {
                let mut worst: f64 = 0.0;
                for split in &self.arts.val_subsets {
                    let (e, t, _) = self.surrogate_run(qc, set, split.num_seqs);
                    worst = worst.max(e / t.max(1.0));
                }
                Ok(worst)
            }
            Engine::Pjrt { exec, val_data, .. } => {
                let owned;
                let qp = match qp {
                    Some(qp) => qp,
                    None => {
                        let (wq, aq) = self.arts.qtable.resolve(qc)?;
                        owned = self.upload_qparams(exec, &wq, &aq)?;
                        &owned
                    }
                };
                let mut worst: f64 = 0.0;
                for data in val_data {
                    let (e, t, _) = self.pjrt_run(exec, qp, set, data)?;
                    worst = worst.max(e / t.max(1.0));
                }
                Ok(worst)
            }
        }
    }

    /// Validation error = max over the subsets (paper §4.2). Cached. A
    /// poisoned cache lock surfaces as an `Err` (not a panic), so worker
    /// threads fail cleanly and `SearchSession` can report
    /// `SearchError::Poisoned`.
    pub fn val_error(&self, qc: &QuantConfig, set: usize) -> Result<f64> {
        let key = CacheKey::new(set, qc);
        if let Some(v) = self.cache.get(&key)? {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(v);
        }
        let worst = self.uncached_val_error(qc, set, None)?;
        self.cache.insert(key, worst)?;
        Ok(worst)
    }

    /// Batched [`val_error`]: evaluate M candidates against one parameter
    /// set with the per-candidate overheads amortized across the batch —
    /// ONE cache lock round trip for all lookups (and one for all
    /// inserts), one packed (M, L, 4) host resolution of every miss's
    /// qparam rows, and on the PJRT engine one wq/aq upload per unique
    /// candidate per batch (the data batches are already device-resident).
    ///
    /// Contract: returns exactly what per-candidate `val_error` calls in
    /// input order would return, bitwise, with the same execution and
    /// cache-hit counter movement — duplicates are evaluated once and
    /// count as hits from their second occurrence on, just as the
    /// sequential path memoizes them.
    pub fn val_error_batch(&self, qcs: &[QuantConfig], set: usize) -> Result<Vec<f64>> {
        if qcs.is_empty() {
            return Ok(Vec::new());
        }
        let keys: Vec<CacheKey> = qcs.iter().map(|qc| CacheKey::new(set, qc)).collect();
        let mut out = self.cache.get_many(&keys)?;
        let mut hits = out.iter().filter(|v| v.is_some()).count();
        // Unique misses in first-occurrence order; in-batch duplicates hit
        // the first occurrence's (pending) result.
        let mut first_of: HashMap<&CacheKey, usize> = HashMap::new();
        let mut miss: Vec<usize> = Vec::new();
        for (i, key) in keys.iter().enumerate() {
            if out[i].is_some() {
                continue;
            }
            if first_of.contains_key(key) {
                hits += 1;
            } else {
                first_of.insert(key, miss.len());
                miss.push(i);
            }
        }
        if hits > 0 {
            self.cache_hits.fetch_add(hits, Ordering::Relaxed);
        }
        if !miss.is_empty() {
            let miss_errs: Vec<f64> = match &self.engine {
                Engine::Surrogate => miss
                    .iter()
                    .map(|&i| self.uncached_val_error(&qcs[i], set, None))
                    .collect::<Result<_>>()?,
                Engine::Pjrt { exec, .. } => {
                    // Pack every miss's (Δ,qmin,qmax,en) rows into one
                    // (M, L, 4) host matrix, then upload candidate slices.
                    let stride = self.arts.layer_names.len() * 4;
                    let mut wq_all = Vec::with_capacity(miss.len() * stride);
                    let mut aq_all = Vec::with_capacity(miss.len() * stride);
                    for &i in &miss {
                        self.arts.qtable.resolve_into(&qcs[i], &mut wq_all, &mut aq_all)?;
                    }
                    let mut errs = Vec::with_capacity(miss.len());
                    for (m, &i) in miss.iter().enumerate() {
                        let rows = m * stride..(m + 1) * stride;
                        let qp =
                            self.upload_qparams(exec, &wq_all[rows.clone()], &aq_all[rows])?;
                        errs.push(self.uncached_val_error(&qcs[i], set, Some(&qp))?);
                    }
                    errs
                }
            };
            let mut entries = Vec::with_capacity(miss.len());
            for (m, &i) in miss.iter().enumerate() {
                out[i] = Some(miss_errs[m]);
                entries.push((keys[i].clone(), miss_errs[m]));
            }
            self.cache.insert_many(entries)?;
            // Duplicate misses take their first occurrence's value.
            for (i, key) in keys.iter().enumerate() {
                if out[i].is_none() {
                    out[i] = Some(miss_errs[first_of[key]]);
                }
            }
        }
        Ok(out.into_iter().map(|v| v.expect("every slot resolved")).collect())
    }

    /// Test-set error (final report column WER_T). Uncached — called once
    /// per Pareto solution.
    pub fn test_error(&self, qc: &QuantConfig, set: usize) -> Result<f64> {
        let (e, t, _) = match &self.engine {
            Engine::Surrogate => self.surrogate_run(qc, set, self.arts.test.num_seqs),
            Engine::Pjrt { exec, test_data, .. } => {
                let (wq, aq) = self.arts.qtable.resolve(qc)?;
                let qp = self.upload_qparams(exec, &wq, &aq)?;
                self.pjrt_run(exec, &qp, set, test_data)?
            }
        };
        Ok(e / t.max(1.0))
    }

    /// Mean validation loss (beacon diagnostics).
    pub fn val_loss(&self, qc: &QuantConfig, set: usize) -> Result<f64> {
        let mut sum = 0.0;
        let mut n = 0usize;
        match &self.engine {
            Engine::Surrogate => {
                for split in &self.arts.val_subsets {
                    let (_, _, l) = self.surrogate_run(qc, set, split.num_seqs);
                    n += split.num_batches(self.arts.batch);
                    sum += l;
                }
            }
            Engine::Pjrt { exec, val_data, .. } => {
                let (wq, aq) = self.arts.qtable.resolve(qc)?;
                let qp = self.upload_qparams(exec, &wq, &aq)?;
                for (split, data) in self.arts.val_subsets.iter().zip(val_data) {
                    let (_, _, l) = self.pjrt_run(exec, &qp, set, data)?;
                    n += split.num_batches(self.arts.batch);
                    sum += l;
                }
            }
        }
        Ok(sum / n.max(1) as f64)
    }
}

/// The store-held uploader for PJRT engines: registered sets (baseline,
/// beacons, replicated pushes) become device-resident through the same
/// executor evaluation runs on. Scalars/1-D keep their manifest shape.
fn device_uploader(exec: Arc<Executor>, arts: Arc<Artifacts>) -> ParamUploader {
    Box::new(move |host: &[Vec<f32>]| {
        let mut bufs = Vec::with_capacity(host.len());
        for (data, info) in host.iter().zip(&arts.tensors) {
            let shape: Vec<i64> = info.shape.iter().map(|&d| d as i64).collect();
            bufs.push(exec.upload(&Input::F32(data, shape))?);
        }
        Ok(bufs)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<Arc<Artifacts>> {
        let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let p = PathBuf::from(dir);
        if !p.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts present");
            return None;
        }
        Some(Arc::new(Artifacts::load(p).unwrap()))
    }

    #[test]
    fn service_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<EvalService>();
    }

    #[test]
    fn packed_cache_keys_are_injective_over_searchable_genomes() {
        use crate::util::prop::check_prop;
        use crate::util::rng::Rng;
        // Two random searchable genomes (any length up to 31) collide iff
        // they are equal — the 2-bit packing plus length marker is
        // injective, so the packed key can replace the allocating one.
        let gen_cfg = |r: &mut Rng| {
            let n = 1 + r.below(31);
            QuantConfig {
                w_bits: (0..n).map(|_| *r.choose(&Bits::SEARCHABLE)).collect(),
                a_bits: (0..n).map(|_| *r.choose(&Bits::SEARCHABLE)).collect(),
            }
        };
        check_prop(
            "packed_cache_key_injective",
            500,
            |r: &mut Rng| (gen_cfg(r), gen_cfg(r)),
            |(a, b)| {
                let (ka, kb) = (CacheKey::new(0, a), CacheKey::new(0, b));
                if !matches!(ka, CacheKey::Packed(..)) {
                    return Err("searchable genome should pack".into());
                }
                if (ka == kb) != (a == b) {
                    return Err(format!("collision: {a:?} vs {b:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cache_key_falls_back_to_wide_when_unpackable() {
        // B32 (report rows) and >31 layers can't take 2 bits/gene; the
        // wide variant keeps them correct instead of colliding.
        let b32 = QuantConfig::uniform(4, Bits::B32, Bits::B32);
        assert!(matches!(CacheKey::new(0, &b32), CacheKey::Wide(..)));
        let long = QuantConfig::uniform(32, Bits::B2, Bits::B2);
        assert!(matches!(CacheKey::new(0, &long), CacheKey::Wide(..)));
        // Distinct sets key distinct entries; same qc+set keys are equal.
        let qc = QuantConfig::uniform(8, Bits::B4, Bits::B8);
        assert_eq!(CacheKey::new(1, &qc), CacheKey::new(1, &qc));
        assert_ne!(CacheKey::new(0, &qc), CacheKey::new(1, &qc));
        // Different lengths never collide (the marker bit).
        let one = QuantConfig::uniform(1, Bits::B2, Bits::B2);
        let two = QuantConfig::uniform(2, Bits::B2, Bits::B2);
        assert_ne!(CacheKey::new(0, &one), CacheKey::new(0, &two));
    }

    #[test]
    fn result_cache_bulk_ops_match_singles() {
        let cache: ResultCache<u32, f64> = ResultCache::new();
        cache.insert_many(vec![(1, 0.1), (2, 0.2)]).unwrap();
        assert_eq!(
            cache.get_many(&[2, 3, 1]).unwrap(),
            vec![Some(0.2), None, Some(0.1)]
        );
        assert_eq!(cache.get(&1).unwrap(), Some(0.1));
        cache.poison_for_test();
        assert!(cache.get_many(&[1]).is_err());
        assert!(cache.insert_many(vec![(4, 0.4)]).is_err());
    }

    // (`poisoned_param_sets_surface_typed_errors_not_panics` and
    // `evicting_a_param_set_frees_it_and_purges_its_memos` moved to
    // `crate::params::tests` with the store extraction.)

    #[test]
    fn val_error_batch_matches_sequential_on_surrogate() {
        let arts = Arc::new(Artifacts::synthetic());
        let n = arts.layer_names.len();
        let qcs = vec![
            QuantConfig::uniform(n, Bits::B2, Bits::B8),
            QuantConfig::uniform(n, Bits::B16, Bits::B4),
            QuantConfig::uniform(n, Bits::B2, Bits::B8), // in-batch duplicate
            QuantConfig::uniform(n, Bits::B32, Bits::B32), // wide-key row
        ];
        let seq_svc = EvalService::surrogate(arts.clone()).unwrap();
        let seq: Vec<f64> =
            qcs.iter().map(|qc| seq_svc.val_error(qc, 0).unwrap()).collect();
        let batch_svc = EvalService::surrogate(arts.clone()).unwrap();
        let batch = batch_svc.val_error_batch(&qcs, 0).unwrap();
        for (s, b) in seq.iter().zip(&batch) {
            assert_eq!(s.to_bits(), b.to_bits());
        }
        // Same counter movement: duplicates count as hits, uniques as
        // executions — the determinism contract callers rely on.
        assert_eq!(seq_svc.stats().executions, batch_svc.stats().executions);
        assert_eq!(seq_svc.stats().cache_hits, batch_svc.stats().cache_hits);
        // Batch results are memoized: a second batched call is pure hits.
        let before = batch_svc.stats().executions;
        let again = batch_svc.val_error_batch(&qcs, 0).unwrap();
        assert_eq!(again, batch);
        assert_eq!(batch_svc.stats().executions, before);
    }

    #[test]
    fn capped_cache_rotates_out_idle_entries_and_counts_evictions() {
        // cap 4 -> generations of 2. Entries untouched for a full
        // generation rotate out; reads promote, so a live working set
        // survives indefinitely.
        let cache: ResultCache<u32, f64> = ResultCache::with_capacity(4);
        for k in 0..8u32 {
            cache.insert(k, k as f64).unwrap();
        }
        assert!(cache.len().unwrap() <= 4, "resident {:?}", cache.len());
        assert_eq!(cache.evictions(), Some(6));
        // Oldest entries are gone; the newest survive.
        assert_eq!(cache.get(&0).unwrap(), None);
        assert_eq!(cache.get(&7).unwrap(), Some(7.0));
        // A key read every generation is never evicted.
        let cache: ResultCache<u32, f64> = ResultCache::with_capacity(4);
        cache.insert(100, 1.0).unwrap();
        for k in 0..20u32 {
            cache.insert(k, 0.0).unwrap();
            assert_eq!(cache.get(&100).unwrap(), Some(1.0), "after insert {k}");
        }
        // Shrinking the cap takes effect at the next rotation.
        let cache: ResultCache<u32, f64> = ResultCache::new();
        for k in 0..100u32 {
            cache.insert(k, 0.0).unwrap();
        }
        assert_eq!(cache.len(), Some(100));
        cache.set_capacity(10).unwrap();
        for k in 100..110u32 {
            cache.insert(k, 0.0).unwrap();
        }
        assert!(cache.len().unwrap() <= 11, "resident {:?}", cache.len());
    }

    #[test]
    fn retain_purges_matching_keys_as_evictions() {
        let cache: ResultCache<u32, f64> = ResultCache::with_capacity(100);
        for k in 0..10u32 {
            cache.insert(k, k as f64).unwrap();
        }
        cache.retain(|k| k % 2 == 0).unwrap();
        assert_eq!(cache.len(), Some(5));
        assert_eq!(cache.evictions(), Some(5));
        assert_eq!(cache.get(&3).unwrap(), None);
        assert_eq!(cache.get(&4).unwrap(), Some(4.0));
    }

    #[test]
    fn result_cache_round_trips_until_poisoned() {
        let cache: ResultCache<u32, f64> = ResultCache::new();
        assert!(cache.is_empty());
        assert!(!cache.poisoned());
        cache.insert(7, 0.25).unwrap();
        assert_eq!(cache.get(&7).unwrap(), Some(0.25));
        assert_eq!(cache.get(&8).unwrap(), None);
        assert_eq!(cache.len(), Some(1));

        cache.poison_for_test();
        let err = cache.get(&7).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        assert!(cache.insert(9, 1.0).is_err());
        // Regression: a poisoned cache used to report len() == 0, making
        // post-incident stats read as "empty cache" instead of "cannot
        // trust the count". The marker is explicit now.
        assert_eq!(cache.len(), None, "poisoned cache must not claim a count");
        assert!(cache.poisoned());
        assert!(!cache.is_empty(), "unknown size is not 'empty'");
    }

    #[test]
    fn surrogate_engine_is_deterministic_monotone_and_cached() {
        let arts = Arc::new(Artifacts::synthetic());
        let svc = EvalService::surrogate(arts.clone()).unwrap();
        assert!(svc.is_surrogate());
        let n = arts.layer_names.len();
        let e16 = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        let e8 = svc.val_error(&QuantConfig::uniform(n, Bits::B8, Bits::B8), 0).unwrap();
        let e2 = svc.val_error(&QuantConfig::uniform(n, Bits::B2, Bits::B2), 0).unwrap();
        assert!(e16 < e8 && e8 < e2, "penalty must grow as precision drops: {e16} {e8} {e2}");
        assert!(e16 >= arts.baseline.val_err_16bit);
        // Cached on repeat, bitwise identical across a fresh service.
        let before = svc.stats().executions;
        let again = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        assert_eq!(again.to_bits(), e16.to_bits());
        assert_eq!(svc.stats().executions, before);
        assert!(svc.stats().cache_hits > 0);
        let svc2 = EvalService::surrogate(arts.clone()).unwrap();
        let fresh = svc2.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        assert_eq!(fresh.to_bits(), e16.to_bits());
    }

    #[test]
    fn float_baseline_error_matches_manifest() {
        let Some(arts) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let svc = EvalService::new(&rt, arts.clone()).unwrap();
        // B32 disables quantization -> must reproduce the float val error
        // computed by the Python pipeline (bit-for-bit same graph modulo
        // the Pallas kernels, which pytest proves equivalent).
        let qc = QuantConfig::uniform(arts.layer_names.len(), Bits::B32, Bits::B32);
        let err = svc.val_error(&qc, 0).unwrap();
        let expect = arts.baseline.val_err;
        assert!(
            (err - expect).abs() < 0.02,
            "rust eval {err} vs python {expect}"
        );
    }

    #[test]
    fn quantized_error_ordered_and_cached() {
        let Some(arts) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let svc = EvalService::new(&rt, arts.clone()).unwrap();
        let n = arts.layer_names.len();
        let e16 = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        let e2 = svc.val_error(&QuantConfig::uniform(n, Bits::B2, Bits::B8), 0).unwrap();
        assert!(e2 > e16 + 0.05, "2-bit {e2} should be much worse than 16-bit {e16}");
        // Cache hit on repeat.
        let before = svc.stats().executions;
        let again = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        assert_eq!(again, e16);
        assert_eq!(svc.stats().executions, before);
        assert!(svc.stats().cache_hits > 0);
    }

    #[test]
    fn concurrent_evaluations_agree_with_sequential() {
        let Some(arts) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let svc = EvalService::new(&rt, arts.clone()).unwrap();
        let n = arts.layer_names.len();
        let qcs: Vec<QuantConfig> = [Bits::B16, Bits::B8, Bits::B4]
            .iter()
            .map(|&b| QuantConfig::uniform(n, b, Bits::B8))
            .collect();
        let seq: Vec<f64> = qcs.iter().map(|qc| svc.val_error(qc, 0).unwrap()).collect();
        let svc2 = EvalService::new(&rt, arts.clone()).unwrap();
        let par = crate::util::pool::map_parallel(3, &qcs, |_, qc| svc2.val_error(qc, 0).unwrap());
        assert_eq!(seq, par);
    }
}
