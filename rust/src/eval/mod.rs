//! Candidate-solution evaluation service: the error objective.
//!
//! Wraps the AOT inference executable. A candidate (QuantConfig) is
//! resolved against the calibration tables into runtime (Δ,qmin,qmax,en)
//! rows, then the executable runs over the validation subsets; the error
//! objective is the MAX subset error (paper §4.2's variance-reduction
//! trick). Results are memoized per (parameter-set, genome) — NSGA-II
//! revisits genomes often with pop 10 x 60 generations.
//!
//! The service is `Send + Sync`: the result cache, execution counters and
//! parameter-set table all use interior mutability, so one instance can
//! score candidates from every worker of the coordinator's thread pool
//! concurrently (the `SearchSession` dedupes in-flight genomes, keeping
//! execution counts thread-count-independent).
//!
//! Parameter sets: index 0 is the baseline pre-trained model; beacon
//! retraining registers additional sets (paper §4.3). All sets stay
//! resident on the PJRT device so per-eval upload cost is only the quant
//! params + data batch.
//!
//! Two engines share this surface:
//!   * [`EvalService::new`] — the PJRT path over the AOT executable;
//!   * [`EvalService::surrogate`] — a hermetic closed-form error model
//!     (no runtime, no artifacts on disk) with the same cache, counters
//!     and determinism contract. Serve mode and CI fall back to it when
//!     no bundle is present, so the full search/serve stack exercises
//!     end to end offline.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{Context, Result};

use crate::quant::{resolve_qparams, Bits, QuantConfig};
use crate::runtime::{scalar_f32, Artifacts, Executor, Input, Runtime, Split};

pub struct ParamSet {
    pub name: String,
    /// Host copy (beacon sets need it as the start point of further runs
    /// and for the final report).
    pub host: Vec<Vec<f32>>,
    bufs: Vec<crate::runtime::DeviceTensor>,
}

type CacheKey = (usize, Vec<Bits>, Vec<Bits>);

/// Shared memo map behind a poison-aware mutex. A worker that panics while
/// holding the lock poisons it; every later access returns a typed error
/// (carrying the "poisoned" marker `SearchSession` maps to
/// `SearchError::Poisoned`) instead of raising a second panic inside the
/// worker pool.
pub struct ResultCache<K, V> {
    inner: Mutex<HashMap<K, V>>,
}

impl<K: std::hash::Hash + Eq, V: Clone> ResultCache<K, V> {
    pub fn new() -> ResultCache<K, V> {
        ResultCache { inner: Mutex::new(HashMap::new()) }
    }

    fn guard(&self) -> Result<std::sync::MutexGuard<'_, HashMap<K, V>>> {
        self.inner.lock().map_err(|_| {
            anyhow::anyhow!("eval cache poisoned: a worker panicked while holding the lock")
        })
    }

    pub fn get(&self, key: &K) -> Result<Option<V>> {
        Ok(self.guard()?.get(key).cloned())
    }

    pub fn insert(&self, key: K, value: V) -> Result<()> {
        self.guard()?.insert(key, value);
        Ok(())
    }

    /// Entry count, or `None` when the lock is poisoned. Reporting
    /// `Some(0)` for a poisoned cache made post-incident `EvalStats` lie
    /// ("0 unique solutions" after thousands of evaluations); the marker
    /// lets stats carry the poisoning explicitly.
    pub fn len(&self) -> Option<usize> {
        self.inner.lock().map(|g| g.len()).ok()
    }

    /// Whether a worker panicked while holding the lock.
    pub fn poisoned(&self) -> bool {
        self.inner.is_poisoned()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == Some(0)
    }

    /// Poison the lock by panicking while holding it — the regression
    /// hook for the typed `SearchError::Poisoned` path. Test-only; the
    /// panic it catches is confined to this call.
    #[doc(hidden)]
    pub fn poison_for_test(&self) {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = self.inner.lock();
            panic!("poisoning eval cache");
        }));
    }
}

impl<K: std::hash::Hash + Eq, V: Clone> Default for ResultCache<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

/// Cumulative service counters. With a shared service (serve mode, session
/// reuse) these are CROSS-REQUEST totals; `SearchOutcome` reports per-run
/// deltas next to a snapshot of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EvalStats {
    pub executions: usize,
    pub cache_hits: usize,
    /// Distinct (param-set, genome) keys memoized; 0 while `poisoned`.
    pub unique_solutions: usize,
    /// True when the result cache was poisoned by a worker panic —
    /// `unique_solutions` can no longer be trusted (post-incident stats
    /// must not silently read as "empty cache").
    pub poisoned: bool,
}

/// How candidate errors are produced.
enum Engine {
    /// The AOT inference executable on a PJRT client.
    Pjrt(Executor),
    /// Hermetic closed-form error model (see `surrogate_val_error`).
    Surrogate,
}

pub struct EvalService {
    pub arts: Arc<Artifacts>,
    engine: Engine,
    param_sets: RwLock<Vec<Arc<ParamSet>>>,
    cache: ResultCache<CacheKey, f64>,
    executions: AtomicUsize,
    cache_hits: AtomicUsize,
}

impl EvalService {
    pub fn new(rt: &Runtime, arts: Arc<Artifacts>) -> Result<EvalService> {
        // Two lowerings of the SAME computation exist in the bundle:
        // `infer` (Pallas kernels, the TPU-shaped artifact) and
        // `infer_ref` (XLA-native ops). pytest proves them numerically
        // equivalent; on CPU PJRT the native lowering is ~4.6x faster
        // (EXPERIMENTS.md §Perf L2), so it is the default here.
        // MOHAQ_INFER_GRAPH=pallas forces the kernel graph.
        let which = match std::env::var("MOHAQ_INFER_GRAPH").as_deref() {
            Ok("pallas") => "infer",
            Ok("ref") => "infer_ref",
            _ => "infer_ref",
        };
        let exec = rt.load(arts.hlo_path(which).or_else(|_| arts.hlo_path("infer"))?)?;
        EvalService::with_engine(arts, Engine::Pjrt(exec))
    }

    /// Hermetic engine: candidate errors come from a deterministic
    /// closed-form model of PTQ degradation instead of the AOT executable
    /// (no PJRT, no files). Same cache, counters, and `Send + Sync`
    /// contract — the search and serve stacks cannot tell the difference,
    /// which is exactly what lets CI drive them end to end offline.
    pub fn surrogate(arts: Arc<Artifacts>) -> Result<EvalService> {
        EvalService::with_engine(arts, Engine::Surrogate)
    }

    fn with_engine(arts: Arc<Artifacts>, engine: Engine) -> Result<EvalService> {
        let svc = EvalService {
            arts: arts.clone(),
            engine,
            param_sets: RwLock::new(Vec::new()),
            cache: ResultCache::new(),
            executions: AtomicUsize::new(0),
            cache_hits: AtomicUsize::new(0),
        };
        let baseline = arts.weights.clone();
        svc.add_param_set("baseline", baseline)?;
        Ok(svc)
    }

    /// Whether this service evaluates through the hermetic surrogate.
    pub fn is_surrogate(&self) -> bool {
        matches!(self.engine, Engine::Surrogate)
    }

    /// Register a parameter set (e.g. a retrained beacon); returns its id.
    pub fn add_param_set(&self, name: &str, host: Vec<Vec<f32>>) -> Result<usize> {
        anyhow::ensure!(
            host.len() == self.arts.tensors.len(),
            "param set has {} tensors, artifact expects {}",
            host.len(),
            self.arts.tensors.len()
        );
        let mut bufs = Vec::new();
        if let Engine::Pjrt(exec) = &self.engine {
            bufs.reserve(host.len());
            for (data, info) in host.iter().zip(&self.arts.tensors) {
                let shape: Vec<i64> = info.shape.iter().map(|&d| d as i64).collect();
                // Scalars/1-D keep their manifest shape.
                bufs.push(exec.upload(&Input::F32(data, shape))?);
            }
        }
        let mut sets = self.param_sets.write().expect("param sets poisoned");
        sets.push(Arc::new(ParamSet { name: name.to_string(), host, bufs }));
        Ok(sets.len() - 1)
    }

    pub fn param_set(&self, idx: usize) -> Arc<ParamSet> {
        self.param_sets.read().expect("param sets poisoned")[idx].clone()
    }

    pub fn num_param_sets(&self) -> usize {
        self.param_sets.read().expect("param sets poisoned").len()
    }

    pub fn stats(&self) -> EvalStats {
        EvalStats {
            executions: self.executions.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            unique_solutions: self.cache.len().unwrap_or(0),
            poisoned: self.cache.poisoned(),
        }
    }

    fn qparams(&self, qc: &QuantConfig) -> Result<(Vec<f32>, Vec<f32>)> {
        resolve_qparams(qc, &self.arts.layer_names, &self.arts.w_clips, &self.arts.a_clips)
    }

    /// Deterministic closed-form PTQ error for the surrogate engine.
    ///
    /// Shaped after the empirical behavior of the real pipeline: the error
    /// starts at the 16-bit baseline and each layer adds a penalty that
    /// shrinks quadratically with precision (quantization MSE ~ 2^-2b),
    /// weighted by the layer's share of the model size. Weight precision
    /// dominates; activations contribute ~30%. A small FNV-hash term keyed
    /// by (set, genome) breaks ties so fronts stay diverse. Pure function
    /// of its inputs — bitwise identical across runs, threads, platforms.
    fn surrogate_val_error(&self, qc: &QuantConfig, set: usize) -> f64 {
        let model = &self.arts.model;
        let total_bits = model.baseline_size_bits() as f64;
        let penalty = |b: Bits| -> f64 {
            match b {
                Bits::B2 => 0.50,
                Bits::B4 => 0.12,
                Bits::B8 => 0.02,
                Bits::B16 => 0.002,
                Bits::B32 => 0.0,
            }
        };
        let mut err = self.arts.baseline.val_err_16bit;
        for (i, (wb, ab)) in qc.w_bits.iter().zip(&qc.a_bits).enumerate() {
            let frac = model.layers[i].matrix_weights() as f64 * 32.0 / total_bits;
            err += frac * (penalty(*wb) + 0.3 * penalty(*ab));
        }
        // FNV-1a over (set, genes): deterministic jitter in [0, 0.002).
        let mut h: u64 = 0xcbf29ce484222325;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        };
        mix(set as u64);
        for (wb, ab) in qc.w_bits.iter().zip(&qc.a_bits) {
            mix(wb.bits() as u64);
            mix(ab.bits() as u64 + 97);
        }
        err + (h % 1000) as f64 * 2.0e-6
    }

    /// (err_count, total, loss_sum) accumulated over every batch of a split.
    fn run_split(&self, qc: &QuantConfig, set: usize, split: &Split) -> Result<(f64, f64, f64)> {
        let Engine::Pjrt(exec) = &self.engine else {
            // Surrogate: one "execution" per split, errors from the
            // closed-form model (counted so cache-hit accounting and the
            // stats surface behave identically to the PJRT path).
            self.executions.fetch_add(1, Ordering::Relaxed);
            let err = self.surrogate_val_error(qc, set);
            let total = split.num_seqs.max(1) as f64;
            return Ok((err * total, total, err * 3.0));
        };
        let a = &self.arts;
        let (b, t, f) = (a.batch, a.seq_len, a.feat_dim);
        let n_layers = a.layer_names.len() as i64;
        let (wq, aq) = self.qparams(qc)?;
        // Arc clone only — the lock is NOT held across executions, so
        // beacon registrations from the sequential phase never contend
        // with in-flight parallel evaluations.
        let params = self.param_set(set);
        let (mut err, mut total, mut loss) = (0.0, 0.0, 0.0);
        for k in 0..split.num_batches(b) {
            let (x, y) = split.batch(k, b, t, f);
            let fresh = [
                Input::F32(&wq, vec![n_layers, 4]),
                Input::F32(&aq, vec![n_layers, 4]),
                Input::F32(x, vec![b as i64, t as i64, f as i64]),
                Input::I32(y, vec![b as i64, t as i64]),
            ];
            let out = exec
                .run_mixed(&params.bufs, &fresh)
                .with_context(|| format!("infer exec, set {set}"))?;
            err += scalar_f32(&out[0])? as f64;
            total += scalar_f32(&out[1])? as f64;
            loss += scalar_f32(&out[2])? as f64;
            self.executions.fetch_add(1, Ordering::Relaxed);
        }
        Ok((err, total, loss))
    }

    /// Validation error = max over the subsets (paper §4.2). Cached. A
    /// poisoned cache lock surfaces as an `Err` (not a panic), so worker
    /// threads fail cleanly and `SearchSession` can report
    /// `SearchError::Poisoned`.
    pub fn val_error(&self, qc: &QuantConfig, set: usize) -> Result<f64> {
        let key: CacheKey = (set, qc.w_bits.clone(), qc.a_bits.clone());
        if let Some(v) = self.cache.get(&key)? {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(v);
        }
        let mut worst: f64 = 0.0;
        for split in &self.arts.val_subsets {
            let (e, t, _) = self.run_split(qc, set, split)?;
            worst = worst.max(e / t.max(1.0));
        }
        self.cache.insert(key, worst)?;
        Ok(worst)
    }

    /// Test-set error (final report column WER_T). Uncached — called once
    /// per Pareto solution.
    pub fn test_error(&self, qc: &QuantConfig, set: usize) -> Result<f64> {
        let (e, t, _) = self.run_split(qc, set, &self.arts.test)?;
        Ok(e / t.max(1.0))
    }

    /// Mean validation loss (beacon diagnostics).
    pub fn val_loss(&self, qc: &QuantConfig, set: usize) -> Result<f64> {
        let mut sum = 0.0;
        let mut n = 0usize;
        for split in &self.arts.val_subsets {
            let (_, _, l) = self.run_split(qc, set, split)?;
            n += split.num_batches(self.arts.batch);
            sum += l;
        }
        Ok(sum / n.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<Arc<Artifacts>> {
        let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let p = PathBuf::from(dir);
        if !p.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts present");
            return None;
        }
        Some(Arc::new(Artifacts::load(p).unwrap()))
    }

    #[test]
    fn service_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<EvalService>();
    }

    #[test]
    fn result_cache_round_trips_until_poisoned() {
        let cache: ResultCache<u32, f64> = ResultCache::new();
        assert!(cache.is_empty());
        assert!(!cache.poisoned());
        cache.insert(7, 0.25).unwrap();
        assert_eq!(cache.get(&7).unwrap(), Some(0.25));
        assert_eq!(cache.get(&8).unwrap(), None);
        assert_eq!(cache.len(), Some(1));

        cache.poison_for_test();
        let err = cache.get(&7).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        assert!(cache.insert(9, 1.0).is_err());
        // Regression: a poisoned cache used to report len() == 0, making
        // post-incident stats read as "empty cache" instead of "cannot
        // trust the count". The marker is explicit now.
        assert_eq!(cache.len(), None, "poisoned cache must not claim a count");
        assert!(cache.poisoned());
        assert!(!cache.is_empty(), "unknown size is not 'empty'");
    }

    #[test]
    fn surrogate_engine_is_deterministic_monotone_and_cached() {
        let arts = Arc::new(Artifacts::synthetic());
        let svc = EvalService::surrogate(arts.clone()).unwrap();
        assert!(svc.is_surrogate());
        let n = arts.layer_names.len();
        let e16 = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        let e8 = svc.val_error(&QuantConfig::uniform(n, Bits::B8, Bits::B8), 0).unwrap();
        let e2 = svc.val_error(&QuantConfig::uniform(n, Bits::B2, Bits::B2), 0).unwrap();
        assert!(e16 < e8 && e8 < e2, "penalty must grow as precision drops: {e16} {e8} {e2}");
        assert!(e16 >= arts.baseline.val_err_16bit);
        // Cached on repeat, bitwise identical across a fresh service.
        let before = svc.stats().executions;
        let again = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        assert_eq!(again.to_bits(), e16.to_bits());
        assert_eq!(svc.stats().executions, before);
        assert!(svc.stats().cache_hits > 0);
        let svc2 = EvalService::surrogate(arts.clone()).unwrap();
        let fresh = svc2.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        assert_eq!(fresh.to_bits(), e16.to_bits());
    }

    #[test]
    fn float_baseline_error_matches_manifest() {
        let Some(arts) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let svc = EvalService::new(&rt, arts.clone()).unwrap();
        // B32 disables quantization -> must reproduce the float val error
        // computed by the Python pipeline (bit-for-bit same graph modulo
        // the Pallas kernels, which pytest proves equivalent).
        let qc = QuantConfig::uniform(arts.layer_names.len(), Bits::B32, Bits::B32);
        let err = svc.val_error(&qc, 0).unwrap();
        let expect = arts.baseline.val_err;
        assert!(
            (err - expect).abs() < 0.02,
            "rust eval {err} vs python {expect}"
        );
    }

    #[test]
    fn quantized_error_ordered_and_cached() {
        let Some(arts) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let svc = EvalService::new(&rt, arts.clone()).unwrap();
        let n = arts.layer_names.len();
        let e16 = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        let e2 = svc.val_error(&QuantConfig::uniform(n, Bits::B2, Bits::B8), 0).unwrap();
        assert!(e2 > e16 + 0.05, "2-bit {e2} should be much worse than 16-bit {e16}");
        // Cache hit on repeat.
        let before = svc.stats().executions;
        let again = svc.val_error(&QuantConfig::uniform(n, Bits::B16, Bits::B16), 0).unwrap();
        assert_eq!(again, e16);
        assert_eq!(svc.stats().executions, before);
        assert!(svc.stats().cache_hits > 0);
    }

    #[test]
    fn concurrent_evaluations_agree_with_sequential() {
        let Some(arts) = artifacts() else { return };
        let rt = Runtime::cpu().unwrap();
        let svc = EvalService::new(&rt, arts.clone()).unwrap();
        let n = arts.layer_names.len();
        let qcs: Vec<QuantConfig> = [Bits::B16, Bits::B8, Bits::B4]
            .iter()
            .map(|&b| QuantConfig::uniform(n, b, Bits::B8))
            .collect();
        let seq: Vec<f64> = qcs.iter().map(|qc| svc.val_error(qc, 0).unwrap()).collect();
        let svc2 = EvalService::new(&rt, arts.clone()).unwrap();
        let par = crate::util::pool::map_parallel(3, &qcs, |_, qc| svc2.val_error(qc, 0).unwrap());
        assert_eq!(seq, par);
    }
}
