//! Experiment driver: wires artifacts + runtime + eval + beacons + NSGA-II
//! into one call, and post-processes the final population into the
//! paper-style solution tables (Tables 5-8).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::beacon::{BeaconManager, BeaconPolicy};
use super::problem::{MohaqProblem, ObjectiveKind};
use super::trainer::Trainer;
use crate::eval::EvalService;
use crate::hw::{bitfusion::Bitfusion, silago::SiLago, Platform};
use crate::moo::{Nsga2, Nsga2Config};
use crate::quant::{Bits, QuantConfig};
use crate::runtime::{Artifacts, Runtime};

#[derive(Debug, Clone)]
pub enum PlatformChoice {
    None,
    SiLago { sram_mb: f64 },
    Bitfusion { sram_mb: f64 },
}

#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    pub name: String,
    pub platform: PlatformChoice,
    pub objectives: Vec<ObjectiveKind>,
    /// Enable beacon-based search with this policy (None = inference-only).
    pub beacon: Option<BeaconPolicyOverrides>,
    pub ga: Nsga2Config,
    /// Feasibility area width above the 16-bit baseline error (paper: 8pp).
    pub err_feasible_pp: f64,
}

/// Beacon policy knobs exposed to drivers; unset fields use paper defaults.
#[derive(Debug, Clone, Default)]
pub struct BeaconPolicyOverrides {
    pub threshold: Option<f64>,
    pub retrain_steps: Option<usize>,
    pub max_beacons: Option<usize>,
}

impl ExperimentSpec {
    /// Experiment 1 (§5.2): WER vs memory size, no hardware model.
    pub fn exp1() -> ExperimentSpec {
        ExperimentSpec {
            name: "exp1-compression".into(),
            platform: PlatformChoice::None,
            objectives: vec![ObjectiveKind::Error, ObjectiveKind::SizeMb],
            beacon: None,
            ga: Nsga2Config { pop_size: 10, initial_pop_size: 40, generations: 60, ..Default::default() },
            err_feasible_pp: 8.0,
        }
    }

    /// Experiment 2 (§5.3): SiLago, 3 objectives, 6 MB SRAM, tied W=A.
    pub fn exp2_silago() -> ExperimentSpec {
        ExperimentSpec {
            name: "exp2-silago".into(),
            platform: PlatformChoice::SiLago { sram_mb: 6.0 },
            objectives: vec![
                ObjectiveKind::Error,
                ObjectiveKind::NegSpeedup,
                ObjectiveKind::EnergyUj,
            ],
            beacon: None,
            ga: Nsga2Config { pop_size: 10, initial_pop_size: 40, generations: 15, ..Default::default() },
            err_feasible_pp: 8.0,
        }
    }

    /// Experiment 3 (§5.4): Bitfusion, 2 MB SRAM; beacon optional.
    pub fn exp3_bitfusion(beacon: bool) -> ExperimentSpec {
        ExperimentSpec {
            name: if beacon { "exp3-bitfusion-beacon".into() } else { "exp3-bitfusion".into() },
            platform: PlatformChoice::Bitfusion { sram_mb: 2.0 },
            objectives: vec![ObjectiveKind::Error, ObjectiveKind::NegSpeedup],
            beacon: beacon.then(BeaconPolicyOverrides::default),
            ga: Nsga2Config { pop_size: 10, initial_pop_size: 40, generations: 60, ..Default::default() },
            err_feasible_pp: 8.0,
        }
    }
}

/// One row of a paper-style solutions table.
#[derive(Debug, Clone)]
pub struct SolutionRow {
    pub qc: QuantConfig,
    pub wer_v: f64,
    pub wer_t: f64,
    pub cp_r: f64,
    pub size_mb: f64,
    pub speedup: Option<f64>,
    pub energy_uj: Option<f64>,
    /// Which parameter set produced wer_v ("baseline" or a beacon name).
    pub param_set: String,
}

#[derive(Debug, Clone)]
pub struct GenerationLog {
    pub generation: usize,
    pub evaluations: usize,
    pub best_err: f64,
    pub feasible: usize,
}

pub struct SearchOutcome {
    pub spec_name: String,
    pub rows: Vec<SolutionRow>,
    pub history: Vec<GenerationLog>,
    pub evaluations: usize,
    pub exec_calls: usize,
    pub cache_hits: usize,
    pub beacons: Vec<(String, usize)>,
    /// All evaluation records (figures 9/10 scatter data).
    pub records: Vec<super::problem::EvalRecord>,
    pub baseline_val_err: f64,
    pub baseline_test_err: f64,
    pub wall_secs: f64,
}

fn make_platform(choice: &PlatformChoice) -> Option<Box<dyn Platform>> {
    match choice {
        PlatformChoice::None => None,
        PlatformChoice::SiLago { sram_mb } => Some(Box::new(SiLago::new(Some(sram_mb * 1024.0 * 1024.0)))),
        PlatformChoice::Bitfusion { sram_mb } => {
            Some(Box::new(Bitfusion::new(Some(sram_mb * 1024.0 * 1024.0))))
        }
    }
}

/// Run a full MOHAQ search per the spec. `verbose` prints per-generation
/// progress to stdout (experiment drivers); silence it in benches.
pub fn run_search(
    spec: &ExperimentSpec,
    arts: Rc<Artifacts>,
    rt: &Runtime,
    verbose: bool,
) -> Result<SearchOutcome> {
    let t0 = std::time::Instant::now();
    let eval = EvalService::new(rt, arts.clone()).context("creating eval service")?;
    let platform = make_platform(&spec.platform);
    let tied = platform.as_ref().map(|p| p.tied_wa()).unwrap_or(false);
    let gene_min = platform
        .as_ref()
        .map(|p| p.supported_bits().iter().map(|b| b.to_gene()).min().unwrap())
        .unwrap_or(1);
    let err_limit = arts.baseline.val_err_16bit + spec.err_feasible_pp / 100.0;

    let (trainer, beacons) = if let Some(ov) = &spec.beacon {
        let mut policy = BeaconPolicy::paper_defaults(
            arts.baseline.val_err_16bit,
            arts.baseline.beacon_lr as f32,
        );
        if let Some(t) = ov.threshold {
            policy.threshold = t;
        }
        if let Some(s) = ov.retrain_steps {
            policy.retrain_steps = s;
        }
        if let Some(m) = ov.max_beacons {
            policy.max_beacons = m;
        }
        (
            Some(Trainer::new(rt, arts.clone(), spec.ga.seed ^ 0xbeac0)?),
            Some(BeaconManager::new(policy)),
        )
    } else {
        (None, None)
    };

    let mut problem = MohaqProblem {
        arts: arts.clone(),
        eval,
        trainer,
        beacons,
        platform,
        objectives: spec.objectives.clone(),
        tied,
        err_limit,
        gene_min,
        records: Vec::new(),
    };

    let mut algo = Nsga2::new(spec.ga.clone());
    let mut history: Vec<GenerationLog> = Vec::new();
    let pop = algo.run(&mut problem, |stats| {
        let best_err = stats
            .population
            .iter()
            .filter(|i| i.feasible())
            .map(|i| i.objectives[0])
            .fold(f64::INFINITY, f64::min);
        let feasible = stats.population.iter().filter(|i| i.feasible()).count();
        history.push(GenerationLog {
            generation: stats.generation,
            evaluations: stats.evaluations,
            best_err,
            feasible,
        });
        if verbose {
            println!(
                "  gen {:>3}  evals {:>4}  feasible {:>2}/{}  best WER_V {:.4}",
                stats.generation,
                stats.evaluations,
                feasible,
                stats.population.len(),
                best_err
            );
        }
    });

    // ---- Post-process the Pareto set into report rows ------------------
    let set = Nsga2::pareto_set(&pop);
    // Latest record per genome tells us which parameter set scored it.
    let mut set_of: HashMap<Vec<i64>, usize> = HashMap::new();
    for r in &problem.records {
        set_of.insert(r.genome.clone(), r.set_idx);
    }

    let mut rows = Vec::with_capacity(set.len());
    for ind in &set {
        let qc = problem.decode(&ind.genome);
        let set_idx = *set_of.get(&ind.genome).unwrap_or(&0);
        let wer_v = problem.eval.val_error(&qc, set_idx)?;
        let wer_t = problem.eval.test_error(&qc, set_idx)?;
        let model = &problem.arts.model;
        rows.push(SolutionRow {
            cp_r: model.compression_ratio(&qc.w_bits),
            size_mb: model.size_bytes(&qc.w_bits) / (1024.0 * 1024.0),
            speedup: problem.platform.as_ref().map(|p| p.speedup(model, &qc)),
            energy_uj: problem
                .platform
                .as_ref()
                .and_then(|p| p.energy_pj(model, &qc))
                .map(|pj| pj / 1e6),
            param_set: problem.eval.param_set(set_idx).name.clone(),
            qc,
            wer_v,
            wer_t,
        });
    }
    rows.sort_by(|a, b| a.wer_v.partial_cmp(&b.wer_v).unwrap());

    let stats = problem.eval.stats();
    Ok(SearchOutcome {
        spec_name: spec.name.clone(),
        rows,
        history,
        evaluations: algo.evaluations(),
        exec_calls: stats.executions,
        cache_hits: stats.cache_hits,
        beacons: problem
            .beacons
            .as_ref()
            .map(|b| {
                b.beacons
                    .iter()
                    .map(|bc| (bc.qc.display_wa(), bc.report.steps))
                    .collect()
            })
            .unwrap_or_default(),
        records: problem.records,
        baseline_val_err: arts.baseline.val_err_16bit,
        baseline_test_err: arts.baseline.test_err,
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}

/// Baseline rows (Base / Base_16bit) for the report tables.
pub fn baseline_rows(arts: &Artifacts) -> Vec<SolutionRow> {
    let n = arts.layer_names.len();
    let float_qc = QuantConfig::uniform(n, Bits::B32, Bits::B32);
    let qc16 = QuantConfig::uniform(n, Bits::B16, Bits::B16);
    vec![
        SolutionRow {
            qc: float_qc,
            wer_v: arts.baseline.val_err,
            wer_t: arts.baseline.test_err,
            cp_r: 1.0,
            size_mb: arts.model.baseline_size_bits() as f64 / 8.0 / (1024.0 * 1024.0),
            speedup: None,
            energy_uj: None,
            param_set: "baseline".into(),
        },
        SolutionRow {
            qc: qc16.clone(),
            wer_v: arts.baseline.val_err_16bit,
            wer_t: arts.baseline.test_err,
            cp_r: arts.model.compression_ratio(&qc16.w_bits),
            size_mb: arts.model.size_bytes(&qc16.w_bits) / (1024.0 * 1024.0),
            speedup: Some(1.0),
            energy_uj: None,
            param_set: "baseline".into(),
        },
    ]
}
