//! `SearchSession`: the public entry point of the MOHAQ search. A session
//! owns the shared artifacts (`Arc<Artifacts>`), the PJRT runtime and ONE
//! shared `EvalService` (PTQ result cache); it evaluates each generation's
//! population in parallel across a thread pool, streams progress through a
//! `SearchEvent` callback, and returns a typed `SearchError` at the API
//! boundary.
//!
//! Session reuse (serve mode): every `run` on the same session shares the
//! compiled executable AND the memoized PTQ results — a second request
//! re-scoring genomes an earlier request already evaluated is pure cache
//! hits, even when the two requests bind different hardware platforms
//! (the error cache is platform-independent; hardware objectives are
//! analytical). `run_with` is `&self` and thread-safe, so concurrent
//! requests can share one session; `shared_queue` additionally funnels
//! their candidate evaluations through one long-lived worker pool.
//! Per-run `SearchOutcome` stats are deltas against the shared service
//! counters, reported next to a cumulative snapshot.
//!
//! Cancellation: `run_with_cancel` takes a [`CancelToken`]; tripping it
//! aborts at the next evaluation batch with `SearchError::Cancelled`.
//!
//! Objectives are resolved through the typed pipeline
//! (`spec.resolve_objectives()`): each hardware objective is bound to a
//! registry platform, a cross-platform spec scores one front against
//! several platforms at once (the genome obeys the intersection of their
//! restrictions; every binding contributes its SRAM constraint), and
//! `SolutionRow::hw` carries the per-platform metrics.
//!
//! Determinism contract: for a fixed spec (including seed), the resulting
//! front is bitwise-identical for ANY thread count, micro-batch geometry
//! or island count — the parallel phases (micro-batched PTQ evaluation,
//! beacon retraining on per-beacon forked RNG streams) compute
//! order-independent pure values, and only the order-dependent beacon
//! *selection* pass stays sequential (see `MohaqProblem::evaluate_batch`
//! and `BeaconManager::plan_batch`).

use std::cmp::Ordering;
use std::collections::HashMap;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};

use anyhow::Context;

use super::beacon::{BeaconManager, BeaconMode, BeaconPolicy, BeaconSnapshot};
use super::error::SearchError;
use super::objective::HwMetrics;
use super::problem::{EvalStrategy, MohaqProblem};
use super::spec::ExperimentSpec;
use super::trainer::{Retrainer, SurrogateTrainer, Trainer};
use crate::eval::{EvalService, EvalStats};
use crate::hw::Platform;
use crate::moo::island::{
    front_hypervolume, IslandConfig, IslandEvent, IslandModel, IslandShard, IslandSnapshot,
};
use crate::moo::{Individual, Nsga2, Nsga2Config, Parallel, Problem, SyncProblem};
use crate::quant::{Bits, QuantConfig};
use crate::runtime::{Artifacts, Runtime};
use crate::util::pool::{self, WorkQueue};

/// Cooperative cancellation handle: clone it, hand one side to
/// `run_with_cancel`, call `cancel()` from any thread. The search aborts
/// at its next evaluation batch with `SearchError::Cancelled` (no partial
/// front is reported — partial populations are not Pareto sets).
#[derive(Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(std::sync::atomic::Ordering::SeqCst)
    }
}

/// One row of a paper-style solutions table.
#[derive(Debug, Clone)]
pub struct SolutionRow {
    pub qc: QuantConfig,
    pub wer_v: f64,
    pub wer_t: f64,
    pub cp_r: f64,
    pub size_mb: f64,
    /// Convenience: the FIRST platform binding's speedup (`None` without
    /// a platform). Cross-platform searches read `hw` instead.
    pub speedup: Option<f64>,
    /// Convenience: the first binding's energy, when it has a model.
    pub energy_uj: Option<f64>,
    /// Per-platform metrics, one entry per binding in table order.
    pub hw: Vec<HwMetrics>,
    /// Which parameter set produced wer_v ("baseline" or a beacon name).
    pub param_set: String,
}

#[derive(Debug, Clone)]
pub struct GenerationLog {
    pub generation: usize,
    pub evaluations: usize,
    pub best_err: f64,
    pub feasible: usize,
    pub pop_size: usize,
    /// Which island produced this generation (`None` = single population).
    pub island: Option<usize>,
}

/// One-line progress rendering shared by the CLI and every example driver.
impl std::fmt::Display for GenerationLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if let Some(i) = self.island {
            write!(f, "  [isl {i}] ")?;
        } else {
            write!(f, "  ")?;
        }
        write!(
            f,
            "gen {:>3}  evals {:>4}  feasible {:>2}/{}  best WER_V {:.4}",
            self.generation, self.evaluations, self.feasible, self.pop_size, self.best_err
        )
    }
}

/// Progress notifications streamed to the `run_with` callback, in order.
#[derive(Debug, Clone)]
pub enum SearchEvent {
    Started {
        name: String,
        num_vars: usize,
        objectives: Vec<String>,
        threads: usize,
        /// Island count (1 = single population).
        islands: usize,
    },
    /// A beacon was retrained and registered (name, retrain steps).
    BeaconCreated { name: String, retrain_steps: usize },
    Generation(GenerationLog),
    /// Island-model migration: elites copied between islands.
    Migration { generation: usize, from: usize, to: usize, accepted: usize },
    /// Distributed mode: a worker accepted ownership of these global
    /// island indices.
    ShardAssigned { worker: usize, islands: Vec<usize> },
    /// Distributed mode: a worker died or timed out; its islands move to
    /// the survivors and the current round replays from the last
    /// migration snapshot (`retry` counts re-shards so far).
    ShardLost { worker: usize, islands: Vec<usize>, retry: usize },
    Finished {
        evaluations: usize,
        pareto: usize,
        wall_secs: f64,
        /// Nadir-referenced hypervolume of the final front (2/3 objectives).
        hypervolume: Option<f64>,
    },
}

pub struct SearchOutcome {
    pub spec_name: String,
    /// Report labels of the objectives, in order — platform-bound ones
    /// carry their binding (`-speedup@silago`), so multi-platform fronts
    /// stay interpretable.
    pub objective_names: Vec<String>,
    pub rows: Vec<SolutionRow>,
    pub history: Vec<GenerationLog>,
    pub evaluations: usize,
    /// Service executions during this run's window (delta of the shared
    /// counters — a reused session carries its cache across runs). NOTE:
    /// on a session shared by CONCURRENT runs this is a service-wide
    /// window delta, so it includes activity the other in-flight runs
    /// performed meanwhile; it is exact when runs are serial.
    pub exec_calls: usize,
    /// Cache hits during this run's window (same delta semantics as
    /// `exec_calls`). On a reused session this includes hits on entries
    /// earlier requests populated — the cross-request-reuse signal.
    pub cache_hits: usize,
    /// Cumulative service counters at the end of this run (cross-run
    /// totals plus the cache-poisoning marker).
    pub eval_stats: EvalStats,
    pub beacons: Vec<(String, usize)>,
    /// All evaluation records (figures 9/10 scatter data).
    pub records: Vec<super::problem::EvalRecord>,
    pub baseline_val_err: f64,
    pub baseline_test_err: f64,
    pub wall_secs: f64,
    /// Nadir-referenced hypervolume of the final front (the deduplicated
    /// non-dominated merge across islands); None for >3 objectives.
    pub front_hypervolume: Option<f64>,
}

/// A reusable handle for running MOHAQ searches over one artifact bundle.
/// `run_with` is `&self` and thread-safe: serve mode shares one session
/// (one compiled executable, one PTQ cache) across concurrent requests.
pub struct SearchSession {
    arts: Arc<Artifacts>,
    /// `None` for synthetic sessions: the surrogate evaluator needs no
    /// PJRT client, and the hermetic fallback must not pay for (or fail
    /// on) one.
    rt: Option<Runtime>,
    eval: Arc<EvalService>,
    threads: usize,
    /// When set, candidate evaluations go through this long-lived shared
    /// pool instead of per-batch scoped threads (serve mode: batches from
    /// every in-flight search interleave as one job stream).
    queue: Option<Arc<WorkQueue>>,
}

impl SearchSession {
    /// Create a session with its own PJRT CPU runtime and an auto-sized
    /// evaluation thread pool (one worker per core).
    pub fn new(arts: Arc<Artifacts>) -> Result<SearchSession, SearchError> {
        let rt = Runtime::cpu().map_err(SearchError::eval)?;
        SearchSession::with_runtime(arts, rt)
    }

    /// Create a session around an existing runtime. Compiles the eval
    /// executable once; every `run` on this session shares it and the
    /// PTQ result cache.
    pub fn with_runtime(arts: Arc<Artifacts>, rt: Runtime) -> Result<SearchSession, SearchError> {
        let eval = EvalService::new(&rt, arts.clone())
            .context("creating eval service")
            .map_err(SearchError::eval)?;
        Ok(SearchSession {
            arts,
            rt: Some(rt),
            eval: Arc::new(eval),
            threads: pool::default_threads(),
            queue: None,
        })
    }

    /// Hermetic session: synthetic in-memory artifacts scored by the
    /// closed-form surrogate evaluator (`EvalService::surrogate`) — no
    /// AOT bundle, no files, and no PJRT runtime (the surrogate never
    /// executes a graph, so the fallback cannot fail on client startup).
    /// Serve mode and CI fall back to this so the full search/serve
    /// stack runs end to end offline. Beacon retraining runs through the
    /// pure [`SurrogateTrainer`], so beacon searches (including the
    /// distributed window schedule) are fully observable offline too.
    pub fn synthetic() -> Result<SearchSession, SearchError> {
        let arts = Arc::new(Artifacts::synthetic());
        let eval = EvalService::surrogate(arts.clone())
            .context("creating surrogate eval service")
            .map_err(SearchError::eval)?;
        Ok(SearchSession {
            arts,
            rt: None,
            eval: Arc::new(eval),
            threads: pool::default_threads(),
            queue: None,
        })
    }

    /// Set the evaluation worker count (0 = auto; 1 = sequential). The
    /// front is identical for every value — this only trades wall clock.
    pub fn threads(mut self, threads: usize) -> SearchSession {
        self.threads = if threads == 0 { pool::default_threads() } else { threads };
        self
    }

    /// Route candidate evaluations through a long-lived shared worker
    /// pool. Fronts stay bitwise-identical to the scoped-thread path —
    /// only the scheduling substrate changes.
    pub fn shared_queue(mut self, queue: Arc<WorkQueue>) -> SearchSession {
        self.queue = Some(queue);
        self
    }

    pub fn artifacts(&self) -> &Arc<Artifacts> {
        &self.arts
    }

    /// The PJRT runtime; `None` on synthetic (surrogate) sessions.
    pub fn runtime(&self) -> Option<&Runtime> {
        self.rt.as_ref()
    }

    /// The shared evaluation service (cumulative cross-run stats live
    /// here: `eval().stats()`).
    pub fn eval(&self) -> &Arc<EvalService> {
        &self.eval
    }

    /// Run a search, discarding progress events.
    pub fn run(&self, spec: &ExperimentSpec) -> Result<SearchOutcome, SearchError> {
        self.run_with(spec, |_| {})
    }

    /// Run a search, streaming `SearchEvent`s to `on_event` as the search
    /// progresses (generation lines, beacon creations).
    pub fn run_with(
        &self,
        spec: &ExperimentSpec,
        on_event: impl FnMut(&SearchEvent),
    ) -> Result<SearchOutcome, SearchError> {
        self.run_with_cancel(spec, on_event, &CancelToken::new())
    }

    /// `run_with` plus cooperative cancellation: when `cancel` trips, the
    /// search aborts at its next evaluation batch and returns
    /// `SearchError::Cancelled`.
    pub fn run_with_cancel(
        &self,
        spec: &ExperimentSpec,
        on_event: impl FnMut(&SearchEvent),
        cancel: &CancelToken,
    ) -> Result<SearchOutcome, SearchError> {
        self.run_checkpointed(spec, on_event, None, cancel)
    }

    /// `run_with_cancel` plus a checkpoint sink: at every migration
    /// boundary of an island-model search the sink receives
    /// `(generation, snapshots, beacon_snapshots)` — the state
    /// `run_resumed` (or `store::SearchCheckpoint`) continues bitwise.
    /// Single-population specs have no boundaries, so the sink never
    /// fires there. Island+beacon runs use the WINDOW schedule: beacons
    /// are created only at migration boundaries from that boundary's
    /// elites (mid-window candidates share the finalized sets), which is
    /// what makes both checkpoints and distributed sharding exact —
    /// beacon state is a pure function of the boundary stream. Resuming
    /// a beacon checkpoint needs the eval store the run saved alongside
    /// it (the parameter sets themselves live there, not in the
    /// checkpoint).
    pub fn run_checkpointed(
        &self,
        spec: &ExperimentSpec,
        mut on_event: impl FnMut(&SearchEvent),
        mut checkpoint: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])>,
        cancel: &CancelToken,
    ) -> Result<SearchOutcome, SearchError> {
        let t0 = std::time::Instant::now();
        let arts = self.arts.clone();
        let eval = self.eval.clone();
        // Per-run stats are deltas against the shared service counters
        // (one cache serves every run of this session).
        let stats0 = eval.stats();
        let mut problem = self.base_problem(spec, cancel.clone())?;

        let island_cfg = spec.island.clone();
        // Island + beacon searches run the window schedule (share-only
        // mid-window, creation at boundaries); single-population beacon
        // searches keep the classic per-batch Algorithm 1 schedule.
        let windowed =
            spec.beacon.is_some() && island_cfg.as_ref().is_some_and(|c| c.islands > 1);
        let beacon_sink = Arc::new(Mutex::new(Vec::new()));
        if let Some(policy) = beacon_policy_for(&arts, spec) {
            let mode = if windowed { BeaconMode::ShareOnly } else { BeaconMode::PerBatch };
            problem.trainer = Some(self.retrainer(spec)?);
            problem.beacons =
                Some(BeaconManager::new(policy).with_mode(mode).with_sink(beacon_sink.clone()));
        }

        on_event(&SearchEvent::Started {
            name: spec.name.clone(),
            num_vars: problem.num_vars(),
            objectives: problem.objective_names(),
            // The ACTIVE evaluator's worker count: the shared serve-mode
            // pool when routed there, the session's scoped-thread setting
            // otherwise.
            threads: problem.evaluator.workers(),
            islands: spec.island.as_ref().map_or(1, |c| c.islands),
        });

        let mut history: Vec<GenerationLog> = Vec::new();
        // Evaluation failures trip the problem's typed-error fuse (no
        // worker-pool panics); the catch_unwind stays as a backstop for
        // engine bugs and poisoned-lock classification.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            match &island_cfg {
                // K > 1 with beacons: the window-scheduled driver —
                // beacon creation happens only at migration boundaries,
                // the exact schedule a distributed worker fleet (and a
                // checkpoint resume) reproduces.
                Some(cfg) if cfg.islands > 1 && windowed => {
                    match drive_islands(
                        spec,
                        cfg,
                        &mut problem,
                        None,
                        &beacon_sink,
                        &mut history,
                        &mut on_event,
                        checkpoint.take(),
                    ) {
                        Ok(r) => r,
                        Err(e) => {
                            if problem.failure.is_none() {
                                problem.failure = Some(e);
                            }
                            (Vec::new(), 0)
                        }
                    }
                }
                // K > 1: island-model search over the same problem; all
                // islands share the EvalService cache through it.
                Some(cfg) if cfg.islands > 1 => {
                    let mut model = IslandModel::new(spec.ga.clone(), cfg.clone());
                    // IslandModel's sink carries no beacon payload; adapt
                    // (beacon checkpoints only exist on the windowed and
                    // single-population paths, and single-population
                    // specs have no boundaries).
                    let mut taken = checkpoint.take();
                    let has_ck = taken.is_some();
                    let mut adapt = |g: usize, s: &[IslandSnapshot]| {
                        if let Some(c) = taken.as_deref_mut() {
                            c(g, s, &[]);
                        }
                    };
                    let ck2: Option<&mut dyn FnMut(usize, &[IslandSnapshot])> =
                        if has_ck { Some(&mut adapt) } else { None };
                    let pop = model.run_with_checkpoints(
                        &mut problem,
                        |event| match event {
                            IslandEvent::Generation { island, stats } => emit_generation(
                                &beacon_sink,
                                &mut history,
                                &mut on_event,
                                Some(*island),
                                stats.generation,
                                stats.evaluations,
                                stats.population,
                            ),
                            IslandEvent::Migration { generation, from, to, accepted } => {
                                on_event(&SearchEvent::Migration {
                                    generation: *generation,
                                    from: *from,
                                    to: *to,
                                    accepted: *accepted,
                                });
                            }
                        },
                        ck2,
                    );
                    (pop, model.evaluations())
                }
                _ => {
                    let mut algo = Nsga2::new(spec.ga.clone());
                    let pop = algo.run(&mut problem, |stats| {
                        emit_generation(
                            &beacon_sink,
                            &mut history,
                            &mut on_event,
                            None,
                            stats.generation,
                            stats.evaluations,
                            stats.population,
                        );
                    });
                    (pop, algo.evaluations())
                }
            }
        }));
        let (pop, evaluations) = match run {
            Ok(result) => result,
            // A poisoned shared cache gets its own variant so callers
            // can tell worker crashes from evaluation failures.
            Err(payload) => return Err(SearchError::from_panic(pool::panic_message(payload))),
        };
        // Evaluation failures trip the problem's fuse instead of
        // panicking in the worker pool; surface the stored typed error
        // now that the engine has unwound.
        if let Some(e) = problem.failure.take() {
            return Err(e);
        }
        // The engine may also have stopped via `Problem::aborted` between
        // generations, before any batch saw the token — a cancelled run
        // never reports a (partial) front.
        if cancel.is_cancelled() {
            return Err(SearchError::Cancelled);
        }

        // ---- Post-process the Pareto set into report rows ----------------
        // The merged front: deduplicated non-dominated feasible subset of
        // the concatenated island populations (or the single population).
        let set = Nsga2::pareto_set(&pop);
        let front_hv = front_hypervolume(&set);
        let rows = if windowed {
            // Window schedule: the parameter-set assignment is re-derived
            // from the FINAL beacon list by the share rule — the same
            // pure computation the distributed merge performs, so both
            // produce identical rows from identical fronts.
            let set_map = problem.beacon_set_map(&set)?;
            assemble_rows(&problem, &set, &set_map)?
        } else {
            // Latest record per genome tells us which parameter set
            // scored it.
            let mut set_of: HashMap<Vec<i64>, usize> = HashMap::new();
            for r in &problem.records {
                set_of.insert(r.genome.clone(), r.set_idx);
            }
            assemble_rows(&problem, &set, &set_of)?
        };

        let stats = problem.eval.stats();
        let outcome = SearchOutcome {
            spec_name: spec.name.clone(),
            objective_names: problem.objective_names(),
            rows,
            history,
            evaluations,
            exec_calls: stats.executions - stats0.executions,
            cache_hits: stats.cache_hits - stats0.cache_hits,
            eval_stats: stats,
            beacons: problem.beacon_outcomes(),
            records: problem.records,
            baseline_val_err: arts.baseline.val_err_16bit,
            baseline_test_err: arts.baseline.test_err,
            wall_secs: t0.elapsed().as_secs_f64(),
            front_hypervolume: front_hv,
        };
        on_event(&SearchEvent::Finished {
            evaluations: outcome.evaluations,
            pareto: outcome.rows.len(),
            wall_secs: outcome.wall_secs,
            hypervolume: outcome.front_hypervolume,
        });
        Ok(outcome)
    }

    /// Distributed sibling of `run_with_cancel`: shard the spec's island
    /// model across the worker processes at `workers` (started with
    /// `mohaq worker`; see the `dist` module). Same spec, same seed, same
    /// front — bitwise — as the in-process island run.
    pub fn run_distributed(
        &self,
        spec: &ExperimentSpec,
        workers: &[String],
        config: &crate::dist::DistConfig,
        on_event: impl FnMut(&SearchEvent),
        cancel: &CancelToken,
    ) -> Result<SearchOutcome, SearchError> {
        crate::dist::run_search(self, spec, workers, config, on_event, cancel)
    }

    /// Distributed sibling of `run_resumed`/`run_checkpointed`: `resume`
    /// (a checkpoint's `(generation, snapshots, beacon_snapshots)`) seeds
    /// the fleet's replay state — workers are assigned their shards
    /// pre-restored, restored beacon sets re-replicate to every shard,
    /// and rounds at or before the boundary are skipped; `checkpoint`
    /// receives every migration boundary the coordinator completes, so a
    /// coordinator crash mid-distributed-run is recoverable from the
    /// latest written boundary.
    #[allow(clippy::too_many_arguments)]
    pub fn run_distributed_resumable(
        &self,
        spec: &ExperimentSpec,
        workers: &[String],
        config: &crate::dist::DistConfig,
        resume: Option<(usize, Vec<IslandSnapshot>, Vec<BeaconSnapshot>)>,
        checkpoint: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])>,
        on_event: impl FnMut(&SearchEvent),
        cancel: &CancelToken,
    ) -> Result<SearchOutcome, SearchError> {
        crate::dist::run_search_resumable(
            self, spec, workers, config, resume, checkpoint, on_event, cancel,
        )
    }

    /// Continue an island-model search from a migration-boundary
    /// checkpoint: `snapshots` must cover islands `0..K` in ascending
    /// order, captured at `generation` by a checkpoint sink. The
    /// remainder of the search replays the uninterrupted run's exact
    /// stream — island RNG positions, populations and evaluation budgets
    /// come from the snapshots, and everything downstream is
    /// deterministic — so the merged front is bitwise-identical to the
    /// run that was interrupted. `checkpoint` keeps receiving later
    /// boundaries, so an interrupted resume can itself be resumed.
    ///
    /// `beacons` restores a beacon-enabled run's manager: each snapshot
    /// names its parameter set, which must already be registered in this
    /// session's eval store (load the `--store` the run saved) — resume
    /// fails with a typed error when a set is missing, never silently.
    #[allow(clippy::too_many_arguments)]
    pub fn run_resumed(
        &self,
        spec: &ExperimentSpec,
        generation: usize,
        snapshots: Vec<IslandSnapshot>,
        beacons: Vec<BeaconSnapshot>,
        mut on_event: impl FnMut(&SearchEvent),
        mut checkpoint: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])>,
        cancel: &CancelToken,
    ) -> Result<SearchOutcome, SearchError> {
        let t0 = std::time::Instant::now();
        let cfg = match &spec.island {
            Some(c) if c.islands > 1 => c.clone(),
            _ => {
                return Err(SearchError::invalid(
                    "resume needs an island-model spec with >= 2 islands (checkpoints \
                     only exist at migration boundaries)",
                ))
            }
        };
        let k = cfg.islands;
        if snapshots.len() != k || snapshots.iter().enumerate().any(|(i, s)| s.island != i) {
            return Err(SearchError::invalid(format!(
                "resume needs snapshots covering all {k} islands in ascending order"
            )));
        }
        if generation == 0
            || generation > spec.ga.generations
            || generation % cfg.migration_interval != 0
        {
            return Err(SearchError::invalid(format!(
                "generation {generation} is not a migration boundary of this spec \
                 (interval {}, {} generations)",
                cfg.migration_interval, spec.ga.generations
            )));
        }
        if !beacons.is_empty() && spec.beacon.is_none() {
            return Err(SearchError::invalid(
                "checkpoint carries beacon state but the spec has no beacon policy",
            ));
        }
        let stats0 = self.eval.stats();
        let mut problem = self.shard_problem(spec, cancel.clone())?;
        let beacon_sink = Arc::new(Mutex::new(Vec::new()));
        if let Some(mgr) = problem.beacons.take() {
            // Re-arm the share-only shard manager for coordinator duty:
            // restore the checkpointed beacons against the eval store,
            // stream creations, retrain future windows.
            let mut mgr = mgr.with_sink(beacon_sink.clone());
            mgr.restore(&beacons, self.eval.param_store().as_ref())
                .map_err(|e| SearchError::invalid(e.to_string()))?;
            problem.trainer = Some(self.retrainer(spec)?);
            problem.beacons = Some(mgr);
        }
        on_event(&SearchEvent::Started {
            name: spec.name.clone(),
            num_vars: problem.num_vars(),
            objectives: problem.objective_names(),
            threads: problem.evaluator.workers(),
            islands: k,
        });

        let mut history: Vec<GenerationLog> = Vec::new();
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            drive_islands(
                spec,
                &cfg,
                &mut problem,
                Some((generation, snapshots)),
                &beacon_sink,
                &mut history,
                &mut on_event,
                checkpoint.take(),
            )
        }));
        let result = match run {
            Ok(result) => result,
            Err(payload) => return Err(SearchError::from_panic(pool::panic_message(payload))),
        };
        if let Some(e) = problem.failure.take() {
            return Err(e);
        }
        let (pop, evaluations) = result?;
        if cancel.is_cancelled() {
            return Err(SearchError::Cancelled);
        }
        let set = Nsga2::pareto_set(&pop);
        let front_hv = front_hypervolume(&set);
        // Same pure share-rule assignment the distributed merge and the
        // windowed single-process run use (empty map when no beacons).
        let set_map = problem.beacon_set_map(&set)?;
        let rows = assemble_rows(&problem, &set, &set_map)?;
        let stats = problem.eval.stats();
        let outcome = SearchOutcome {
            spec_name: spec.name.clone(),
            objective_names: problem.objective_names(),
            rows,
            history,
            evaluations,
            exec_calls: stats.executions - stats0.executions,
            cache_hits: stats.cache_hits - stats0.cache_hits,
            eval_stats: stats,
            beacons: problem.beacon_outcomes(),
            records: problem.records,
            baseline_val_err: self.arts.baseline.val_err_16bit,
            baseline_test_err: self.arts.baseline.test_err,
            wall_secs: t0.elapsed().as_secs_f64(),
            front_hypervolume: front_hv,
        };
        on_event(&SearchEvent::Finished {
            evaluations: outcome.evaluations,
            pareto: outcome.rows.len(),
            wall_secs: outcome.wall_secs,
            hypervolume: outcome.front_hypervolume,
        });
        Ok(outcome)
    }

    /// The retraining engine for beacon creation: the real PJRT
    /// binary-connect loop when the session has a runtime, the pure
    /// surrogate stand-in on synthetic sessions. Both fork per-beacon RNG
    /// streams that are pure functions of (seed, beacon index), so the
    /// trained parameters are identical for any scheduling order.
    pub(crate) fn retrainer(&self, spec: &ExperimentSpec) -> Result<Retrainer, SearchError> {
        let seed = spec.ga.seed ^ 0xbeac0;
        Ok(match &self.rt {
            Some(rt) => Retrainer::Pjrt(
                Trainer::new(rt, self.arts.clone(), seed).map_err(SearchError::eval)?,
            ),
            None => Retrainer::Surrogate(SurrogateTrainer::new(seed)),
        })
    }

    /// Resolve `spec` into the evaluation problem (no beacon machinery
    /// attached — `run_checkpointed` and `shard_problem` bolt that on).
    fn base_problem(
        &self,
        spec: &ExperimentSpec,
        cancel: CancelToken,
    ) -> Result<MohaqProblem, SearchError> {
        let (objectives, bindings) = spec.resolve_objectives()?;
        // The genome obeys the INTERSECTION of platform restrictions: any
        // tying platform ties it, and the floor precision is the highest
        // minimum across bindings (SiLago lacks 2-bit => 2).
        let tied = spec.tied.unwrap_or_else(|| bindings.iter().any(|b| b.platform.tied_wa()));
        let mut gene_min = 1;
        for b in &bindings {
            // The registry rejects empty supported_bits at resolve time;
            // keep a typed error here as defense in depth (a long-lived
            // server must not panic on a hand-built binding).
            let min = b
                .platform
                .supported_bits()
                .iter()
                .map(|bit| bit.to_gene())
                .min()
                .ok_or_else(|| {
                    SearchError::invalid(format!(
                        "platform '{}' declares no supported precisions",
                        b.name
                    ))
                })?;
            gene_min = gene_min.max(min);
        }
        let err_limit = self.arts.baseline.val_err_16bit + spec.err_feasible_pp / 100.0;
        let evaluator = match &self.queue {
            Some(q) => EvalStrategy::Shared(q.clone()),
            None => EvalStrategy::Threads(self.threads),
        };
        Ok(MohaqProblem {
            arts: self.arts.clone(),
            eval: self.eval.clone(),
            trainer: None,
            beacons: None,
            bindings,
            objectives,
            tied,
            err_limit,
            gene_min,
            evaluator,
            cancel,
            records: Vec::new(),
            failure: None,
        })
    }

    /// The problem a distributed shard (worker or coordinator) evaluates
    /// against. Beacon specs get a SHARE-ONLY manager: candidates
    /// re-evaluate against finalized (replicated) beacon sets by the
    /// log2-distance rule, but the shard never plans fresh beacons —
    /// creation stays on the coordinator's boundary window pass, which
    /// keeps Algorithm 1's order-dependent selection in one process.
    pub(crate) fn shard_problem(
        &self,
        spec: &ExperimentSpec,
        cancel: CancelToken,
    ) -> Result<MohaqProblem, SearchError> {
        let mut problem = self.base_problem(spec, cancel)?;
        if let Some(policy) = beacon_policy_for(&self.arts, spec) {
            problem.beacons = Some(BeaconManager::new(policy).with_mode(BeaconMode::ShareOnly));
        }
        Ok(problem)
    }

    /// Run NSGA-II over any artifact-free `SyncProblem` with `threads`
    /// evaluation workers (0 = one per core) — the generic half of the
    /// session's parallel plumbing, exposed for smoke tests and engine
    /// benchmarks.
    pub fn run_generic<P: SyncProblem>(
        problem: &P,
        ga: Nsga2Config,
        threads: usize,
    ) -> Vec<Individual> {
        let mut wrapped =
            if threads == 0 { Parallel::auto(problem) } else { Parallel::new(problem, threads) };
        let mut algo = Nsga2::new(ga);
        let pop = algo.run(&mut wrapped, |_| {});
        Nsga2::pareto_set(&pop)
    }

    /// Island-model sibling of `run_generic`: K lockstep islands over any
    /// `SyncProblem` with `threads` evaluation workers (0 = one per
    /// core); returns the deduplicated merged front. Bitwise-identical
    /// for any thread count at a fixed (seed, island config).
    pub fn run_generic_islands<P: SyncProblem>(
        problem: &P,
        ga: Nsga2Config,
        island: IslandConfig,
        threads: usize,
    ) -> Vec<Individual> {
        let mut wrapped =
            if threads == 0 { Parallel::auto(problem) } else { Parallel::new(problem, threads) };
        let mut model = IslandModel::new(ga, island);
        let pop = model.run(&mut wrapped, |_| {});
        Nsga2::pareto_set(&pop)
    }
}

/// Resolve the spec's beacon overrides against the artifact defaults;
/// `None` when the spec has beacons off.
pub(crate) fn beacon_policy_for(arts: &Artifacts, spec: &ExperimentSpec) -> Option<BeaconPolicy> {
    let ov = spec.beacon.as_ref()?;
    let mut policy =
        BeaconPolicy::paper_defaults(arts.baseline.val_err_16bit, arts.baseline.beacon_lr as f32);
    if let Some(t) = ov.threshold {
        policy.threshold = t;
    }
    if let Some(s) = ov.retrain_steps {
        policy.retrain_steps = s;
    }
    if let Some(m) = ov.max_beacons {
        policy.max_beacons = m;
    }
    Some(policy)
}

/// The manual island driver behind both the windowed (island + beacon)
/// search and checkpoint resume: one `IslandShard` owns every island, so
/// `elites()` is already in global island order and the exchange below
/// is exactly `IslandModel::migrate`'s schedule. At each migration
/// boundary, BEFORE the exchange, the beacon window pass runs over the
/// boundary elites (a no-op without a beacon manager) — the same
/// boundary-synchronized schedule the distributed coordinator runs, so
/// fronts merge bitwise-identical across all three paths. `resume`
/// restores from a checkpoint `(generation, snapshots)`; window passes
/// at or before that boundary are skipped (their beacons came back
/// through the checkpoint).
#[allow(clippy::too_many_arguments)]
fn drive_islands(
    spec: &ExperimentSpec,
    cfg: &IslandConfig,
    problem: &mut MohaqProblem,
    resume: Option<(usize, Vec<IslandSnapshot>)>,
    beacon_sink: &Mutex<Vec<(String, usize)>>,
    history: &mut Vec<GenerationLog>,
    on_event: &mut dyn FnMut(&SearchEvent),
    mut checkpoint: Option<&mut dyn FnMut(usize, &[IslandSnapshot], &[BeaconSnapshot])>,
) -> Result<(Vec<Individual>, usize), SearchError> {
    let k = cfg.islands;
    let (mut shard, start_gen) = match resume {
        Some((gen, snaps)) => (
            IslandShard::restore(spec.ga.clone(), cfg.clone(), gen, snaps)
                .map_err(SearchError::invalid)?,
            gen,
        ),
        None => {
            let indices: Vec<usize> = (0..k).collect();
            (
                IslandShard::new(spec.ga.clone(), cfg.clone(), &indices)
                    .map_err(SearchError::invalid)?,
                0,
            )
        }
    };
    let mut windows_done = start_gen;
    if !shard.seeded() {
        shard.seed(problem);
        for local in 0..k {
            emit_generation(
                beacon_sink,
                history,
                on_event,
                Some(local),
                0,
                shard.engine_evaluations(local),
                &shard.pops()[local],
            );
        }
    }
    for gen in start_gen + 1..=spec.ga.generations {
        if problem.aborted() {
            break;
        }
        shard.step(problem);
        let boundary = gen % cfg.migration_interval == 0;
        if boundary {
            let elites = shard.elites();
            if gen > windows_done {
                let groups: Vec<&[Individual]> =
                    elites.iter().map(|(_, g)| g.as_slice()).collect();
                problem.run_beacon_window(&groups)?;
                windows_done = gen;
            }
            for to in 0..k {
                for from in cfg.topology.sources(k, to) {
                    if let Some(accepted) = shard.inject(to, &elites[from].1) {
                        if accepted > 0 {
                            on_event(&SearchEvent::Migration {
                                generation: gen,
                                from,
                                to,
                                accepted,
                            });
                        }
                    }
                }
            }
        }
        for local in 0..k {
            let evals = shard.engine_evaluations(local);
            emit_generation(
                beacon_sink,
                history,
                on_event,
                Some(local),
                gen,
                evals,
                &shard.pops()[local],
            );
        }
        if boundary {
            if let Some(sink) = checkpoint.as_deref_mut() {
                let bsnaps = problem.beacon_snapshots()?;
                sink(gen, &shard.snapshot(), &bsnaps);
            }
        }
    }
    let pop: Vec<Individual> = shard.pops().iter().flatten().cloned().collect();
    Ok((pop, shard.evaluations()))
}

/// Score a final Pareto set into report rows — shared by the in-process
/// and distributed paths so both produce identical tables for identical
/// fronts. `set_of` maps genome → parameter-set index (empty map = the
/// baseline set everywhere: the non-beacon case).
pub(crate) fn assemble_rows(
    problem: &MohaqProblem,
    set: &[Individual],
    set_of: &HashMap<Vec<i64>, usize>,
) -> Result<Vec<SolutionRow>, SearchError> {
    let mut rows = Vec::with_capacity(set.len());
    for ind in set {
        let qc = problem.try_decode(&ind.genome)?;
        let set_idx = *set_of.get(&ind.genome).unwrap_or(&0);
        let wer_v = problem.eval.val_error(&qc, set_idx).map_err(SearchError::eval)?;
        let wer_t = problem.eval.test_error(&qc, set_idx).map_err(SearchError::eval)?;
        let model = &problem.arts.model;
        let hw: Vec<HwMetrics> = problem
            .bindings
            .iter()
            .map(|b| HwMetrics {
                platform: b.name.clone(),
                speedup: b.platform.speedup(model, &qc),
                energy_uj: b.platform.energy_pj(model, &qc).map(|pj| pj / 1e6),
            })
            .collect();
        rows.push(SolutionRow {
            cp_r: model.compression_ratio(&qc.w_bits),
            size_mb: model.size_bytes(&qc.w_bits) / (1024.0 * 1024.0),
            speedup: hw.first().map(|h| h.speedup),
            energy_uj: hw.first().and_then(|h| h.energy_uj),
            param_set: problem
                .eval
                .param_set(set_idx)
                .map_err(SearchError::eval)?
                .name
                .clone(),
            hw,
            qc,
            wer_v,
            wer_t,
        });
    }
    sort_rows_nan_last(&mut rows);
    Ok(rows)
}

/// Order report rows by validation error, NaN rows last. A degenerate
/// evaluation (e.g. an all-NaN surrogate or a broken artifact) used to
/// panic the whole session here via `partial_cmp(..).unwrap()` — fatal
/// for a long-lived server. NaN rows are kept (visible in the report)
/// but sort after every real number.
pub(crate) fn sort_rows_nan_last(rows: &mut [SolutionRow]) {
    rows.sort_by(|a, b| match (a.wer_v.is_nan(), b.wer_v.is_nan()) {
        (false, false) => a.wer_v.partial_cmp(&b.wer_v).unwrap_or(Ordering::Equal),
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
    });
}

/// Drain pending beacon notifications, then emit one generation summary
/// and record it in the history — shared by the single-population and
/// island paths so both stream identical event shapes.
fn emit_generation(
    beacon_sink: &Mutex<Vec<(String, usize)>>,
    history: &mut Vec<GenerationLog>,
    on_event: &mut dyn FnMut(&SearchEvent),
    island: Option<usize>,
    generation: usize,
    evaluations: usize,
    population: &[Individual],
) {
    // Beacons created during this generation stream first, so the
    // callback sees them before the generation summary they shaped.
    let created: Vec<(String, usize)> =
        beacon_sink.lock().expect("beacon sink poisoned").drain(..).collect();
    for (name, steps) in created {
        on_event(&SearchEvent::BeaconCreated { name, retrain_steps: steps });
    }
    let best_err = population
        .iter()
        .filter(|i| i.feasible())
        .map(|i| i.objectives[0])
        .fold(f64::INFINITY, f64::min);
    let feasible = population.iter().filter(|i| i.feasible()).count();
    let log = GenerationLog {
        generation,
        evaluations,
        best_err,
        feasible,
        pop_size: population.len(),
        island,
    };
    on_event(&SearchEvent::Generation(log.clone()));
    history.push(log);
}

/// Baseline rows (Base / Base_16bit) for the report tables.
pub fn baseline_rows(arts: &Artifacts) -> Vec<SolutionRow> {
    let n = arts.layer_names.len();
    let float_qc = QuantConfig::uniform(n, Bits::B32, Bits::B32);
    let qc16 = QuantConfig::uniform(n, Bits::B16, Bits::B16);
    vec![
        SolutionRow {
            qc: float_qc,
            wer_v: arts.baseline.val_err,
            wer_t: arts.baseline.test_err,
            cp_r: 1.0,
            size_mb: arts.model.baseline_size_bits() as f64 / 8.0 / (1024.0 * 1024.0),
            speedup: None,
            energy_uj: None,
            hw: Vec::new(),
            param_set: "baseline".into(),
        },
        SolutionRow {
            qc: qc16.clone(),
            wer_v: arts.baseline.val_err_16bit,
            wer_t: arts.baseline.test_err,
            cp_r: arts.model.compression_ratio(&qc16.w_bits),
            size_mb: arts.model.size_bytes(&qc16.w_bits) / (1024.0 * 1024.0),
            speedup: Some(1.0),
            energy_uj: None,
            hw: Vec::new(),
            param_set: "baseline".into(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(wer_v: f64) -> SolutionRow {
        SolutionRow {
            qc: QuantConfig::uniform(2, Bits::B8, Bits::B8),
            wer_v,
            wer_t: wer_v,
            cp_r: 4.0,
            size_mb: 1.0,
            speedup: None,
            energy_uj: None,
            hw: Vec::new(),
            param_set: "baseline".into(),
        }
    }

    #[test]
    fn final_report_sort_survives_nan_rows() {
        // Regression: `partial_cmp(..).unwrap()` panicked on the first NaN
        // from a degenerate evaluation. NaN rows now sort last; real rows
        // keep ascending order.
        let mut rows = vec![row(0.30), row(f64::NAN), row(0.10), row(f64::NAN), row(0.20)];
        sort_rows_nan_last(&mut rows);
        let order: Vec<f64> = rows.iter().map(|r| r.wer_v).collect();
        assert_eq!(&order[..3], &[0.10, 0.20, 0.30]);
        assert!(order[3].is_nan() && order[4].is_nan());
    }

    #[test]
    fn session_is_send_sync() {
        // Serve mode shares one session across connection threads.
        fn check<T: Send + Sync>() {}
        check::<SearchSession>();
        check::<CancelToken>();
    }

    #[test]
    fn cancel_token_round_trip() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        let clone = t.clone();
        clone.cancel();
        assert!(t.is_cancelled(), "clones share the flag");
    }
}
