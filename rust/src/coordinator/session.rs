//! `SearchSession`: the public entry point of the MOHAQ search. A session
//! owns the shared artifacts (`Arc<Artifacts>`) and the PJRT runtime,
//! evaluates each generation's population in parallel across a thread
//! pool, streams progress through a `SearchEvent` callback, and returns a
//! typed `SearchError` at the API boundary. It replaces the old one-shot
//! `run_search` free function; re-running `run` on the same session reuses
//! the runtime (each run compiles its own executable against the shared
//! client).
//!
//! Determinism contract: for a fixed spec (including seed), the resulting
//! front is bitwise-identical for ANY thread count — the parallel phase
//! computes order-independent pure values and the order-dependent beacon
//! phase stays sequential (see `MohaqProblem::evaluate_batch`).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Context;

use super::beacon::{BeaconManager, BeaconPolicy};
use super::error::SearchError;
use super::problem::MohaqProblem;
use super::spec::ExperimentSpec;
use super::trainer::Trainer;
use crate::eval::EvalService;
use crate::hw::Platform;
use crate::moo::{Individual, Nsga2, Nsga2Config, Parallel, Problem, SyncProblem};
use crate::quant::{Bits, QuantConfig};
use crate::runtime::{Artifacts, Runtime};
use crate::util::pool;

/// One row of a paper-style solutions table.
#[derive(Debug, Clone)]
pub struct SolutionRow {
    pub qc: QuantConfig,
    pub wer_v: f64,
    pub wer_t: f64,
    pub cp_r: f64,
    pub size_mb: f64,
    pub speedup: Option<f64>,
    pub energy_uj: Option<f64>,
    /// Which parameter set produced wer_v ("baseline" or a beacon name).
    pub param_set: String,
}

#[derive(Debug, Clone)]
pub struct GenerationLog {
    pub generation: usize,
    pub evaluations: usize,
    pub best_err: f64,
    pub feasible: usize,
    pub pop_size: usize,
}

/// One-line progress rendering shared by the CLI and every example driver.
impl std::fmt::Display for GenerationLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "  gen {:>3}  evals {:>4}  feasible {:>2}/{}  best WER_V {:.4}",
            self.generation, self.evaluations, self.feasible, self.pop_size, self.best_err
        )
    }
}

/// Progress notifications streamed to the `run_with` callback, in order.
#[derive(Debug, Clone)]
pub enum SearchEvent {
    Started { name: String, num_vars: usize, objectives: Vec<String>, threads: usize },
    /// A beacon was retrained and registered (name, retrain steps).
    BeaconCreated { name: String, retrain_steps: usize },
    Generation(GenerationLog),
    Finished { evaluations: usize, pareto: usize, wall_secs: f64 },
}

pub struct SearchOutcome {
    pub spec_name: String,
    pub rows: Vec<SolutionRow>,
    pub history: Vec<GenerationLog>,
    pub evaluations: usize,
    pub exec_calls: usize,
    pub cache_hits: usize,
    pub beacons: Vec<(String, usize)>,
    /// All evaluation records (figures 9/10 scatter data).
    pub records: Vec<super::problem::EvalRecord>,
    pub baseline_val_err: f64,
    pub baseline_test_err: f64,
    pub wall_secs: f64,
}

/// A reusable handle for running MOHAQ searches over one artifact bundle.
pub struct SearchSession {
    arts: Arc<Artifacts>,
    rt: Runtime,
    threads: usize,
}

impl SearchSession {
    /// Create a session with its own PJRT CPU runtime and an auto-sized
    /// evaluation thread pool (one worker per core).
    pub fn new(arts: Arc<Artifacts>) -> Result<SearchSession, SearchError> {
        let rt = Runtime::cpu().map_err(SearchError::eval)?;
        Ok(SearchSession::with_runtime(arts, rt))
    }

    /// Create a session around an existing runtime.
    pub fn with_runtime(arts: Arc<Artifacts>, rt: Runtime) -> SearchSession {
        SearchSession { arts, rt, threads: pool::default_threads() }
    }

    /// Set the evaluation worker count (0 = auto; 1 = sequential). The
    /// front is identical for every value — this only trades wall clock.
    pub fn threads(mut self, threads: usize) -> SearchSession {
        self.threads = if threads == 0 { pool::default_threads() } else { threads };
        self
    }

    pub fn artifacts(&self) -> &Arc<Artifacts> {
        &self.arts
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Run a search, discarding progress events.
    pub fn run(&self, spec: &ExperimentSpec) -> Result<SearchOutcome, SearchError> {
        self.run_with(spec, |_| {})
    }

    /// Run a search, streaming `SearchEvent`s to `on_event` as the search
    /// progresses (generation lines, beacon creations).
    pub fn run_with(
        &self,
        spec: &ExperimentSpec,
        mut on_event: impl FnMut(&SearchEvent),
    ) -> Result<SearchOutcome, SearchError> {
        let t0 = std::time::Instant::now();
        let arts = self.arts.clone();
        let eval = EvalService::new(&self.rt, arts.clone())
            .context("creating eval service")
            .map_err(SearchError::eval)?;
        let platform = spec.resolve_platform()?;
        let tied = spec
            .tied
            .unwrap_or_else(|| platform.as_ref().map(|p| p.tied_wa()).unwrap_or(false));
        let gene_min = platform
            .as_ref()
            .map(|p| p.supported_bits().iter().map(|b| b.to_gene()).min().unwrap())
            .unwrap_or(1);
        let err_limit = arts.baseline.val_err_16bit + spec.err_feasible_pp / 100.0;

        let beacon_sink = Arc::new(Mutex::new(Vec::new()));
        let (trainer, beacons) = if let Some(ov) = &spec.beacon {
            let mut policy = BeaconPolicy::paper_defaults(
                arts.baseline.val_err_16bit,
                arts.baseline.beacon_lr as f32,
            );
            if let Some(t) = ov.threshold {
                policy.threshold = t;
            }
            if let Some(s) = ov.retrain_steps {
                policy.retrain_steps = s;
            }
            if let Some(m) = ov.max_beacons {
                policy.max_beacons = m;
            }
            let trainer = Trainer::new(&self.rt, arts.clone(), spec.ga.seed ^ 0xbeac0)
                .map_err(SearchError::eval)?;
            (
                Some(trainer),
                Some(BeaconManager::new(policy).with_sink(beacon_sink.clone())),
            )
        } else {
            (None, None)
        };

        let mut problem = MohaqProblem {
            arts: arts.clone(),
            eval,
            trainer,
            beacons,
            platform,
            objectives: spec.objectives.clone(),
            tied,
            err_limit,
            gene_min,
            threads: self.threads,
            records: Vec::new(),
        };

        on_event(&SearchEvent::Started {
            name: spec.name.clone(),
            num_vars: problem.num_vars(),
            objectives: problem.objective_names(),
            threads: self.threads,
        });

        let mut algo = Nsga2::new(spec.ga.clone());
        let mut history: Vec<GenerationLog> = Vec::new();
        // The GA engine's Problem interface is infallible, so evaluation
        // failures surface as panics deep in the generation loop; catch
        // them here and honor the typed-error contract of the public API.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            algo.run(&mut problem, |stats| {
                // Beacons created during this generation stream first, so
                // the callback sees them before the generation summary
                // they shaped.
                let created: Vec<(String, usize)> = beacon_sink
                    .lock()
                    .expect("beacon sink poisoned")
                    .drain(..)
                    .collect();
                for (name, steps) in created {
                    on_event(&SearchEvent::BeaconCreated { name, retrain_steps: steps });
                }
                let best_err = stats
                    .population
                    .iter()
                    .filter(|i| i.feasible())
                    .map(|i| i.objectives[0])
                    .fold(f64::INFINITY, f64::min);
                let feasible = stats.population.iter().filter(|i| i.feasible()).count();
                let log = GenerationLog {
                    generation: stats.generation,
                    evaluations: stats.evaluations,
                    best_err,
                    feasible,
                    pop_size: stats.population.len(),
                };
                on_event(&SearchEvent::Generation(log.clone()));
                history.push(log);
            })
        }));
        let pop = match run {
            Ok(pop) => pop,
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "search evaluation panicked".into());
                return Err(SearchError::Eval(msg));
            }
        };

        // ---- Post-process the Pareto set into report rows ----------------
        let set = Nsga2::pareto_set(&pop);
        // Latest record per genome tells us which parameter set scored it.
        let mut set_of: HashMap<Vec<i64>, usize> = HashMap::new();
        for r in &problem.records {
            set_of.insert(r.genome.clone(), r.set_idx);
        }

        let mut rows = Vec::with_capacity(set.len());
        for ind in &set {
            let qc = problem.decode(&ind.genome);
            let set_idx = *set_of.get(&ind.genome).unwrap_or(&0);
            let wer_v = problem.eval.val_error(&qc, set_idx).map_err(SearchError::eval)?;
            let wer_t = problem.eval.test_error(&qc, set_idx).map_err(SearchError::eval)?;
            let model = &problem.arts.model;
            rows.push(SolutionRow {
                cp_r: model.compression_ratio(&qc.w_bits),
                size_mb: model.size_bytes(&qc.w_bits) / (1024.0 * 1024.0),
                speedup: problem.platform.as_ref().map(|p| p.speedup(model, &qc)),
                energy_uj: problem
                    .platform
                    .as_ref()
                    .and_then(|p| p.energy_pj(model, &qc))
                    .map(|pj| pj / 1e6),
                param_set: problem.eval.param_set(set_idx).name.clone(),
                qc,
                wer_v,
                wer_t,
            });
        }
        rows.sort_by(|a, b| a.wer_v.partial_cmp(&b.wer_v).unwrap());

        let stats = problem.eval.stats();
        let outcome = SearchOutcome {
            spec_name: spec.name.clone(),
            rows,
            history,
            evaluations: algo.evaluations(),
            exec_calls: stats.executions,
            cache_hits: stats.cache_hits,
            beacons: problem
                .beacons
                .as_ref()
                .map(|b| {
                    b.beacons
                        .iter()
                        .map(|bc| (bc.qc.display_wa(), bc.report.steps))
                        .collect()
                })
                .unwrap_or_default(),
            records: problem.records,
            baseline_val_err: arts.baseline.val_err_16bit,
            baseline_test_err: arts.baseline.test_err,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        on_event(&SearchEvent::Finished {
            evaluations: outcome.evaluations,
            pareto: outcome.rows.len(),
            wall_secs: outcome.wall_secs,
        });
        Ok(outcome)
    }

    /// Run NSGA-II over any artifact-free `SyncProblem` with `threads`
    /// evaluation workers — the generic half of the session's parallel
    /// plumbing, exposed for smoke tests and engine benchmarks.
    pub fn run_generic<P: SyncProblem>(
        problem: &P,
        ga: Nsga2Config,
        threads: usize,
    ) -> Vec<Individual> {
        let mut wrapped = Parallel::new(problem, threads.max(1));
        let mut algo = Nsga2::new(ga);
        let pop = algo.run(&mut wrapped, |_| {});
        Nsga2::pareto_set(&pop)
    }
}

/// Baseline rows (Base / Base_16bit) for the report tables.
pub fn baseline_rows(arts: &Artifacts) -> Vec<SolutionRow> {
    let n = arts.layer_names.len();
    let float_qc = QuantConfig::uniform(n, Bits::B32, Bits::B32);
    let qc16 = QuantConfig::uniform(n, Bits::B16, Bits::B16);
    vec![
        SolutionRow {
            qc: float_qc,
            wer_v: arts.baseline.val_err,
            wer_t: arts.baseline.test_err,
            cp_r: 1.0,
            size_mb: arts.model.baseline_size_bits() as f64 / 8.0 / (1024.0 * 1024.0),
            speedup: None,
            energy_uj: None,
            param_set: "baseline".into(),
        },
        SolutionRow {
            qc: qc16.clone(),
            wer_v: arts.baseline.val_err_16bit,
            wer_t: arts.baseline.test_err,
            cp_r: arts.model.compression_ratio(&qc16.w_bits),
            size_mb: arts.model.size_bytes(&qc16.w_bits) / (1024.0 * 1024.0),
            speedup: Some(1.0),
            energy_uj: None,
            param_set: "baseline".into(),
        },
    ]
}
