//! Typed errors at the public search API boundary. Internals keep using
//! `anyhow` for context-rich plumbing; `SearchSession` and the
//! `ExperimentSpec` builder translate to `SearchError` so callers can
//! match on failure classes instead of parsing strings.

use std::fmt;

use crate::hw::registry::RegistryError;

#[derive(Debug)]
pub enum SearchError {
    /// The spec names a platform the registry doesn't know.
    UnknownPlatform { name: String, known: Vec<String> },
    /// The spec is internally inconsistent (objective/platform mismatch,
    /// tied-W=A violation, empty objectives, ...).
    InvalidSpec(String),
    /// A config file failed to parse into a spec.
    Config(String),
    /// Artifact loading, PJRT execution or retraining failed; the message
    /// carries the flattened cause chain.
    Eval(String),
    /// Shared evaluation state (e.g. the EvalService result cache) was
    /// poisoned by a worker panic; partial results cannot be trusted.
    Poisoned(String),
    /// The search was cancelled through its `CancelToken` (serve mode:
    /// client `cancel` frame or disconnect) before producing a front.
    Cancelled,
    /// Distributed mode: a worker process died or timed out and the
    /// search could not recover (re-shard retry budget exhausted, or no
    /// workers left to take over the lost islands).
    WorkerLost(String),
}

impl SearchError {
    /// Wrap an internal `anyhow` failure, keeping its full cause chain.
    /// Poison-marked failures (the eval cache and param-set table return
    /// typed "poisoned" errors instead of panicking) classify as
    /// `Poisoned`, same as panic payloads caught at the session boundary.
    pub fn eval(e: anyhow::Error) -> SearchError {
        let msg = format!("{e:#}");
        if msg.contains("poisoned") {
            SearchError::Poisoned(msg)
        } else {
            SearchError::Eval(msg)
        }
    }

    pub fn invalid(msg: impl Into<String>) -> SearchError {
        SearchError::InvalidSpec(msg.into())
    }

    /// Classify a panic payload caught at the session boundary: poisoned
    /// shared state gets its own variant so callers can distinguish
    /// "a worker crashed and took the cache with it" from an evaluation
    /// failure.
    pub fn from_panic(msg: String) -> SearchError {
        if msg.contains("poisoned") {
            SearchError::Poisoned(msg)
        } else {
            SearchError::Eval(msg)
        }
    }

    /// Stable machine-readable class, used by the serve protocol's error
    /// frames (`{"event":"error","kind":...}`) so clients can match on
    /// failure classes without parsing messages.
    pub fn kind(&self) -> &'static str {
        match self {
            SearchError::UnknownPlatform { .. } => "unknown_platform",
            SearchError::InvalidSpec(_) => "invalid_spec",
            SearchError::Config(_) => "config",
            SearchError::Eval(_) => "eval",
            SearchError::Poisoned(_) => "poisoned",
            SearchError::Cancelled => "cancelled",
            SearchError::WorkerLost(_) => "worker_lost",
        }
    }
}

impl fmt::Display for SearchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SearchError::UnknownPlatform { name, known } => write!(
                f,
                "unknown platform '{name}' — registered platforms: {}",
                known.join(", ")
            ),
            SearchError::InvalidSpec(msg) => write!(f, "invalid experiment spec: {msg}"),
            SearchError::Config(msg) => write!(f, "config: {msg}"),
            SearchError::Eval(msg) => write!(f, "evaluation failed: {msg}"),
            SearchError::Poisoned(msg) => write!(f, "evaluation state poisoned: {msg}"),
            SearchError::Cancelled => write!(f, "search cancelled"),
            SearchError::WorkerLost(msg) => write!(f, "worker lost: {msg}"),
        }
    }
}

impl std::error::Error for SearchError {}

impl From<RegistryError> for SearchError {
    fn from(e: RegistryError) -> SearchError {
        match e {
            RegistryError::Unknown { name, known } => SearchError::UnknownPlatform { name, known },
            RegistryError::Invalid(msg) => SearchError::InvalidSpec(msg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_errors_map_to_typed_variants() {
        let e: SearchError = RegistryError::Unknown {
            name: "tpu".into(),
            known: vec!["silago".into(), "bitfusion".into()],
        }
        .into();
        assert!(matches!(e, SearchError::UnknownPlatform { .. }));
        assert!(e.to_string().contains("silago"));
    }

    #[test]
    fn panic_payloads_classify_poisoned_state() {
        let e = SearchError::from_panic("candidate evaluation failed: eval cache poisoned".into());
        assert!(matches!(e, SearchError::Poisoned(_)), "{e:?}");
        let e = SearchError::from_panic("candidate evaluation failed: device lost".into());
        assert!(matches!(e, SearchError::Eval(_)), "{e:?}");
    }

    #[test]
    fn eval_wrapper_classifies_poisoned_state() {
        // The fuse path (try_evaluate_batch -> SearchError::eval) must
        // type poisoned-lock failures the same way the panic boundary
        // does — `param sets poisoned` used to surface as plain Eval.
        let e = SearchError::eval(anyhow::anyhow!(
            "param sets poisoned: a worker panicked while holding the lock"
        ));
        assert!(matches!(e, SearchError::Poisoned(_)), "{e:?}");
        assert_eq!(e.kind(), "poisoned");
    }

    #[test]
    fn eval_wrapper_keeps_cause_chain() {
        use anyhow::Context;
        let inner: anyhow::Result<()> =
            Err(anyhow::anyhow!("device lost")).context("running generation 3");
        let e = SearchError::eval(inner.unwrap_err());
        assert_eq!(
            e.to_string(),
            "evaluation failed: running generation 3: device lost"
        );
    }
}
