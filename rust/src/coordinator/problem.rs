//! The MOHAQ optimization problem: glues genome decoding, the AOT error
//! evaluation (with optional beacon search), the analytical hardware
//! objectives and the per-platform SRAM constraints into a `moo::Problem`
//! NSGA-II can drive (paper Fig. 4).
//!
//! Objectives are typed [`BoundObjective`]s resolved against a
//! [`PlatformBinding`] table (PR 4 redesign): one search can mix hardware
//! objectives bound to DIFFERENT registered platforms, and every binding
//! contributes its own SRAM constraint (violations are summed).
//!
//! Generations are evaluated in two phases: the post-training-quantization
//! errors (the expensive PJRT executions) fan out across the session's
//! thread pool, then the order-dependent beacon logic (Algorithm 1) runs
//! sequentially over the precomputed errors. Both phases are deterministic
//! per seed, so the front is bitwise-identical for any thread count.
//!
//! Under the island model (`moo::island`) a "generation" is the
//! concatenation of every island's offspring, delivered here as one
//! `evaluate_batch` call: the in-batch dedup below collapses genomes bred
//! independently on different islands, and the `EvalService` memo makes
//! cross-generation repeats cache hits, so K islands share one PTQ cache.
//!
//! Failure contract: the GA engine's `Problem` interface is infallible, so
//! evaluation failures cannot propagate through it directly. Instead the
//! first failure trips an internal fuse — the typed `SearchError` is
//! stored, every subsequent evaluation returns an instant infeasible
//! sentinel (no further PJRT work), and `SearchSession` surfaces the
//! stored error after the engine unwinds. No worker-pool panics.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::beacon::BeaconManager;
use crate::coordinator::error::SearchError;
use crate::coordinator::objective::{sram_violation_mb, BoundObjective, PlatformBinding};
use crate::coordinator::session::CancelToken;
use crate::coordinator::trainer::Trainer;
use crate::eval::EvalService;
use crate::moo::{Evaluation, Problem};
use crate::quant::QuantConfig;
use crate::runtime::Artifacts;
use crate::util::pool::{map_parallel, WorkQueue};

/// How the parallel PTQ phase fans out over workers.
#[derive(Clone)]
pub enum EvalStrategy {
    /// Scoped threads spawned per batch (offline searches).
    Threads(usize),
    /// A long-lived shared pool: batches from every concurrent search
    /// interleave as one job stream (serve mode).
    Shared(Arc<WorkQueue>),
}

impl EvalStrategy {
    pub fn workers(&self) -> usize {
        match self {
            EvalStrategy::Threads(n) => *n,
            EvalStrategy::Shared(q) => q.threads(),
        }
    }
}

/// Objective sentinel once the failure fuse has tripped: large but finite
/// (crowding-distance math stays NaN-free), and infeasible so a sentinel
/// can never enter a Pareto set even if the outcome were inspected.
const FUSE_SENTINEL: f64 = 1e30;

/// Telemetry of one candidate evaluation (figures 5/9/10 inputs).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub genome: Vec<i64>,
    pub base_err: f64,
    pub err: f64,
    /// Parameter set used for the final error (0 = baseline).
    pub set_idx: usize,
    pub objectives: Vec<f64>,
    pub violation: f64,
}

pub struct MohaqProblem {
    pub arts: Arc<Artifacts>,
    /// Shared evaluation service — `Arc` so a long-lived session (serve
    /// mode) keeps ONE PTQ cache across every request it runs.
    pub eval: Arc<EvalService>,
    pub trainer: Option<Trainer>,
    pub beacons: Option<BeaconManager>,
    /// Distinct platform bindings the objectives reference; EVERY binding
    /// contributes its SRAM constraint.
    pub bindings: Vec<PlatformBinding>,
    pub objectives: Vec<BoundObjective>,
    /// W == A per layer (SiLago) halves the genome.
    pub tied: bool,
    /// Feasibility area: err <= err_limit (paper: baseline + 8pp => 24%).
    pub err_limit: f64,
    /// Minimum gene value (SiLago lacks 2-bit => 2).
    pub gene_min: i64,
    /// How the PTQ evaluation phase fans out (scoped threads or a shared
    /// serve-mode pool).
    pub evaluator: EvalStrategy,
    /// Cooperative cancellation: checked at every batch; tripping it
    /// surfaces as `SearchError::Cancelled` through the failure fuse.
    pub cancel: CancelToken,
    /// Every evaluation, in order (telemetry).
    pub records: Vec<EvalRecord>,
    /// First evaluation failure (the tripped fuse). `SearchSession` takes
    /// it after the GA engine returns; populated instead of panicking.
    pub failure: Option<SearchError>,
}

impl MohaqProblem {
    /// Decode a genome into a quantization config, or the typed error the
    /// session will surface (malformed genomes indicate an engine bug or
    /// a hand-built population, not a user mistake).
    pub fn try_decode(&self, genome: &[i64]) -> Result<QuantConfig, SearchError> {
        let qc = if self.tied {
            QuantConfig::from_genome_tied(genome)
        } else {
            QuantConfig::from_genome_wa(genome)
        };
        qc.ok_or_else(|| SearchError::Eval(format!("invalid genome {genome:?}")))
    }

    /// Sequential half of Algorithm 1: given the (possibly parallel)
    /// precomputed baseline error, decide whether a beacon parameter set
    /// applies and return (err, set_idx).
    fn refine_with_beacons(
        &mut self,
        qc: &QuantConfig,
        base_err: f64,
    ) -> anyhow::Result<(f64, usize)> {
        if let (Some(beacons), Some(trainer)) = (self.beacons.as_mut(), self.trainer.as_mut()) {
            if let Some(set) = beacons.select_or_create(qc, base_err, &self.eval, trainer)? {
                let err = self.eval.val_error(qc, set)?;
                // A beacon can only help; keep the better of the two
                // (retraining a *different* genome can occasionally hurt
                // an easy solution — the paper keeps such solutions via
                // the baseline parameters).
                if err < base_err {
                    return Ok((err, set));
                }
            }
        }
        Ok((base_err, 0))
    }

    fn score(
        &mut self,
        genome: &[i64],
        qc: &QuantConfig,
        base_err: f64,
    ) -> Result<Evaluation, SearchError> {
        let (err, set_idx) = self.refine_with_beacons(qc, base_err).map_err(SearchError::eval)?;

        let mut objectives = Vec::with_capacity(self.objectives.len());
        for obj in &self.objectives {
            objectives.push(obj.score(&self.bindings, &self.arts.model, qc, err)?);
        }

        // Constraints: per-binding SRAM capacity (MB over, summed) + error
        // feasibility area (paper §4.2: solutions > baseline+8pp are
        // excluded from the pool). Error violation is scaled so a few pp
        // of excess error compares to MBs of memory excess.
        let mut violation = sram_violation_mb(&self.bindings, &self.arts.model, qc);
        violation += (err - self.err_limit).max(0.0) * 10.0;

        self.records.push(EvalRecord {
            genome: genome.to_vec(),
            base_err,
            err,
            set_idx,
            objectives: objectives.clone(),
            violation,
        });
        Ok(Evaluation { objectives, violation })
    }

    /// The infeasible placeholder returned for every candidate after the
    /// failure fuse has tripped (keeps the infallible engine loop moving
    /// at zero evaluation cost; the outcome is discarded).
    fn sentinel(&self) -> Evaluation {
        Evaluation {
            objectives: vec![FUSE_SENTINEL; self.objectives.len()],
            violation: FUSE_SENTINEL,
        }
    }

    /// Fallible batch evaluation; any error trips the fuse in the caller.
    fn try_evaluate_batch(&mut self, genomes: &[Vec<i64>]) -> Result<Vec<Evaluation>, SearchError> {
        let qcs: Vec<QuantConfig> =
            genomes.iter().map(|g| self.try_decode(g)).collect::<Result<_, _>>()?;

        // Phase 1 (parallel): baseline-parameter PTQ error per UNIQUE
        // genome. Deduplication keeps the execution count (and the shared
        // cache's interaction pattern) identical for every thread count.
        let mut unique: Vec<usize> = Vec::new();
        let mut slot_of: HashMap<&[i64], usize> = HashMap::new();
        for (i, g) in genomes.iter().enumerate() {
            if !slot_of.contains_key(g.as_slice()) {
                slot_of.insert(g.as_slice(), unique.len());
                unique.push(i);
            }
        }
        let base_results: Vec<anyhow::Result<f64>> = match &self.evaluator {
            EvalStrategy::Threads(threads) => {
                let eval = &self.eval;
                map_parallel(*threads, &unique, |_, &i| eval.val_error(&qcs[i], 0))
            }
            EvalStrategy::Shared(queue) => queue.run_batch(
                unique
                    .iter()
                    .map(|&i| {
                        let eval = self.eval.clone();
                        let qc = qcs[i].clone();
                        move || eval.val_error(&qc, 0)
                    })
                    .collect(),
            ),
        };
        let base_errs: Vec<f64> = base_results
            .into_iter()
            .map(|r| r.map_err(SearchError::eval))
            .collect::<Result<_, _>>()?;

        // Phase 2 (sequential, input order): beacon logic + objectives.
        genomes
            .iter()
            .zip(&qcs)
            .map(|(genome, qc)| {
                let base_err = base_errs[slot_of[genome.as_slice()]];
                self.score(genome, qc, base_err)
            })
            .collect()
    }
}

impl Problem for MohaqProblem {
    fn num_vars(&self) -> usize {
        let l = self.arts.layer_names.len();
        if self.tied {
            l
        } else {
            2 * l
        }
    }

    fn num_objectives(&self) -> usize {
        self.objectives.len()
    }

    fn var_range(&self, _i: usize) -> (i64, i64) {
        (self.gene_min, 4)
    }

    fn objective_names(&self) -> Vec<String> {
        self.objectives.iter().map(|o| o.label.clone()).collect()
    }

    /// Engines stop their generation loop once the fuse tripped or the
    /// run was cancelled — a long-lived server must not spin through the
    /// remaining schedule on sentinels.
    fn aborted(&self) -> bool {
        self.failure.is_some() || self.cancel.is_cancelled()
    }

    fn evaluate(&mut self, genome: &[i64]) -> Evaluation {
        self.evaluate_batch(std::slice::from_ref(&genome.to_vec()))
            .pop()
            .expect("batch of one returned nothing")
    }

    fn evaluate_batch(&mut self, genomes: &[Vec<i64>]) -> Vec<Evaluation> {
        // Cooperative cancellation rides the failure fuse: the engine keeps
        // its infallible loop, every remaining candidate costs nothing, and
        // the session surfaces `SearchError::Cancelled` after unwinding.
        if self.failure.is_none() && self.cancel.is_cancelled() {
            self.failure = Some(SearchError::Cancelled);
        }
        if self.failure.is_some() {
            return genomes.iter().map(|_| self.sentinel()).collect();
        }
        match self.try_evaluate_batch(genomes) {
            Ok(evals) => evals,
            Err(e) => {
                self.failure = Some(e);
                genomes.iter().map(|_| self.sentinel()).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fuse_sentinel_is_finite_and_infeasible() {
        // NaN-free crowding math requires finite objectives; a positive
        // violation keeps sentinels out of every feasible Pareto set.
        assert!(FUSE_SENTINEL.is_finite());
        let e = Evaluation { objectives: vec![FUSE_SENTINEL; 3], violation: FUSE_SENTINEL };
        assert!(!e.feasible());
        assert!(e.objectives.iter().all(|v| v.is_finite()));
    }
}
