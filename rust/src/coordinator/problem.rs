//! The MOHAQ optimization problem: glues genome decoding, the AOT error
//! evaluation (with optional beacon search), the analytical hardware
//! objectives and the per-platform SRAM constraints into a `moo::Problem`
//! NSGA-II can drive (paper Fig. 4).
//!
//! Objectives are typed [`BoundObjective`]s resolved against a
//! [`PlatformBinding`] table (PR 4 redesign): one search can mix hardware
//! objectives bound to DIFFERENT registered platforms, and every binding
//! contributes its own SRAM constraint (violations are summed).
//!
//! Generations are evaluated in phases: the post-training-quantization
//! errors (the expensive PJRT executions) fan out across the session's
//! thread pool as MICRO-BATCHES — each worker receives one packed
//! `val_error_batch` submission instead of one job per genome. The
//! order-dependent half of the beacon logic (Algorithm 1) is only the
//! *selection* pass, which runs sequentially over the precomputed errors;
//! the retrainings it schedules are independent (each beacon trains on a
//! forked RNG stream that is a pure function of seed and beacon index)
//! and fan out across the same pool, with results applied in beacon
//! order. Every phase is deterministic per seed, so the front is
//! bitwise-identical for any thread count, batch size or island count.
//!
//! Under the island model (`moo::island`) a "generation" is the
//! concatenation of every island's offspring, delivered here as one
//! `evaluate_batch` call: the in-batch dedup below collapses genomes bred
//! independently on different islands, and the `EvalService` memo makes
//! cross-generation repeats cache hits, so K islands share one PTQ cache.
//!
//! Failure contract: the GA engine's `Problem` interface is infallible, so
//! evaluation failures cannot propagate through it directly. Instead the
//! first failure trips an internal fuse — the typed `SearchError` is
//! stored, every subsequent evaluation returns an instant infeasible
//! sentinel (no further PJRT work), and `SearchSession` surfaces the
//! stored error after the engine unwinds. No worker-pool panics.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

use crate::coordinator::beacon::{BeaconManager, BeaconPlan, BeaconSnapshot};
use crate::coordinator::error::SearchError;
use crate::coordinator::objective::{sram_violation_mb, BoundObjective, PlatformBinding};
use crate::coordinator::session::CancelToken;
use crate::coordinator::trainer::Retrainer;
use crate::eval::EvalService;
use crate::moo::{Evaluation, Individual, Problem};
use crate::quant::QuantConfig;
use crate::runtime::Artifacts;
use crate::util::pool::{map_parallel, run_once_parallel, WorkQueue};

/// How the parallel PTQ phase fans out over workers.
#[derive(Clone)]
pub enum EvalStrategy {
    /// Scoped threads spawned per batch (offline searches).
    Threads(usize),
    /// A long-lived shared pool: batches from every concurrent search
    /// interleave as one job stream (serve mode).
    Shared(Arc<WorkQueue>),
}

impl EvalStrategy {
    pub fn workers(&self) -> usize {
        match self {
            EvalStrategy::Threads(n) => *n,
            EvalStrategy::Shared(q) => q.threads(),
        }
    }
}

/// Objective sentinel once the failure fuse has tripped: large but finite
/// (crowding-distance math stays NaN-free), and infeasible so a sentinel
/// can never enter a Pareto set even if the outcome were inspected.
const FUSE_SENTINEL: f64 = 1e30;

/// Telemetry of one candidate evaluation (figures 5/9/10 inputs).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub genome: Vec<i64>,
    pub base_err: f64,
    pub err: f64,
    /// Parameter set used for the final error (0 = baseline).
    pub set_idx: usize,
    pub objectives: Vec<f64>,
    pub violation: f64,
}

pub struct MohaqProblem {
    pub arts: Arc<Artifacts>,
    /// Shared evaluation service — `Arc` so a long-lived session (serve
    /// mode) keeps ONE PTQ cache across every request it runs.
    pub eval: Arc<EvalService>,
    /// Retraining engine for beacon creation. `None` on share-only
    /// shards (fleet workers), which re-evaluate against replicated
    /// beacon sets but never create beacons themselves.
    pub trainer: Option<Retrainer>,
    pub beacons: Option<BeaconManager>,
    /// Distinct platform bindings the objectives reference; EVERY binding
    /// contributes its SRAM constraint.
    pub bindings: Vec<PlatformBinding>,
    pub objectives: Vec<BoundObjective>,
    /// W == A per layer (SiLago) halves the genome.
    pub tied: bool,
    /// Feasibility area: err <= err_limit (paper: baseline + 8pp => 24%).
    pub err_limit: f64,
    /// Minimum gene value (SiLago lacks 2-bit => 2).
    pub gene_min: i64,
    /// How the PTQ evaluation phase fans out (scoped threads or a shared
    /// serve-mode pool).
    pub evaluator: EvalStrategy,
    /// Cooperative cancellation: checked at every batch; tripping it
    /// surfaces as `SearchError::Cancelled` through the failure fuse.
    pub cancel: CancelToken,
    /// Every evaluation, in order (telemetry).
    pub records: Vec<EvalRecord>,
    /// First evaluation failure (the tripped fuse). `SearchSession` takes
    /// it after the GA engine returns; populated instead of panicking.
    pub failure: Option<SearchError>,
}

impl MohaqProblem {
    /// Decode a genome into a quantization config, or the typed error the
    /// session will surface (malformed genomes indicate an engine bug or
    /// a hand-built population, not a user mistake).
    pub fn try_decode(&self, genome: &[i64]) -> Result<QuantConfig, SearchError> {
        let qc = if self.tied {
            QuantConfig::from_genome_tied(genome)
        } else {
            QuantConfig::from_genome_wa(genome)
        };
        qc.ok_or_else(|| SearchError::Eval(format!("invalid genome {genome:?}")))
    }

    /// Fan the PTQ evaluation of `qcs` (against parameter set `set`) out
    /// over the active strategy as micro-batches: ~one chunk per worker,
    /// each chunk ONE packed `val_error_batch` submission, so a whole
    /// generation reaches the eval service as a handful of batched jobs
    /// instead of one per genome. Results come back in input order, and
    /// the batched entry point is bitwise- and counter-identical to
    /// per-candidate calls, so chunk geometry can never leak into the
    /// front. (Associated fn, not a method: callers hold disjoint field
    /// borrows of `self` during the beacon phase.)
    fn fan_out_val_errors(
        evaluator: &EvalStrategy,
        eval: &Arc<EvalService>,
        qcs: &[QuantConfig],
        set: usize,
    ) -> Result<Vec<f64>, SearchError> {
        if qcs.is_empty() {
            return Ok(Vec::new());
        }
        let chunk = qcs.len().div_ceil(evaluator.workers().max(1)).max(1);
        let results: Vec<anyhow::Result<Vec<f64>>> = match evaluator {
            EvalStrategy::Threads(threads) => {
                let chunks: Vec<&[QuantConfig]> = qcs.chunks(chunk).collect();
                map_parallel(*threads, &chunks, |_, c| eval.val_error_batch(c, set))
            }
            EvalStrategy::Shared(queue) => queue.run_batch(
                qcs.chunks(chunk)
                    .map(|c| {
                        let eval = eval.clone();
                        let chunk: Vec<QuantConfig> = c.to_vec();
                        move || eval.val_error_batch(&chunk, set)
                    })
                    .collect(),
            ),
        };
        let mut out = Vec::with_capacity(qcs.len());
        for r in results {
            out.extend(r.map_err(SearchError::eval)?);
        }
        Ok(out)
    }

    fn score(
        &mut self,
        genome: &[i64],
        qc: &QuantConfig,
        base_err: f64,
        err: f64,
        set_idx: usize,
    ) -> Result<Evaluation, SearchError> {
        let mut objectives = Vec::with_capacity(self.objectives.len());
        for obj in &self.objectives {
            objectives.push(obj.score(&self.bindings, &self.arts.model, qc, err)?);
        }

        // Constraints: per-binding SRAM capacity (MB over, summed) + error
        // feasibility area (paper §4.2: solutions > baseline+8pp are
        // excluded from the pool). Error violation is scaled so a few pp
        // of excess error compares to MBs of memory excess.
        let mut violation = sram_violation_mb(&self.bindings, &self.arts.model, qc);
        violation += (err - self.err_limit).max(0.0) * 10.0;

        self.records.push(EvalRecord {
            genome: genome.to_vec(),
            base_err,
            err,
            set_idx,
            objectives: objectives.clone(),
            violation,
        });
        Ok(Evaluation { objectives, violation })
    }

    /// The infeasible placeholder returned for every candidate after the
    /// failure fuse has tripped (keeps the infallible engine loop moving
    /// at zero evaluation cost; the outcome is discarded).
    fn sentinel(&self) -> Evaluation {
        Evaluation {
            objectives: vec![FUSE_SENTINEL; self.objectives.len()],
            violation: FUSE_SENTINEL,
        }
    }

    /// Fallible batch evaluation; any error trips the fuse in the caller.
    fn try_evaluate_batch(&mut self, genomes: &[Vec<i64>]) -> Result<Vec<Evaluation>, SearchError> {
        let qcs: Vec<QuantConfig> =
            genomes.iter().map(|g| self.try_decode(g)).collect::<Result<_, _>>()?;

        // Phase 1 (parallel): baseline-parameter PTQ error per UNIQUE
        // genome. Deduplication keeps the execution count (and the shared
        // cache's interaction pattern) identical for every thread count.
        let mut unique: Vec<usize> = Vec::new();
        let mut slot_of: HashMap<&[i64], usize> = HashMap::new();
        for (i, g) in genomes.iter().enumerate() {
            if !slot_of.contains_key(g.as_slice()) {
                slot_of.insert(g.as_slice(), unique.len());
                unique.push(i);
            }
        }
        let unique_qcs: Vec<QuantConfig> = unique.iter().map(|&i| qcs[i].clone()).collect();
        let base_errs = Self::fan_out_val_errors(&self.evaluator, &self.eval, &unique_qcs, 0)?;

        // Phase 2 (Algorithm 1), split so only the genuinely
        // order-dependent parts stay sequential:
        //   2a (sequential, input order): beacon selection/creation
        //       decisions — pending beacons become visible to later
        //       candidates exactly as in the per-candidate schedule.
        //   2b (parallel): retraining of the fresh beacons. Each trains on
        //       a forked RNG stream that is a pure function of (seed,
        //       beacon index), so dispatch order cannot reach the trained
        //       parameters.
        //   2c (sequential, beacon order): apply the retraining results —
        //       param-set registration, reports, creation events.
        //   2d (parallel): beacon-set re-evaluations, deduped and
        //       micro-batched per set.
        let mut final_err_set: Vec<(f64, usize)> =
            genomes.iter().map(|g| (base_errs[slot_of[g.as_slice()]], 0usize)).collect();
        {
            // Disjoint field borrows: the beacon manager is held mutably
            // across fan-outs that need the evaluator and eval service.
            let Self { beacons, trainer, evaluator, eval, .. } = &mut *self;
            if let Some(beacons) = beacons.as_mut() {
                let cands: Vec<(&QuantConfig, f64)> = genomes
                    .iter()
                    .zip(&qcs)
                    .map(|(g, qc)| (qc, base_errs[slot_of[g.as_slice()]]))
                    .collect();
                // In ShareOnly mode (island/fleet shards) this never
                // plans fresh beacons — candidates only share already
                // finalized (possibly replicated) sets.
                let (plans, fresh) = beacons.plan_batch(&cands);
                retrain_and_finalize(beacons, trainer.as_ref(), evaluator, eval, &fresh)?;

                // 2d: one re-eval per unique (set, genome) pair, grouped
                // by set so each group is a packed batched submission.
                let mut by_set: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
                let mut seen: HashSet<(usize, usize)> = HashSet::new();
                for (i, plan) in plans.iter().enumerate() {
                    if let BeaconPlan::Beacon { beacon_idx } = plan {
                        let set = beacons.set_of(*beacon_idx);
                        let slot = slot_of[genomes[i].as_slice()];
                        if seen.insert((set, slot)) {
                            by_set.entry(set).or_default().push(i);
                        }
                    }
                }
                let mut beacon_err: HashMap<(usize, usize), f64> = HashMap::new();
                for (set, idxs) in &by_set {
                    let batch: Vec<QuantConfig> = idxs.iter().map(|&i| qcs[i].clone()).collect();
                    let errs = Self::fan_out_val_errors(evaluator, eval, &batch, *set)?;
                    for (&i, e) in idxs.iter().zip(errs) {
                        beacon_err.insert((*set, slot_of[genomes[i].as_slice()]), e);
                    }
                }
                for (i, plan) in plans.iter().enumerate() {
                    if let BeaconPlan::Beacon { beacon_idx } = plan {
                        let set = beacons.set_of(*beacon_idx);
                        let err = beacon_err[&(set, slot_of[genomes[i].as_slice()])];
                        // A beacon can only help; keep the better of the
                        // two (retraining a *different* genome can
                        // occasionally hurt an easy solution — the paper
                        // keeps such solutions via the baseline params).
                        if err < final_err_set[i].0 {
                            final_err_set[i] = (err, set);
                        }
                    }
                }
            }
        }

        // Phase 3 (sequential, input order): objectives + records.
        genomes
            .iter()
            .zip(&qcs)
            .enumerate()
            .map(|(i, (genome, qc))| {
                let base_err = base_errs[slot_of[genome.as_slice()]];
                let (err, set_idx) = final_err_set[i];
                self.score(genome, qc, base_err, err, set_idx)
            })
            .collect()
    }

    /// Window-scheduled beacon creation (island + distributed searches):
    /// run Algorithm 1's selection pass over the boundary elites of every
    /// island in global island order, retrain the fresh beacons it plans,
    /// and finalize them. Mid-window candidates only SHARE the resulting
    /// sets (the manager runs in `ShareOnly` mode), so the beacon list is
    /// a pure function of the boundary elites — identical whether the
    /// islands ran in one process or across a worker fleet. `elites` must
    /// be the per-island elite groups in ascending global island order.
    pub(crate) fn run_beacon_window(&mut self, elites: &[&[Individual]]) -> Result<(), SearchError> {
        if self.beacons.is_none() {
            return Ok(());
        }
        let mut qcs: Vec<QuantConfig> = Vec::new();
        for group in elites {
            for ind in group.iter() {
                qcs.push(self.try_decode(&ind.genome)?);
            }
        }
        // Baseline errors are cache hits when this process evaluated the
        // elites itself, fresh (pure, so identical) computations when a
        // worker did.
        let mut base_errs = Vec::with_capacity(qcs.len());
        for qc in &qcs {
            base_errs.push(self.eval.val_error(qc, 0).map_err(SearchError::eval)?);
        }
        let Self { beacons, trainer, evaluator, eval, .. } = &mut *self;
        let mgr = beacons.as_mut().expect("window pass checked for a manager");
        let cands: Vec<(&QuantConfig, f64)> =
            qcs.iter().zip(base_errs.iter().copied()).collect();
        let (_plans, fresh) = mgr.plan_window(&cands);
        retrain_and_finalize(mgr, trainer.as_ref(), evaluator, eval, &fresh)
    }

    /// Final-front parameter-set assignment for window-scheduled runs:
    /// which finalized beacon set (if any) each front genome should report
    /// its error against. Built from the FINAL beacon list via the
    /// non-mutating share rule + the keep-better comparison, so a
    /// distributed merge and the single-process run derive identical rows
    /// from identical fronts. Empty map when no beacon manager is
    /// attached.
    pub(crate) fn beacon_set_map(
        &self,
        set: &[Individual],
    ) -> Result<HashMap<Vec<i64>, usize>, SearchError> {
        let mut map = HashMap::new();
        let Some(mgr) = self.beacons.as_ref() else { return Ok(map) };
        for ind in set {
            let qc = self.try_decode(&ind.genome)?;
            let base = self.eval.val_error(&qc, 0).map_err(SearchError::eval)?;
            if let Some(b) = mgr.share_target(&qc, base) {
                let s = mgr.set_of(b);
                let err = self.eval.val_error(&qc, s).map_err(SearchError::eval)?;
                if err < base {
                    map.insert(ind.genome.clone(), s);
                }
            }
        }
        Ok(map)
    }

    /// Checkpointable view of the attached beacon manager (empty when
    /// beacons are off).
    pub(crate) fn beacon_snapshots(&self) -> Result<Vec<BeaconSnapshot>, SearchError> {
        match &self.beacons {
            Some(mgr) => mgr
                .snapshot(self.eval.param_store().as_ref())
                .map_err(SearchError::eval),
            None => Ok(Vec::new()),
        }
    }

    /// `(config, retrain_steps)` per created beacon, for `SearchOutcome`.
    pub(crate) fn beacon_outcomes(&self) -> Vec<(String, usize)> {
        self.beacons
            .as_ref()
            .map(|m| m.beacons.iter().map(|bc| (bc.qc.display_wa(), bc.report.steps)).collect())
            .unwrap_or_default()
    }
}

/// Retrain the freshly planned beacons and finalize them in ascending
/// beacon order — the one code path both the per-batch schedule and the
/// boundary window pass go through. Retraining is order-independent
/// (each beacon trains on an RNG stream forked from its GLOBAL beacon
/// index), so only finalization is sequential.
fn retrain_and_finalize(
    beacons: &mut BeaconManager,
    trainer: Option<&Retrainer>,
    evaluator: &EvalStrategy,
    eval: &Arc<EvalService>,
    fresh: &[usize],
) -> Result<(), SearchError> {
    if fresh.is_empty() {
        return Ok(());
    }
    let trainer = trainer.ok_or_else(|| {
        SearchError::invalid(
            "beacon creation requires a retrainer; share-only shards must \
             never plan fresh beacons",
        )
    })?;
    let base = eval.param_set(0).map_err(SearchError::eval)?;
    let (steps, lr) = (beacons.policy.retrain_steps, beacons.policy.lr);
    let jobs: Vec<_> = fresh
        .iter()
        .map(|&bidx| {
            let mut t = trainer.fork(bidx as u64);
            let qc = beacons.beacons[bidx].qc.clone();
            let base = base.clone();
            move || t.retrain(&base.host, &qc, steps, lr)
        })
        .collect();
    let results = match evaluator {
        EvalStrategy::Threads(threads) => run_once_parallel(*threads, jobs),
        EvalStrategy::Shared(queue) => queue.run_batch(jobs),
    };
    let store = eval.param_store();
    for (&bidx, result) in fresh.iter().zip(results) {
        let (params, report) = result.map_err(SearchError::eval)?;
        beacons
            .finalize_pending(bidx, store.as_ref(), params, report)
            .map_err(SearchError::eval)?;
    }
    Ok(())
}

impl Problem for MohaqProblem {
    fn num_vars(&self) -> usize {
        let l = self.arts.layer_names.len();
        if self.tied {
            l
        } else {
            2 * l
        }
    }

    fn num_objectives(&self) -> usize {
        self.objectives.len()
    }

    fn var_range(&self, _i: usize) -> (i64, i64) {
        (self.gene_min, 4)
    }

    fn objective_names(&self) -> Vec<String> {
        self.objectives.iter().map(|o| o.label.clone()).collect()
    }

    /// Engines stop their generation loop once the fuse tripped or the
    /// run was cancelled — a long-lived server must not spin through the
    /// remaining schedule on sentinels.
    fn aborted(&self) -> bool {
        self.failure.is_some() || self.cancel.is_cancelled()
    }

    fn evaluate(&mut self, genome: &[i64]) -> Evaluation {
        self.evaluate_batch(std::slice::from_ref(&genome.to_vec()))
            .pop()
            .expect("batch of one returned nothing")
    }

    fn evaluate_batch(&mut self, genomes: &[Vec<i64>]) -> Vec<Evaluation> {
        // Cooperative cancellation rides the failure fuse: the engine keeps
        // its infallible loop, every remaining candidate costs nothing, and
        // the session surfaces `SearchError::Cancelled` after unwinding.
        if self.failure.is_none() && self.cancel.is_cancelled() {
            self.failure = Some(SearchError::Cancelled);
        }
        if self.failure.is_some() {
            return genomes.iter().map(|_| self.sentinel()).collect();
        }
        match self.try_evaluate_batch(genomes) {
            Ok(evals) => evals,
            Err(e) => {
                self.failure = Some(e);
                genomes.iter().map(|_| self.sentinel()).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fuse_sentinel_is_finite_and_infeasible() {
        // NaN-free crowding math requires finite objectives; a positive
        // violation keeps sentinels out of every feasible Pareto set.
        assert!(FUSE_SENTINEL.is_finite());
        let e = Evaluation { objectives: vec![FUSE_SENTINEL; 3], violation: FUSE_SENTINEL };
        assert!(!e.feasible());
        assert!(e.objectives.iter().all(|v| v.is_finite()));
    }
}
