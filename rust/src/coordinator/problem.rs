//! The MOHAQ optimization problem: glues genome decoding, the AOT error
//! evaluation (with optional beacon search), the analytical hardware
//! objectives and the SRAM constraint into a `moo::Problem` NSGA-II can
//! drive (paper Fig. 4).
//!
//! Generations are evaluated in two phases: the post-training-quantization
//! errors (the expensive PJRT executions) fan out across the session's
//! thread pool, then the order-dependent beacon logic (Algorithm 1) runs
//! sequentially over the precomputed errors. Both phases are deterministic
//! per seed, so the front is bitwise-identical for any thread count.
//!
//! Under the island model (`moo::island`) a "generation" is the
//! concatenation of every island's offspring, delivered here as one
//! `evaluate_batch` call: the in-batch dedup below collapses genomes bred
//! independently on different islands, and the `EvalService` memo makes
//! cross-generation repeats cache hits, so K islands share one PTQ cache.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::beacon::BeaconManager;
use crate::coordinator::trainer::Trainer;
use crate::eval::EvalService;
use crate::hw::registry::SharedPlatform;
use crate::hw::Platform;
use crate::moo::{Evaluation, Problem};
use crate::quant::QuantConfig;
use crate::runtime::Artifacts;
use crate::util::pool::map_parallel;

/// Objectives supported by the experiments (all minimized; speedup is
/// negated per paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObjectiveKind {
    /// Validation error (max over subsets).
    Error,
    /// Model size in MB (experiment 1).
    SizeMb,
    /// Negated Eq.-4 speedup (experiments 2, 3).
    NegSpeedup,
    /// Eq.-3 energy in uJ (experiment 2).
    EnergyUj,
}

impl ObjectiveKind {
    pub fn name(&self) -> &'static str {
        match self {
            ObjectiveKind::Error => "WER_V",
            ObjectiveKind::SizeMb => "size_MB",
            ObjectiveKind::NegSpeedup => "-speedup",
            ObjectiveKind::EnergyUj => "energy_uJ",
        }
    }

    /// Canonical config-file identifier (what `to_json` emits).
    pub fn id(&self) -> &'static str {
        match self {
            ObjectiveKind::Error => "error",
            ObjectiveKind::SizeMb => "size_mb",
            ObjectiveKind::NegSpeedup => "neg_speedup",
            ObjectiveKind::EnergyUj => "energy_uj",
        }
    }

    /// Parse a config-file identifier (several aliases accepted).
    pub fn from_id(id: &str) -> Option<ObjectiveKind> {
        Some(match id {
            "error" | "wer" => ObjectiveKind::Error,
            "size" | "size_mb" => ObjectiveKind::SizeMb,
            "neg_speedup" | "speedup" => ObjectiveKind::NegSpeedup,
            "energy" | "energy_uj" => ObjectiveKind::EnergyUj,
            _ => return None,
        })
    }

    /// Whether scoring this objective requires a hardware platform.
    pub fn needs_platform(&self) -> bool {
        matches!(self, ObjectiveKind::NegSpeedup | ObjectiveKind::EnergyUj)
    }
}

/// Telemetry of one candidate evaluation (figures 5/9/10 inputs).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub genome: Vec<i64>,
    pub base_err: f64,
    pub err: f64,
    /// Parameter set used for the final error (0 = baseline).
    pub set_idx: usize,
    pub objectives: Vec<f64>,
    pub violation: f64,
}

pub struct MohaqProblem {
    pub arts: Arc<Artifacts>,
    pub eval: EvalService,
    pub trainer: Option<Trainer>,
    pub beacons: Option<BeaconManager>,
    pub platform: Option<SharedPlatform>,
    pub objectives: Vec<ObjectiveKind>,
    /// W == A per layer (SiLago) halves the genome.
    pub tied: bool,
    /// Feasibility area: err <= err_limit (paper: baseline + 8pp => 24%).
    pub err_limit: f64,
    /// Minimum gene value (SiLago lacks 2-bit => 2).
    pub gene_min: i64,
    /// Worker threads for the PTQ evaluation phase (1 = sequential).
    pub threads: usize,
    /// Every evaluation, in order (telemetry).
    pub records: Vec<EvalRecord>,
}

impl MohaqProblem {
    pub fn decode(&self, genome: &[i64]) -> QuantConfig {
        let qc = if self.tied {
            QuantConfig::from_genome_tied(genome)
        } else {
            QuantConfig::from_genome_wa(genome)
        };
        qc.unwrap_or_else(|| panic!("invalid genome {genome:?}"))
    }

    /// Sequential half of Algorithm 1: given the (possibly parallel)
    /// precomputed baseline error, decide whether a beacon parameter set
    /// applies and return (err, set_idx).
    fn refine_with_beacons(&mut self, qc: &QuantConfig, base_err: f64) -> anyhow::Result<(f64, usize)> {
        if let (Some(beacons), Some(trainer)) = (self.beacons.as_mut(), self.trainer.as_mut()) {
            if let Some(set) = beacons.select_or_create(qc, base_err, &self.eval, trainer)? {
                let err = self.eval.val_error(qc, set)?;
                // A beacon can only help; keep the better of the two
                // (retraining a *different* genome can occasionally hurt
                // an easy solution — the paper keeps such solutions via
                // the baseline parameters).
                if err < base_err {
                    return Ok((err, set));
                }
            }
        }
        Ok((base_err, 0))
    }

    fn score(&mut self, genome: &[i64], qc: &QuantConfig, base_err: f64) -> Evaluation {
        let (err, set_idx) = self
            .refine_with_beacons(qc, base_err)
            .unwrap_or_else(|e| panic!("candidate evaluation failed: {e:#}"));

        let mut objectives = Vec::with_capacity(self.objectives.len());
        for kind in &self.objectives {
            let v = match kind {
                ObjectiveKind::Error => err,
                ObjectiveKind::SizeMb => {
                    self.arts.model.size_bytes(&qc.w_bits) / (1024.0 * 1024.0)
                }
                ObjectiveKind::NegSpeedup => {
                    let p = self.platform.as_ref().expect("speedup needs a platform");
                    -p.speedup(&self.arts.model, qc)
                }
                ObjectiveKind::EnergyUj => {
                    let p = self.platform.as_ref().expect("energy needs a platform");
                    p.energy_pj(&self.arts.model, qc).expect("platform lacks energy model")
                        / 1e6
                }
            };
            objectives.push(v);
        }

        // Constraints: SRAM capacity (MB over) + error feasibility area
        // (paper §4.2: solutions > baseline+8pp are excluded from the
        // pool). Error violation is scaled so a few pp of excess error
        // compares to MBs of memory excess.
        let mut violation = 0.0;
        if let Some(p) = self.platform.as_ref() {
            violation += p.sram_violation(&self.arts.model, qc);
        }
        violation += (err - self.err_limit).max(0.0) * 10.0;

        self.records.push(EvalRecord {
            genome: genome.to_vec(),
            base_err,
            err,
            set_idx,
            objectives: objectives.clone(),
            violation,
        });
        Evaluation { objectives, violation }
    }
}

impl Problem for MohaqProblem {
    fn num_vars(&self) -> usize {
        let l = self.arts.layer_names.len();
        if self.tied {
            l
        } else {
            2 * l
        }
    }

    fn num_objectives(&self) -> usize {
        self.objectives.len()
    }

    fn var_range(&self, _i: usize) -> (i64, i64) {
        (self.gene_min, 4)
    }

    fn objective_names(&self) -> Vec<String> {
        self.objectives.iter().map(|o| o.name().to_string()).collect()
    }

    fn evaluate(&mut self, genome: &[i64]) -> Evaluation {
        self.evaluate_batch(std::slice::from_ref(&genome.to_vec()))
            .pop()
            .expect("batch of one returned nothing")
    }

    fn evaluate_batch(&mut self, genomes: &[Vec<i64>]) -> Vec<Evaluation> {
        let qcs: Vec<QuantConfig> = genomes.iter().map(|g| self.decode(g)).collect();

        // Phase 1 (parallel): baseline-parameter PTQ error per UNIQUE
        // genome. Deduplication keeps the execution count (and the shared
        // cache's interaction pattern) identical for every thread count.
        let mut unique: Vec<usize> = Vec::new();
        let mut slot_of: HashMap<&[i64], usize> = HashMap::new();
        for (i, g) in genomes.iter().enumerate() {
            if !slot_of.contains_key(g.as_slice()) {
                slot_of.insert(g.as_slice(), unique.len());
                unique.push(i);
            }
        }
        let eval = &self.eval;
        let base_results: Vec<anyhow::Result<f64>> =
            map_parallel(self.threads, &unique, |_, &i| eval.val_error(&qcs[i], 0));
        let base_errs: Vec<f64> = base_results
            .into_iter()
            .map(|r| r.unwrap_or_else(|e| panic!("candidate evaluation failed: {e:#}")))
            .collect();

        // Phase 2 (sequential, input order): beacon logic + objectives.
        genomes
            .iter()
            .zip(&qcs)
            .map(|(genome, qc)| {
                let base_err = base_errs[slot_of[genome.as_slice()]];
                self.score(genome, qc, base_err)
            })
            .collect()
    }
}
