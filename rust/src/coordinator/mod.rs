//! L3 coordinator — the paper's system contribution: the MOHAQ search
//! (Fig. 4) over AOT-compiled evaluation, with beacon-based retraining
//! (Algorithm 1) orchestrated entirely from Rust.

pub mod beacon;
pub mod problem;
pub mod search;
pub mod trainer;

pub use beacon::{Beacon, BeaconManager, BeaconPolicy};
pub use problem::{EvalRecord, MohaqProblem, ObjectiveKind};
pub use search::{
    baseline_rows, run_search, BeaconPolicyOverrides, ExperimentSpec, GenerationLog,
    PlatformChoice, SearchOutcome, SolutionRow,
};
pub use trainer::{RetrainReport, Trainer};
