//! L3 coordinator — the paper's system contribution: the MOHAQ search
//! (Fig. 4) over AOT-compiled evaluation, with beacon-based retraining
//! (Algorithm 1) orchestrated entirely from Rust.
//!
//! Public API shape (see DESIGN.md):
//!   * `ExperimentSpec::builder()` — validated, serializable experiment
//!     descriptions; platforms named by `hw::registry` string.
//!   * `ScoredObjective` — typed objectives with explicit platform
//!     bindings (`neg_speedup@silago`); one search can mix hardware
//!     objectives bound to different platforms.
//!   * `SearchSession` — owns `Arc<Artifacts>` + runtime, evaluates
//!     populations across a thread pool, streams `SearchEvent`s, returns
//!     typed `SearchError`s.

pub mod beacon;
pub mod error;
pub mod objective;
pub mod problem;
pub mod session;
pub mod spec;
pub mod trainer;

pub use beacon::{Beacon, BeaconDecision, BeaconManager, BeaconPolicy, BeaconSnapshot};
pub use error::SearchError;
pub use objective::{BoundObjective, Direction, HwMetrics, PlatformBinding, ScoredObjective};
pub use problem::{EvalRecord, EvalStrategy, MohaqProblem};
pub use session::{
    baseline_rows, CancelToken, GenerationLog, SearchEvent, SearchOutcome, SearchSession,
    SolutionRow,
};
pub use spec::{BeaconPolicyOverrides, ExperimentSpec, ExperimentSpecBuilder};
pub use trainer::{RetrainReport, Trainer};
