//! Beacon retraining drivers. [`Trainer`] loops the AOT binary-connect
//! train step (paper §4.3) from Rust — Python is NOT involved, the
//! train-step graph was lowered once at `make artifacts`.
//! [`SurrogateTrainer`] is its hermetic stand-in for synthetic sessions;
//! [`Retrainer`] is the engine-agnostic handle the search holds.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::quant::QuantConfig;
use crate::runtime::{scalar_f32, vec_f32, Artifacts, Executor, Input, Runtime};
use crate::util::rng::Rng;

pub struct Trainer {
    arts: Arc<Artifacts>,
    /// Shared compiled train-step executable: forked trainers reuse it,
    /// so a parallel retraining fan-out compiles nothing.
    exec: Arc<Executor>,
    /// The seed this trainer was built with — forked per-beacon RNG
    /// streams derive from it, NOT from the live `rng` (which advances).
    seed: u64,
    rng: Rng,
    /// Scratch for gathering non-contiguous training batches.
    x_batch: Vec<f32>,
    y_batch: Vec<i32>,
}

#[derive(Debug, Clone)]
pub struct RetrainReport {
    pub steps: usize,
    pub lr: f32,
    /// Loss after each logged interval (the loss curve for EXPERIMENTS.md).
    pub loss_curve: Vec<(usize, f32)>,
    pub wall_secs: f64,
}

impl Trainer {
    pub fn new(rt: &Runtime, arts: Arc<Artifacts>, seed: u64) -> Result<Trainer> {
        let exec = Arc::new(rt.load(arts.hlo_path("train_step")?)?);
        Ok(Trainer {
            arts,
            exec,
            seed,
            rng: Rng::new(seed),
            x_batch: Vec::new(),
            y_batch: Vec::new(),
        })
    }

    /// Derive an independent trainer for one parallel retraining run. It
    /// shares the compiled executable (Arc clone, no recompilation) and
    /// draws batches from an RNG stream that is a PURE function of
    /// (base seed, stream tag) — beacon i always retrains on stream i, so
    /// the trained parameters are identical whether the runs execute
    /// sequentially or fan out across a worker pool in any order.
    pub fn fork(&self, stream: u64) -> Trainer {
        let mut base = Rng::new(self.seed);
        Trainer {
            arts: self.arts.clone(),
            exec: self.exec.clone(),
            seed: self.seed,
            rng: base.fork(stream.wrapping_add(1)),
            x_batch: Vec::new(),
            y_batch: Vec::new(),
        }
    }

    fn gather_batch(&mut self) {
        let a = &self.arts;
        let (b, t, f) = (a.batch, a.seq_len, a.feat_dim);
        let xs = t * f;
        self.x_batch.clear();
        self.y_batch.clear();
        for _ in 0..b {
            let s = self.rng.below(a.train.num_seqs);
            self.x_batch.extend_from_slice(&a.train.x[s * xs..(s + 1) * xs]);
            self.y_batch.extend_from_slice(&a.train.y[s * t..(s + 1) * t]);
        }
    }

    /// Run `steps` binary-connect SGD steps starting from `start` params,
    /// quantized per `qc`. Returns (new params, report).
    pub fn retrain(
        &mut self,
        start: &[Vec<f32>],
        qc: &QuantConfig,
        steps: usize,
        lr: f32,
    ) -> Result<(Vec<Vec<f32>>, RetrainReport)> {
        let t0 = std::time::Instant::now();
        let a = self.arts.clone();
        anyhow::ensure!(start.len() == a.tensors.len(), "bad param count");
        let (wq, aq) = a.qtable.resolve(qc)?;
        let n_layers = a.layer_names.len() as i64;
        let (b, t, f) = (a.batch as i64, a.seq_len as i64, a.feat_dim as i64);
        let shapes: Vec<Vec<i64>> = a
            .tensors
            .iter()
            .map(|info| info.shape.iter().map(|&d| d as i64).collect())
            .collect();

        let mut params: Vec<Vec<f32>> = start.to_vec();
        let mut loss_curve = Vec::new();
        let log_every = (steps / 10).max(1);

        for step in 0..steps {
            self.gather_batch();
            let mut inputs: Vec<Input> = Vec::with_capacity(params.len() + 5);
            for (data, shape) in params.iter().zip(&shapes) {
                inputs.push(Input::F32(data, shape.clone()));
            }
            inputs.push(Input::F32(&wq, vec![n_layers, 4]));
            inputs.push(Input::F32(&aq, vec![n_layers, 4]));
            inputs.push(Input::F32(&self.x_batch, vec![b, t, f]));
            inputs.push(Input::I32(&self.y_batch, vec![b, t]));
            inputs.push(Input::ScalarF32(lr));

            let out = self.exec.run_literals(&inputs).context("train step")?;
            anyhow::ensure!(
                out.len() == params.len() + 1,
                "train step returned {} outputs, expected {}",
                out.len(),
                params.len() + 1
            );
            for (i, lit) in out[..params.len()].iter().enumerate() {
                params[i] = vec_f32(lit)?;
            }
            let loss = scalar_f32(&out[params.len()])?;
            if step % log_every == 0 || step + 1 == steps {
                loss_curve.push((step, loss));
            }
        }
        let report = RetrainReport {
            steps,
            lr,
            loss_curve,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        Ok((params, report))
    }
}

/// Hermetic retraining stand-in for synthetic (surrogate) sessions: the
/// returned parameters are EXACTLY the start point and the loss curve is
/// a pure function of (seed, stream, steps). That is enough for beacons
/// to be fully observable offline — the surrogate error model keys on
/// the parameter-SET INDEX (`EvalService::surrogate_val_error` hashes
/// it), so registering a beacon set changes candidate errors
/// deterministically without any tensor arithmetic. `wall_secs` is real
/// wall time and never front-affecting.
pub struct SurrogateTrainer {
    seed: u64,
    stream: u64,
}

impl SurrogateTrainer {
    pub fn new(seed: u64) -> SurrogateTrainer {
        SurrogateTrainer { seed, stream: 0 }
    }

    pub fn fork(&self, stream: u64) -> SurrogateTrainer {
        SurrogateTrainer { seed: self.seed, stream }
    }

    pub fn retrain(
        &mut self,
        start: &[Vec<f32>],
        _qc: &QuantConfig,
        steps: usize,
        lr: f32,
    ) -> Result<(Vec<Vec<f32>>, RetrainReport)> {
        let t0 = std::time::Instant::now();
        // Same logging cadence as the real trainer; strictly decreasing
        // synthetic loss with a per-stream offset so forked streams are
        // distinguishable in diagnostics yet bitwise-reproducible.
        let offset = ((self.seed ^ self.stream.wrapping_mul(0x9e37)) % 997) as f32 * 1e-6;
        let log_every = (steps / 10).max(1);
        let mut loss_curve = Vec::new();
        for step in 0..steps {
            if step % log_every == 0 || step + 1 == steps {
                let frac = step as f32 / steps.max(1) as f32;
                loss_curve.push((step, 1.0 - 0.5 * frac + offset));
            }
        }
        let report = RetrainReport {
            steps,
            lr,
            loss_curve,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        Ok((start.to_vec(), report))
    }
}

/// The engine-agnostic retraining handle `MohaqProblem` holds: the real
/// PJRT binary-connect loop on artifact-backed sessions, the pure
/// surrogate stand-in on synthetic ones. Both fork per-beacon RNG
/// streams that are pure functions of (base seed, stream tag), so
/// retrained parameters never depend on scheduling order.
pub enum Retrainer {
    Pjrt(Trainer),
    Surrogate(SurrogateTrainer),
}

impl Retrainer {
    pub fn fork(&self, stream: u64) -> Retrainer {
        match self {
            Retrainer::Pjrt(t) => Retrainer::Pjrt(t.fork(stream)),
            Retrainer::Surrogate(t) => Retrainer::Surrogate(t.fork(stream)),
        }
    }

    pub fn retrain(
        &mut self,
        start: &[Vec<f32>],
        qc: &QuantConfig,
        steps: usize,
        lr: f32,
    ) -> Result<(Vec<Vec<f32>>, RetrainReport)> {
        match self {
            Retrainer::Pjrt(t) => t.retrain(start, qc, steps, lr),
            Retrainer::Surrogate(t) => t.retrain(start, qc, steps, lr),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Bits;
    use std::path::PathBuf;

    #[test]
    fn surrogate_retrainer_is_pure_and_order_independent() {
        let start = vec![vec![1.0f32, 2.0], vec![3.0]];
        let qc = QuantConfig::uniform(2, Bits::B2, Bits::B8);
        let base = Retrainer::Surrogate(SurrogateTrainer::new(7));
        let mut a = base.fork(3);
        let mut b = base.fork(3);
        let (pa, ra) = a.retrain(&start, &qc, 50, 1e-3).unwrap();
        let (pb, rb) = b.retrain(&start, &qc, 50, 1e-3).unwrap();
        assert_eq!(pa, start, "surrogate retraining returns the start point");
        assert_eq!(pa, pb, "same stream, same params");
        assert_eq!(ra.loss_curve, rb.loss_curve, "same stream, same curve");
        assert_eq!(ra.steps, 50);
        assert!(
            ra.loss_curve.windows(2).all(|w| w[1].1 < w[0].1),
            "synthetic loss must decrease: {:?}",
            ra.loss_curve
        );
        // Distinct streams are distinguishable in diagnostics but share
        // the purity contract.
        let mut c = base.fork(4);
        let (pc, rc) = c.retrain(&start, &qc, 50, 1e-3).unwrap();
        assert_eq!(pc, start);
        assert_ne!(rc.loss_curve, ra.loss_curve);
    }

    #[test]
    fn retraining_decreases_loss() {
        let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let p = PathBuf::from(dir);
        if !p.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts present");
            return;
        }
        let arts = Arc::new(Artifacts::load(p).unwrap());
        let rt = Runtime::cpu().unwrap();
        let mut trainer = Trainer::new(&rt, arts.clone(), 42).unwrap();
        let qc = QuantConfig::uniform(arts.layer_names.len(), Bits::B2, Bits::B8);
        let (new_params, report) = trainer
            .retrain(&arts.weights, &qc, 30, arts.baseline.beacon_lr as f32)
            .unwrap();
        assert_eq!(new_params.len(), arts.weights.len());
        let first = report.loss_curve.first().unwrap().1;
        let last = report.loss_curve.last().unwrap().1;
        assert!(
            last < first,
            "loss should decrease: {first} -> {last} ({:?})",
            report.loss_curve
        );
        // Parameters actually moved.
        let moved = new_params
            .iter()
            .zip(&arts.weights)
            .any(|(a, b)| a.iter().zip(b).any(|(x, y)| (x - y).abs() > 1e-6));
        assert!(moved);
    }
}
