//! Beacon retraining driver: loops the AOT binary-connect train step
//! (paper §4.3) from Rust. Python is NOT involved — the train-step graph
//! was lowered once at `make artifacts`.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::quant::QuantConfig;
use crate::runtime::{scalar_f32, vec_f32, Artifacts, Executor, Input, Runtime};
use crate::util::rng::Rng;

pub struct Trainer {
    arts: Arc<Artifacts>,
    /// Shared compiled train-step executable: forked trainers reuse it,
    /// so a parallel retraining fan-out compiles nothing.
    exec: Arc<Executor>,
    /// The seed this trainer was built with — forked per-beacon RNG
    /// streams derive from it, NOT from the live `rng` (which advances).
    seed: u64,
    rng: Rng,
    /// Scratch for gathering non-contiguous training batches.
    x_batch: Vec<f32>,
    y_batch: Vec<i32>,
}

#[derive(Debug, Clone)]
pub struct RetrainReport {
    pub steps: usize,
    pub lr: f32,
    /// Loss after each logged interval (the loss curve for EXPERIMENTS.md).
    pub loss_curve: Vec<(usize, f32)>,
    pub wall_secs: f64,
}

impl Trainer {
    pub fn new(rt: &Runtime, arts: Arc<Artifacts>, seed: u64) -> Result<Trainer> {
        let exec = Arc::new(rt.load(arts.hlo_path("train_step")?)?);
        Ok(Trainer {
            arts,
            exec,
            seed,
            rng: Rng::new(seed),
            x_batch: Vec::new(),
            y_batch: Vec::new(),
        })
    }

    /// Derive an independent trainer for one parallel retraining run. It
    /// shares the compiled executable (Arc clone, no recompilation) and
    /// draws batches from an RNG stream that is a PURE function of
    /// (base seed, stream tag) — beacon i always retrains on stream i, so
    /// the trained parameters are identical whether the runs execute
    /// sequentially or fan out across a worker pool in any order.
    pub fn fork(&self, stream: u64) -> Trainer {
        let mut base = Rng::new(self.seed);
        Trainer {
            arts: self.arts.clone(),
            exec: self.exec.clone(),
            seed: self.seed,
            rng: base.fork(stream.wrapping_add(1)),
            x_batch: Vec::new(),
            y_batch: Vec::new(),
        }
    }

    fn gather_batch(&mut self) {
        let a = &self.arts;
        let (b, t, f) = (a.batch, a.seq_len, a.feat_dim);
        let xs = t * f;
        self.x_batch.clear();
        self.y_batch.clear();
        for _ in 0..b {
            let s = self.rng.below(a.train.num_seqs);
            self.x_batch.extend_from_slice(&a.train.x[s * xs..(s + 1) * xs]);
            self.y_batch.extend_from_slice(&a.train.y[s * t..(s + 1) * t]);
        }
    }

    /// Run `steps` binary-connect SGD steps starting from `start` params,
    /// quantized per `qc`. Returns (new params, report).
    pub fn retrain(
        &mut self,
        start: &[Vec<f32>],
        qc: &QuantConfig,
        steps: usize,
        lr: f32,
    ) -> Result<(Vec<Vec<f32>>, RetrainReport)> {
        let t0 = std::time::Instant::now();
        let a = self.arts.clone();
        anyhow::ensure!(start.len() == a.tensors.len(), "bad param count");
        let (wq, aq) = a.qtable.resolve(qc)?;
        let n_layers = a.layer_names.len() as i64;
        let (b, t, f) = (a.batch as i64, a.seq_len as i64, a.feat_dim as i64);
        let shapes: Vec<Vec<i64>> = a
            .tensors
            .iter()
            .map(|info| info.shape.iter().map(|&d| d as i64).collect())
            .collect();

        let mut params: Vec<Vec<f32>> = start.to_vec();
        let mut loss_curve = Vec::new();
        let log_every = (steps / 10).max(1);

        for step in 0..steps {
            self.gather_batch();
            let mut inputs: Vec<Input> = Vec::with_capacity(params.len() + 5);
            for (data, shape) in params.iter().zip(&shapes) {
                inputs.push(Input::F32(data, shape.clone()));
            }
            inputs.push(Input::F32(&wq, vec![n_layers, 4]));
            inputs.push(Input::F32(&aq, vec![n_layers, 4]));
            inputs.push(Input::F32(&self.x_batch, vec![b, t, f]));
            inputs.push(Input::I32(&self.y_batch, vec![b, t]));
            inputs.push(Input::ScalarF32(lr));

            let out = self.exec.run_literals(&inputs).context("train step")?;
            anyhow::ensure!(
                out.len() == params.len() + 1,
                "train step returned {} outputs, expected {}",
                out.len(),
                params.len() + 1
            );
            for (i, lit) in out[..params.len()].iter().enumerate() {
                params[i] = vec_f32(lit)?;
            }
            let loss = scalar_f32(&out[params.len()])?;
            if step % log_every == 0 || step + 1 == steps {
                loss_curve.push((step, loss));
            }
        }
        let report = RetrainReport {
            steps,
            lr,
            loss_curve,
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        Ok((params, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::Bits;
    use std::path::PathBuf;

    #[test]
    fn retraining_decreases_loss() {
        let dir = std::env::var("MOHAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let p = PathBuf::from(dir);
        if !p.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts present");
            return;
        }
        let arts = Arc::new(Artifacts::load(p).unwrap());
        let rt = Runtime::cpu().unwrap();
        let mut trainer = Trainer::new(&rt, arts.clone(), 42).unwrap();
        let qc = QuantConfig::uniform(arts.layer_names.len(), Bits::B2, Bits::B8);
        let (new_params, report) = trainer
            .retrain(&arts.weights, &qc, 30, arts.baseline.beacon_lr as f32)
            .unwrap();
        assert_eq!(new_params.len(), arts.weights.len());
        let first = report.loss_curve.first().unwrap().1;
        let last = report.loss_curve.last().unwrap().1;
        assert!(
            last < first,
            "loss should decrease: {first} -> {last} ({:?})",
            report.loss_curve
        );
        // Parameters actually moved.
        let moved = new_params
            .iter()
            .zip(&arts.weights)
            .any(|(a, b)| a.iter().zip(b).any(|(x, y)| (x - y).abs() > 1e-6));
        assert!(moved);
    }
}
