//! Experiment specifications: what to search, on which platform, with
//! which objectives and GA/beacon settings. Specs are built through a
//! validating builder (`ExperimentSpec::builder()`), round-trip through
//! JSON (so `mohaq search --config FILE` covers everything the presets
//! do), and name platforms by registry string — adding a backend never
//! touches this module.

use std::collections::BTreeMap;

use crate::coordinator::error::SearchError;
use crate::coordinator::problem::ObjectiveKind;
use crate::hw::registry::{self, PlatformSpec, SharedPlatform};
use crate::hw::Platform;
use crate::moo::island::{IslandConfig, Topology};
use crate::moo::Nsga2Config;
use crate::util::json::Json;

/// Beacon policy knobs exposed to drivers; unset fields use paper defaults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BeaconPolicyOverrides {
    pub threshold: Option<f64>,
    pub retrain_steps: Option<usize>,
    pub max_beacons: Option<usize>,
}

/// A validated experiment description. Construct via `builder()` (or the
/// paper presets, which go through the builder); direct field edits after
/// that are the driver's responsibility.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    pub name: String,
    /// Registry reference; `None` = no hardware model (experiment 1).
    pub platform: Option<PlatformSpec>,
    pub objectives: Vec<ObjectiveKind>,
    /// Enable beacon-based search with this policy (None = inference-only).
    pub beacon: Option<BeaconPolicyOverrides>,
    pub ga: Nsga2Config,
    /// Island-model settings (`ga` then describes EACH island);
    /// `None` = single population.
    pub island: Option<IslandConfig>,
    /// Feasibility area width above the 16-bit baseline error (paper: 8pp).
    pub err_feasible_pp: f64,
    /// Force tied W=A genomes even without a platform that requires it.
    /// `None` defers to the platform (`tied_wa()`).
    pub tied: Option<bool>,
}

impl ExperimentSpec {
    pub fn builder() -> ExperimentSpecBuilder {
        ExperimentSpecBuilder::default()
    }

    /// Experiment 1 (§5.2): WER vs memory size, no hardware model.
    pub fn exp1() -> ExperimentSpec {
        ExperimentSpec::builder()
            .name("exp1-compression")
            .objective(ObjectiveKind::Error)
            .objective(ObjectiveKind::SizeMb)
            .generations(60)
            .build()
            .expect("exp1 preset is valid")
    }

    /// Experiment 2 (§5.3): SiLago, 3 objectives, 6 MB SRAM, tied W=A.
    pub fn exp2_silago() -> ExperimentSpec {
        ExperimentSpec::builder()
            .name("exp2-silago")
            .platform("silago")
            .sram_mb(6.0)
            .objective(ObjectiveKind::Error)
            .objective(ObjectiveKind::NegSpeedup)
            .objective(ObjectiveKind::EnergyUj)
            .generations(15)
            .build()
            .expect("exp2 preset is valid")
    }

    /// Experiment 3 (§5.4): Bitfusion, 2 MB SRAM; beacon optional.
    pub fn exp3_bitfusion(beacon: bool) -> ExperimentSpec {
        let b = ExperimentSpec::builder()
            .name(if beacon { "exp3-bitfusion-beacon" } else { "exp3-bitfusion" })
            .platform("bitfusion")
            .sram_mb(2.0)
            .objective(ObjectiveKind::Error)
            .objective(ObjectiveKind::NegSpeedup)
            .generations(60);
        let b = if beacon { b.beacon(BeaconPolicyOverrides::default()) } else { b };
        b.build().expect("exp3 preset is valid")
    }

    /// Resolve the platform reference against the registry (None when the
    /// spec has no hardware model).
    pub fn resolve_platform(&self) -> Result<Option<SharedPlatform>, SearchError> {
        match &self.platform {
            None => Ok(None),
            Some(spec) => Ok(Some(registry::resolve(spec)?)),
        }
    }

    // ------------------------------------------------------------- serde

    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = BTreeMap::new();
        obj.insert("name".into(), Json::Str(self.name.clone()));
        if let Some(p) = &self.platform {
            obj.insert("platform".into(), p.to_json());
        }
        obj.insert(
            "objectives".into(),
            Json::Arr(self.objectives.iter().map(|o| Json::Str(o.id().into())).collect()),
        );
        let mut ga: BTreeMap<String, Json> = BTreeMap::new();
        ga.insert("pop_size".into(), self.ga.pop_size.into());
        ga.insert("initial_pop_size".into(), self.ga.initial_pop_size.into());
        ga.insert("generations".into(), self.ga.generations.into());
        ga.insert("crossover_prob".into(), Json::Num(self.ga.crossover_prob));
        if let Some(pm) = self.ga.mutation_prob {
            ga.insert("mutation_prob".into(), Json::Num(pm));
        }
        // Seeds are full u64s; JSON numbers are f64 and would silently
        // corrupt values >= 2^53, so emit as a decimal string.
        ga.insert("seed".into(), Json::Str(self.ga.seed.to_string()));
        obj.insert("ga".into(), Json::Obj(ga));
        if let Some(isl) = &self.island {
            let mut im: BTreeMap<String, Json> = BTreeMap::new();
            im.insert("islands".into(), isl.islands.into());
            im.insert("migration_interval".into(), isl.migration_interval.into());
            im.insert("topology".into(), Json::Str(isl.topology.id().into()));
            im.insert("migrants".into(), isl.migrants.into());
            obj.insert("island".into(), Json::Obj(im));
        }
        if let Some(b) = &self.beacon {
            let mut bm: BTreeMap<String, Json> = BTreeMap::new();
            if let Some(t) = b.threshold {
                bm.insert("threshold".into(), Json::Num(t));
            }
            if let Some(s) = b.retrain_steps {
                bm.insert("retrain_steps".into(), s.into());
            }
            if let Some(m) = b.max_beacons {
                bm.insert("max_beacons".into(), m.into());
            }
            obj.insert("beacon".into(), Json::Obj(bm));
        }
        obj.insert("err_feasible_pp".into(), Json::Num(self.err_feasible_pp));
        if let Some(t) = self.tied {
            obj.insert("tied".into(), Json::Bool(t));
        }
        Json::Obj(obj)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse from JSON, running the same validation as the builder.
    pub fn from_json(j: &Json) -> Result<ExperimentSpec, SearchError> {
        let mut b = ExperimentSpec::builder();
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| SearchError::Config("missing 'name'".into()))?;
        b = b.name(name);

        if let Some(p) = j.get("platform") {
            let spec = PlatformSpec::from_json(p).map_err(SearchError::from)?;
            // Config-file escape hatch: {"kind": "none"} means no platform.
            if spec.name != "none" {
                b = b.platform_spec(spec);
            }
        }

        let objectives = j
            .get("objectives")
            .and_then(Json::as_arr)
            .ok_or_else(|| SearchError::Config("missing 'objectives' array".into()))?;
        for o in objectives {
            let id = o
                .as_str()
                .ok_or_else(|| SearchError::Config("objectives must be strings".into()))?;
            let kind = ObjectiveKind::from_id(id)
                .ok_or_else(|| SearchError::Config(format!("unknown objective '{id}'")))?;
            b = b.objective(kind);
        }

        if let Some(g) = j.get("ga") {
            let mut ga = Nsga2Config::default();
            if let Some(v) = g.get("pop_size").and_then(Json::as_usize) {
                ga.pop_size = v;
            }
            if let Some(v) = g.get("initial_pop_size").and_then(Json::as_usize) {
                ga.initial_pop_size = v;
            }
            if let Some(v) = g.get("generations").and_then(Json::as_usize) {
                ga.generations = v;
            }
            // Decimal-string form is canonical (lossless u64); bare JSON
            // numbers are accepted for hand-written configs.
            if let Some(s) = g.get("seed") {
                if let Some(v) = s.as_str().map(str::parse::<u64>) {
                    ga.seed = v.map_err(|e| {
                        SearchError::Config(format!("ga.seed: {e}"))
                    })?;
                } else if let Some(v) = s.as_i64() {
                    ga.seed = v as u64;
                }
            }
            if let Some(v) = g.get("crossover_prob").and_then(Json::as_f64) {
                ga.crossover_prob = v;
            }
            if let Some(v) = g.get("mutation_prob").and_then(Json::as_f64) {
                ga.mutation_prob = Some(v);
            }
            b = b.ga(ga);
        }

        if let Some(ij) = j.get("island") {
            let mut isl = IslandConfig::default();
            if let Some(v) = ij.get("islands").and_then(Json::as_usize) {
                isl.islands = v;
            }
            if let Some(v) = ij.get("migration_interval").and_then(Json::as_usize) {
                isl.migration_interval = v;
            }
            if let Some(t) = ij.get("topology").and_then(Json::as_str) {
                isl.topology = Topology::from_id(t)
                    .ok_or_else(|| SearchError::Config(format!("unknown topology '{t}'")))?;
            }
            if let Some(v) = ij.get("migrants").and_then(Json::as_usize) {
                isl.migrants = v;
            }
            b = b.island(isl);
        }

        if let Some(bj) = j.get("beacon") {
            b = b.beacon(BeaconPolicyOverrides {
                threshold: bj.get("threshold").and_then(Json::as_f64),
                retrain_steps: bj.get("retrain_steps").and_then(Json::as_usize),
                max_beacons: bj.get("max_beacons").and_then(Json::as_usize),
            });
        }

        if let Some(v) = j.get("err_feasible_pp").and_then(Json::as_f64) {
            b = b.err_feasible_pp(v);
        }
        if let Some(t) = j.get("tied").and_then(Json::as_bool) {
            b = b.tied(t);
        }
        b.build()
    }

    pub fn from_json_str(text: &str) -> Result<ExperimentSpec, SearchError> {
        let j = Json::parse(text).map_err(|e| SearchError::Config(e.to_string()))?;
        ExperimentSpec::from_json(&j)
    }
}

/// Builder collecting spec fields; all validation happens in `build()`.
#[derive(Debug, Clone, Default)]
pub struct ExperimentSpecBuilder {
    name: Option<String>,
    platform: Option<PlatformSpec>,
    pending_sram_mb: Option<f64>,
    objectives: Vec<ObjectiveKind>,
    beacon: Option<BeaconPolicyOverrides>,
    ga: Option<Nsga2Config>,
    island: Option<IslandConfig>,
    err_feasible_pp: Option<f64>,
    tied: Option<bool>,
}

impl ExperimentSpecBuilder {
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    /// Name a platform from the registry (parameters via `sram_mb` or
    /// `platform_spec` for anything richer).
    pub fn platform(mut self, name: impl Into<String>) -> Self {
        self.platform = Some(PlatformSpec::new(name));
        self
    }

    pub fn platform_spec(mut self, spec: PlatformSpec) -> Self {
        self.platform = Some(spec);
        self
    }

    /// Shorthand for the one parameter every built-in takes.
    pub fn sram_mb(mut self, mb: f64) -> Self {
        match self.platform.take() {
            Some(p) => self.platform = Some(p.with_f64("sram_mb", mb)),
            None => self.pending_sram_mb = Some(mb),
        }
        self
    }

    pub fn objective(mut self, kind: ObjectiveKind) -> Self {
        self.objectives.push(kind);
        self
    }

    pub fn beacon(mut self, overrides: BeaconPolicyOverrides) -> Self {
        self.beacon = Some(overrides);
        self
    }

    pub fn ga(mut self, ga: Nsga2Config) -> Self {
        self.ga = Some(ga);
        self
    }

    pub fn generations(mut self, n: usize) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).generations = n;
        self
    }

    pub fn pop_size(mut self, n: usize) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).pop_size = n;
        self
    }

    pub fn initial_pop_size(mut self, n: usize) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).initial_pop_size = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).seed = seed;
        self
    }

    /// Split the search into `k` islands (island-model NSGA-II). The GA
    /// settings then describe each island, so the archipelago evaluates
    /// `k * pop_size` candidates per generation.
    pub fn islands(mut self, k: usize) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).islands = k;
        self
    }

    /// Exchange elites between islands every `m` generations.
    pub fn migration_interval(mut self, m: usize) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).migration_interval = m;
        self
    }

    pub fn topology(mut self, topology: Topology) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).topology = topology;
        self
    }

    /// Elites each source island sends per migration event.
    pub fn migrants(mut self, n: usize) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).migrants = n;
        self
    }

    /// Set the whole island configuration at once (config files).
    pub fn island(mut self, cfg: IslandConfig) -> Self {
        self.island = Some(cfg);
        self
    }

    pub fn err_feasible_pp(mut self, pp: f64) -> Self {
        self.err_feasible_pp = Some(pp);
        self
    }

    pub fn tied(mut self, tied: bool) -> Self {
        self.tied = Some(tied);
        self
    }

    /// Validate and assemble. Checks: objectives present and unique,
    /// platform resolvable from the registry, hardware objectives only
    /// with a capable platform, and tied-W=A consistency (a platform that
    /// ties precisions, like SiLago, cannot be overridden to untied).
    pub fn build(self) -> Result<ExperimentSpec, SearchError> {
        if self.objectives.is_empty() {
            return Err(SearchError::invalid("at least one objective required"));
        }
        for (i, a) in self.objectives.iter().enumerate() {
            if self.objectives[..i].contains(a) {
                return Err(SearchError::invalid(format!("duplicate objective '{}'", a.id())));
            }
        }
        if self.platform.is_none() && self.pending_sram_mb.is_some() {
            return Err(SearchError::invalid("sram_mb set but no platform named"));
        }

        let platform_spec = self.platform.map(|p| match self.pending_sram_mb {
            Some(mb) if p.f64("sram_mb").is_none() => p.with_f64("sram_mb", mb),
            _ => p,
        });

        // Resolving validates the name against the registry and lets us
        // interrogate capabilities; the handle is dropped (SearchSession
        // re-resolves at run time so late registrations are honored).
        let platform = match &platform_spec {
            None => None,
            Some(spec) => Some(registry::resolve(spec)?),
        };

        for kind in &self.objectives {
            if kind.needs_platform() && platform.is_none() {
                return Err(SearchError::invalid(format!(
                    "objective '{}' requires a hardware platform",
                    kind.id()
                )));
            }
            if *kind == ObjectiveKind::EnergyUj
                && !platform.as_ref().is_some_and(|p| p.has_energy_model())
            {
                return Err(SearchError::invalid(
                    "objective 'energy_uj' requires a platform with an energy model",
                ));
            }
        }

        if let (Some(p), Some(false)) = (&platform, self.tied) {
            if p.tied_wa() {
                return Err(SearchError::invalid(format!(
                    "platform '{}' ties weight and activation precision per layer; \
                     tied(false) is not satisfiable",
                    p.name()
                )));
            }
        }

        let ga = self.ga.unwrap_or_default();
        if let Some(island) = &self.island {
            island
                .validate(ga.pop_size)
                .map_err(|e| SearchError::invalid(format!("island config: {e}")))?;
        }

        Ok(ExperimentSpec {
            name: self.name.unwrap_or_else(|| "custom".into()),
            platform: platform_spec,
            objectives: self.objectives,
            beacon: self.beacon,
            ga,
            island: self.island,
            err_feasible_pp: self.err_feasible_pp.unwrap_or(8.0),
            tied: self.tied,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_setups() {
        let e1 = ExperimentSpec::exp1();
        assert!(e1.platform.is_none());
        assert_eq!(e1.objectives, vec![ObjectiveKind::Error, ObjectiveKind::SizeMb]);
        assert_eq!(e1.ga.generations, 60);

        let e2 = ExperimentSpec::exp2_silago();
        assert_eq!(e2.platform.as_ref().unwrap().name, "silago");
        assert_eq!(e2.platform.as_ref().unwrap().f64("sram_mb"), Some(6.0));
        assert_eq!(e2.objectives.len(), 3);
        assert_eq!(e2.ga.generations, 15);

        let e3 = ExperimentSpec::exp3_bitfusion(true);
        assert!(e3.beacon.is_some());
        assert!(ExperimentSpec::exp3_bitfusion(false).beacon.is_none());
    }

    #[test]
    fn builder_rejects_invalid_combinations() {
        // No objectives.
        assert!(ExperimentSpec::builder().build().is_err());
        // Duplicate objective.
        assert!(ExperimentSpec::builder()
            .objective(ObjectiveKind::Error)
            .objective(ObjectiveKind::Error)
            .build()
            .is_err());
        // Hardware objective without platform.
        assert!(ExperimentSpec::builder()
            .objective(ObjectiveKind::NegSpeedup)
            .build()
            .is_err());
        // Energy on a platform without an energy model.
        assert!(ExperimentSpec::builder()
            .platform("bitfusion")
            .objective(ObjectiveKind::Error)
            .objective(ObjectiveKind::EnergyUj)
            .build()
            .is_err());
        // Untying a tied platform.
        assert!(ExperimentSpec::builder()
            .platform("silago")
            .objective(ObjectiveKind::Error)
            .tied(false)
            .build()
            .is_err());
        // Unknown platform surfaces the registry's helpful error.
        let err = ExperimentSpec::builder()
            .platform("tpu")
            .objective(ObjectiveKind::Error)
            .build()
            .unwrap_err();
        assert!(matches!(err, SearchError::UnknownPlatform { .. }), "{err}");
        // sram_mb without a platform.
        assert!(ExperimentSpec::builder()
            .sram_mb(4.0)
            .objective(ObjectiveKind::Error)
            .build()
            .is_err());
    }

    #[test]
    fn sram_mb_applies_before_or_after_platform() {
        let a = ExperimentSpec::builder()
            .platform("silago")
            .sram_mb(4.0)
            .objective(ObjectiveKind::Error)
            .build()
            .unwrap();
        assert_eq!(a.platform.unwrap().f64("sram_mb"), Some(4.0));
    }

    #[test]
    fn island_settings_validate_and_roundtrip() {
        let spec = ExperimentSpec::builder()
            .objective(ObjectiveKind::Error)
            .objective(ObjectiveKind::SizeMb)
            .islands(4)
            .migration_interval(3)
            .topology(Topology::FullyConnected)
            .migrants(2)
            .build()
            .unwrap();
        let isl = spec.island.as_ref().unwrap();
        assert_eq!(isl.islands, 4);
        assert_eq!(isl.migration_interval, 3);
        assert_eq!(isl.topology, Topology::FullyConnected);
        let back = ExperimentSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back, "island settings lost in JSON roundtrip");

        // migrants >= pop_size cannot be satisfied.
        let err = ExperimentSpec::builder()
            .objective(ObjectiveKind::Error)
            .pop_size(4)
            .islands(2)
            .migrants(4)
            .build()
            .unwrap_err();
        assert!(matches!(err, SearchError::InvalidSpec(_)), "{err}");

        // Zero islands / zero interval rejected.
        assert!(ExperimentSpec::builder()
            .objective(ObjectiveKind::Error)
            .islands(0)
            .build()
            .is_err());
        assert!(ExperimentSpec::builder()
            .objective(ObjectiveKind::Error)
            .islands(2)
            .migration_interval(0)
            .build()
            .is_err());

        // Unknown topology in a config file is a Config error.
        let bad = r#"{"name": "x", "objectives": ["error"],
                      "island": {"islands": 4, "topology": "torus"}}"#;
        let err = ExperimentSpec::from_json_str(bad).unwrap_err();
        assert!(matches!(err, SearchError::Config(_)), "{err}");
    }

    #[test]
    fn large_seeds_roundtrip_losslessly() {
        // f64 JSON numbers lose precision above 2^53; the string encoding
        // must carry the full u64 so a saved config reproduces its search.
        let spec = ExperimentSpec::builder()
            .objective(ObjectiveKind::Error)
            .seed(u64::MAX - 12345)
            .build()
            .unwrap();
        let back = ExperimentSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back.ga.seed, u64::MAX - 12345);
        assert_eq!(spec, back);
    }

    #[test]
    fn json_roundtrip_is_identity_for_presets() {
        for spec in [
            ExperimentSpec::exp1(),
            ExperimentSpec::exp2_silago(),
            ExperimentSpec::exp3_bitfusion(false),
            ExperimentSpec::exp3_bitfusion(true),
        ] {
            let back = ExperimentSpec::from_json_str(&spec.to_json_string()).unwrap();
            assert_eq!(spec, back, "roundtrip changed {}", spec.name);
        }
    }
}
