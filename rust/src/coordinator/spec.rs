//! Experiment specifications: what to search, on which platforms, with
//! which objectives and GA/beacon settings. Specs are built through a
//! validating builder (`ExperimentSpec::builder()`), round-trip through
//! JSON (so `mohaq search --config FILE` covers everything the presets
//! do), and name platforms by registry string — adding a backend never
//! touches this module.
//!
//! Objectives are typed [`ScoredObjective`]s (PR 4): each carries an
//! optional platform binding (`neg_speedup@silago`), the spec holds a
//! *table* of platforms, and one search can score hardware objectives
//! against several platforms at once. `build()` normalizes implicit
//! bindings (a lone platform binds every hardware objective) so the JSON
//! form is always explicit and round-trips losslessly.

use std::collections::BTreeMap;

use crate::coordinator::error::SearchError;
use crate::coordinator::objective::{BoundObjective, PlatformBinding, ScoredObjective};
use crate::hw::registry::{self, PlatformSpec, SharedPlatform};
use crate::hw::Platform;
use crate::moo::island::{IslandConfig, Topology};
use crate::moo::Nsga2Config;
use crate::util::json::Json;

/// Beacon policy knobs exposed to drivers; unset fields use paper defaults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BeaconPolicyOverrides {
    pub threshold: Option<f64>,
    pub retrain_steps: Option<usize>,
    pub max_beacons: Option<usize>,
}

/// A validated experiment description. Construct via `builder()` (or the
/// paper presets, which go through the builder); direct field edits after
/// that are the driver's responsibility.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    pub name: String,
    /// Platform binding table (registry references). Empty = no hardware
    /// model (experiment 1). EVERY listed platform contributes its SRAM
    /// constraint, whether or not an objective references it.
    pub platforms: Vec<PlatformSpec>,
    pub objectives: Vec<ScoredObjective>,
    /// Enable beacon-based search with this policy (None = inference-only).
    pub beacon: Option<BeaconPolicyOverrides>,
    pub ga: Nsga2Config,
    /// Island-model settings (`ga` then describes EACH island);
    /// `None` = single population.
    pub island: Option<IslandConfig>,
    /// Feasibility area width above the 16-bit baseline error (paper: 8pp).
    pub err_feasible_pp: f64,
    /// Force tied W=A genomes even without a platform that requires it.
    /// `None` defers to the platforms (tied if ANY bound platform ties).
    pub tied: Option<bool>,
}

impl ExperimentSpec {
    pub fn builder() -> ExperimentSpecBuilder {
        ExperimentSpecBuilder::default()
    }

    /// Experiment 1 (§5.2): WER vs memory size, no hardware model.
    pub fn exp1() -> ExperimentSpec {
        ExperimentSpec::builder()
            .name("exp1-compression")
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::size_mb())
            .generations(60)
            .build()
            .expect("exp1 preset is valid")
    }

    /// Experiment 2 (§5.3): SiLago, 3 objectives, 6 MB SRAM, tied W=A.
    pub fn exp2_silago() -> ExperimentSpec {
        ExperimentSpec::builder()
            .name("exp2-silago")
            .platform("silago")
            .sram_mb(6.0)
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::neg_speedup())
            .objective(ScoredObjective::energy_uj())
            .generations(15)
            .build()
            .expect("exp2 preset is valid")
    }

    /// Experiment 3 (§5.4): Bitfusion, 2 MB SRAM; beacon optional.
    pub fn exp3_bitfusion(beacon: bool) -> ExperimentSpec {
        let b = ExperimentSpec::builder()
            .name(if beacon { "exp3-bitfusion-beacon" } else { "exp3-bitfusion" })
            .platform("bitfusion")
            .sram_mb(2.0)
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::neg_speedup())
            .generations(60);
        let b = if beacon { b.beacon(BeaconPolicyOverrides::default()) } else { b };
        b.build().expect("exp3 preset is valid")
    }

    /// Cross-platform search: ONE front scored jointly against SiLago
    /// (6 MB DiMArch scratchpad) and Bitfusion (2 MB SRAM). The genome
    /// obeys the intersection of platform restrictions (tied W=A, no
    /// 2-bit — SiLago), both SRAM constraints apply, and the per-platform
    /// speedup objectives expose which solutions are robust across
    /// accelerators and which are specialization artifacts.
    pub fn cross_platform() -> ExperimentSpec {
        ExperimentSpec::builder()
            .name("cross-platform")
            .platform("silago")
            .sram_mb(6.0)
            .platform("bitfusion")
            .sram_mb(2.0)
            .objective(ScoredObjective::error())
            .platform_objective("silago", ScoredObjective::neg_speedup())
            .platform_objective("bitfusion", ScoredObjective::neg_speedup())
            .generations(30)
            .build()
            .expect("cross_platform preset is valid")
    }

    /// Resolve the platform table against the registry and bind every
    /// objective to its platform, ready for scoring. Re-validates binding
    /// references (spec fields are public and may have been edited after
    /// `build()`).
    pub fn resolve_objectives(
        &self,
    ) -> Result<(Vec<BoundObjective>, Vec<PlatformBinding>), SearchError> {
        let mut bindings: Vec<PlatformBinding> = Vec::with_capacity(self.platforms.len());
        for spec in &self.platforms {
            bindings.push(PlatformBinding {
                name: spec.name.clone(),
                spec: spec.clone(),
                platform: registry::resolve(spec)?,
            });
        }

        let names: Vec<&str> = self.platforms.iter().map(|p| p.name.as_str()).collect();
        let mut bound = Vec::with_capacity(self.objectives.len());
        for obj in &self.objectives {
            let binding = binding_index(obj, &names)?;
            // Auto-bound objectives (possible after direct field edits)
            // get the platform suffix in their label too, so report
            // columns always say where a hardware number came from.
            let label = match binding {
                Some(i) if obj.platform().is_none() => {
                    format!("{}@{}", obj.metric.label(), bindings[i].name)
                }
                _ => obj.label(),
            };
            bound.push(BoundObjective { label, metric: obj.metric, binding });
        }
        Ok((bound, bindings))
    }

    // ------------------------------------------------------------- serde

    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = BTreeMap::new();
        obj.insert("name".into(), Json::Str(self.name.clone()));
        if !self.platforms.is_empty() {
            obj.insert(
                "platforms".into(),
                Json::Arr(self.platforms.iter().map(PlatformSpec::to_json).collect()),
            );
        }
        obj.insert(
            "objectives".into(),
            Json::Arr(self.objectives.iter().map(|o| Json::Str(o.id())).collect()),
        );
        let mut ga: BTreeMap<String, Json> = BTreeMap::new();
        ga.insert("pop_size".into(), self.ga.pop_size.into());
        ga.insert("initial_pop_size".into(), self.ga.initial_pop_size.into());
        ga.insert("generations".into(), self.ga.generations.into());
        ga.insert("crossover_prob".into(), Json::Num(self.ga.crossover_prob));
        if let Some(pm) = self.ga.mutation_prob {
            ga.insert("mutation_prob".into(), Json::Num(pm));
        }
        // Seeds are full u64s; JSON numbers are f64 and would silently
        // corrupt values >= 2^53, so emit as a decimal string.
        ga.insert("seed".into(), Json::Str(self.ga.seed.to_string()));
        obj.insert("ga".into(), Json::Obj(ga));
        if let Some(isl) = &self.island {
            let mut im: BTreeMap<String, Json> = BTreeMap::new();
            im.insert("islands".into(), isl.islands.into());
            im.insert("migration_interval".into(), isl.migration_interval.into());
            im.insert("topology".into(), Json::Str(isl.topology.id().into()));
            im.insert("migrants".into(), isl.migrants.into());
            obj.insert("island".into(), Json::Obj(im));
        }
        if let Some(b) = &self.beacon {
            let mut bm: BTreeMap<String, Json> = BTreeMap::new();
            if let Some(t) = b.threshold {
                bm.insert("threshold".into(), Json::Num(t));
            }
            if let Some(s) = b.retrain_steps {
                bm.insert("retrain_steps".into(), s.into());
            }
            if let Some(m) = b.max_beacons {
                bm.insert("max_beacons".into(), m.into());
            }
            obj.insert("beacon".into(), Json::Obj(bm));
        }
        obj.insert("err_feasible_pp".into(), Json::Num(self.err_feasible_pp));
        if let Some(t) = self.tied {
            obj.insert("tied".into(), Json::Bool(t));
        }
        Json::Obj(obj)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse from JSON, running the same validation as the builder.
    /// Accepts the canonical `"platforms": [..]` table and, for config
    /// compatibility, the legacy singular `"platform": {..}` shape.
    pub fn from_json(j: &Json) -> Result<ExperimentSpec, SearchError> {
        let mut b = ExperimentSpec::builder();
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| SearchError::Config("missing 'name'".into()))?;
        b = b.name(name);

        if let Some(arr) = j.get("platforms").and_then(Json::as_arr) {
            for p in arr {
                b = b.platform_spec(PlatformSpec::from_json(p).map_err(SearchError::from)?);
            }
        } else if let Some(p) = j.get("platform") {
            let spec = PlatformSpec::from_json(p).map_err(SearchError::from)?;
            // Config-file escape hatch: {"kind": "none"} means no platform.
            if spec.name != "none" {
                b = b.platform_spec(spec);
            }
        }

        let objectives = j
            .get("objectives")
            .and_then(Json::as_arr)
            .ok_or_else(|| SearchError::Config("missing 'objectives' array".into()))?;
        for o in objectives {
            let id = o
                .as_str()
                .ok_or_else(|| SearchError::Config("objectives must be strings".into()))?;
            b = b.objective(ScoredObjective::parse(id)?);
        }

        if let Some(g) = j.get("ga") {
            let mut ga = Nsga2Config::default();
            if let Some(v) = g.get("pop_size").and_then(Json::as_usize) {
                ga.pop_size = v;
            }
            if let Some(v) = g.get("initial_pop_size").and_then(Json::as_usize) {
                ga.initial_pop_size = v;
            }
            if let Some(v) = g.get("generations").and_then(Json::as_usize) {
                ga.generations = v;
            }
            // Decimal-string form is canonical (lossless u64); bare JSON
            // numbers are accepted for hand-written configs.
            if let Some(s) = g.get("seed") {
                if let Some(v) = s.as_str().map(str::parse::<u64>) {
                    ga.seed = v.map_err(|e| SearchError::Config(format!("ga.seed: {e}")))?;
                } else if let Some(v) = s.as_i64() {
                    ga.seed = v as u64;
                }
            }
            if let Some(v) = g.get("crossover_prob").and_then(Json::as_f64) {
                ga.crossover_prob = v;
            }
            if let Some(v) = g.get("mutation_prob").and_then(Json::as_f64) {
                ga.mutation_prob = Some(v);
            }
            b = b.ga(ga);
        }

        if let Some(ij) = j.get("island") {
            let mut isl = IslandConfig::default();
            if let Some(v) = ij.get("islands").and_then(Json::as_usize) {
                isl.islands = v;
            }
            if let Some(v) = ij.get("migration_interval").and_then(Json::as_usize) {
                isl.migration_interval = v;
            }
            if let Some(t) = ij.get("topology").and_then(Json::as_str) {
                isl.topology = Topology::from_id(t)
                    .ok_or_else(|| SearchError::Config(format!("unknown topology '{t}'")))?;
            }
            if let Some(v) = ij.get("migrants").and_then(Json::as_usize) {
                isl.migrants = v;
            }
            b = b.island(isl);
        }

        if let Some(bj) = j.get("beacon") {
            b = b.beacon(BeaconPolicyOverrides {
                threshold: bj.get("threshold").and_then(Json::as_f64),
                retrain_steps: bj.get("retrain_steps").and_then(Json::as_usize),
                max_beacons: bj.get("max_beacons").and_then(Json::as_usize),
            });
        }

        if let Some(v) = j.get("err_feasible_pp").and_then(Json::as_f64) {
            b = b.err_feasible_pp(v);
        }
        if let Some(t) = j.get("tied").and_then(Json::as_bool) {
            b = b.tied(t);
        }
        b.build()
    }

    pub fn from_json_str(text: &str) -> Result<ExperimentSpec, SearchError> {
        let j = Json::parse(text).map_err(|e| SearchError::Config(e.to_string()))?;
        ExperimentSpec::from_json(&j)
    }
}

/// Resolve one objective's binding to an index into the platform-name
/// table, applying the lone-platform implicit rule. Shared by `build()`
/// (which then writes the binding back explicitly) and
/// `resolve_objectives()` (re-validating possibly field-edited specs), so
/// the two paths cannot drift.
fn binding_index(obj: &ScoredObjective, names: &[&str]) -> Result<Option<usize>, SearchError> {
    if !obj.needs_platform() {
        return match obj.platform() {
            Some(name) => Err(SearchError::invalid(format!(
                "objective '{}' is platform-independent; drop the '@{name}' binding",
                obj.metric.id()
            ))),
            None => Ok(None),
        };
    }
    if let Some(name) = obj.platform() {
        return match names.iter().position(|n| *n == name) {
            Some(i) => Ok(Some(i)),
            None => Err(SearchError::invalid(format!(
                "objective '{}' names a platform outside the spec's table (platforms: {})",
                obj.id(),
                if names.is_empty() { "none".to_string() } else { names.join(", ") }
            ))),
        };
    }
    match names.len() {
        1 => Ok(Some(0)),
        0 => Err(SearchError::invalid(format!(
            "objective '{}' requires a hardware platform",
            obj.id()
        ))),
        _ => Err(SearchError::invalid(format!(
            "objective '{}' is ambiguous with {} platforms; bind it explicitly, e.g. '{}@{}'",
            obj.id(),
            names.len(),
            obj.id(),
            names[0]
        ))),
    }
}

/// Builder collecting spec fields; all validation happens in `build()`.
#[derive(Debug, Clone, Default)]
pub struct ExperimentSpecBuilder {
    name: Option<String>,
    platforms: Vec<PlatformSpec>,
    pending_sram_mb: Option<f64>,
    objectives: Vec<ScoredObjective>,
    beacon: Option<BeaconPolicyOverrides>,
    ga: Option<Nsga2Config>,
    island: Option<IslandConfig>,
    err_feasible_pp: Option<f64>,
    tied: Option<bool>,
}

impl ExperimentSpecBuilder {
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    /// Add a platform from the registry to the spec's platform table
    /// (parameters via `sram_mb` or `platform_spec` for anything richer).
    /// Call repeatedly for a cross-platform search.
    pub fn platform(mut self, name: impl Into<String>) -> Self {
        self.platforms.push(PlatformSpec::new(name));
        self
    }

    pub fn platform_spec(mut self, spec: PlatformSpec) -> Self {
        self.platforms.push(spec);
        self
    }

    /// Shorthand for the one parameter every built-in takes; applies to
    /// the most recently added platform.
    pub fn sram_mb(mut self, mb: f64) -> Self {
        match self.platforms.pop() {
            Some(p) => self.platforms.push(p.with_f64("sram_mb", mb)),
            None => self.pending_sram_mb = Some(mb),
        }
        self
    }

    pub fn objective(mut self, objective: ScoredObjective) -> Self {
        self.objectives.push(objective);
        self
    }

    /// Add `objective` bound to `platform`, adding the platform to the
    /// table if it isn't there yet — the cross-platform building block.
    pub fn platform_objective(
        mut self,
        platform: impl Into<String>,
        objective: ScoredObjective,
    ) -> Self {
        let name = platform.into().to_lowercase();
        if !self.platforms.iter().any(|p| p.name == name) {
            self.platforms.push(PlatformSpec::new(name.clone()));
        }
        self.objectives.push(objective.on(name));
        self
    }

    pub fn beacon(mut self, overrides: BeaconPolicyOverrides) -> Self {
        self.beacon = Some(overrides);
        self
    }

    pub fn ga(mut self, ga: Nsga2Config) -> Self {
        self.ga = Some(ga);
        self
    }

    pub fn generations(mut self, n: usize) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).generations = n;
        self
    }

    pub fn pop_size(mut self, n: usize) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).pop_size = n;
        self
    }

    pub fn initial_pop_size(mut self, n: usize) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).initial_pop_size = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.ga.get_or_insert_with(Nsga2Config::default).seed = seed;
        self
    }

    /// Split the search into `k` islands (island-model NSGA-II). The GA
    /// settings then describe each island, so the archipelago evaluates
    /// `k * pop_size` candidates per generation.
    pub fn islands(mut self, k: usize) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).islands = k;
        self
    }

    /// Exchange elites between islands every `m` generations.
    pub fn migration_interval(mut self, m: usize) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).migration_interval = m;
        self
    }

    pub fn topology(mut self, topology: Topology) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).topology = topology;
        self
    }

    /// Elites each source island sends per migration event.
    pub fn migrants(mut self, n: usize) -> Self {
        self.island.get_or_insert_with(IslandConfig::default).migrants = n;
        self
    }

    /// Set the whole island configuration at once (config files).
    pub fn island(mut self, cfg: IslandConfig) -> Self {
        self.island = Some(cfg);
        self
    }

    pub fn err_feasible_pp(mut self, pp: f64) -> Self {
        self.err_feasible_pp = Some(pp);
        self
    }

    pub fn tied(mut self, tied: bool) -> Self {
        self.tied = Some(tied);
        self
    }

    /// Validate and assemble. Checks: objectives present and unique (after
    /// binding normalization), platform table free of duplicates and
    /// resolvable from the registry, hardware objectives bound to a
    /// capable platform (energy needs an energy model; a lone platform
    /// binds implicitly, several demand explicit '@platform' bindings),
    /// and tied-W=A consistency (a table containing a tying platform,
    /// like SiLago, cannot be overridden to untied).
    pub fn build(self) -> Result<ExperimentSpec, SearchError> {
        if self.objectives.is_empty() {
            return Err(SearchError::invalid("at least one objective required"));
        }

        let mut platforms = self.platforms;
        if let Some(mb) = self.pending_sram_mb {
            match platforms.first_mut() {
                Some(p) if p.f64("sram_mb").is_none() => {
                    *p = p.clone().with_f64("sram_mb", mb);
                }
                Some(_) => {}
                None => return Err(SearchError::invalid("sram_mb set but no platform named")),
            }
        }
        for i in 1..platforms.len() {
            if platforms[..i].iter().any(|q| q.name == platforms[i].name) {
                return Err(SearchError::invalid(format!(
                    "platform '{}' appears twice in the platform table",
                    platforms[i].name
                )));
            }
        }

        // Platforms referenced by explicit bindings join the table FIRST
        // (`platform_objective` adds them; a hand-built `.on("silago")`
        // gets default parameters here), so the implicit-binding rule
        // below sees the complete table.
        let mut objectives = self.objectives;
        for obj in &objectives {
            if let Some(name) = obj.platform() {
                if obj.needs_platform() && !platforms.iter().any(|p| p.name == name) {
                    platforms.push(PlatformSpec::new(name));
                }
            }
        }

        // Normalize bindings: a lone platform binds every hardware
        // objective explicitly (so the JSON form is always labeled);
        // several platforms demand explicit bindings.
        let names: Vec<String> = platforms.iter().map(|p| p.name.clone()).collect();
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        for obj in &mut objectives {
            if let Some(i) = binding_index(obj, &name_refs)? {
                if obj.platform().is_none() {
                    obj.binding = Some(names[i].clone());
                }
            }
        }

        for (i, a) in objectives.iter().enumerate() {
            if objectives[..i].contains(a) {
                return Err(SearchError::invalid(format!("duplicate objective '{}'", a.id())));
            }
        }

        // Resolving validates every name against the registry and lets us
        // interrogate capabilities; the handles are dropped (SearchSession
        // re-resolves at run time so late registrations are honored).
        let mut resolved: Vec<SharedPlatform> = Vec::with_capacity(platforms.len());
        for spec in &platforms {
            resolved.push(registry::resolve(spec)?);
        }

        for obj in &objectives {
            if !obj.needs_energy_model() {
                continue;
            }
            let name = obj.platform().expect("hardware objectives normalized above");
            let idx = platforms
                .iter()
                .position(|p| p.name == name)
                .expect("bound platforms added to the table above");
            if !resolved[idx].has_energy_model() {
                return Err(SearchError::invalid(format!(
                    "objective '{}' requires a platform with an energy model",
                    obj.id()
                )));
            }
        }

        if self.tied == Some(false) {
            for p in &resolved {
                if p.tied_wa() {
                    return Err(SearchError::invalid(format!(
                        "platform '{}' ties weight and activation precision per layer; \
                         tied(false) is not satisfiable",
                        p.name()
                    )));
                }
            }
        }

        let ga = self.ga.unwrap_or_default();
        if let Some(island) = &self.island {
            island
                .validate(ga.pop_size)
                .map_err(|e| SearchError::invalid(format!("island config: {e}")))?;
        }

        Ok(ExperimentSpec {
            name: self.name.unwrap_or_else(|| "custom".into()),
            platforms,
            objectives,
            beacon: self.beacon,
            ga,
            island: self.island,
            err_feasible_pp: self.err_feasible_pp.unwrap_or(8.0),
            tied: self.tied,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_setups() {
        let e1 = ExperimentSpec::exp1();
        assert!(e1.platforms.is_empty());
        assert_eq!(e1.objectives, vec![ScoredObjective::error(), ScoredObjective::size_mb()]);
        assert_eq!(e1.ga.generations, 60);

        let e2 = ExperimentSpec::exp2_silago();
        assert_eq!(e2.platforms[0].name, "silago");
        assert_eq!(e2.platforms[0].f64("sram_mb"), Some(6.0));
        assert_eq!(e2.objectives.len(), 3);
        // The lone platform binds hardware objectives explicitly.
        assert_eq!(e2.objectives[1].id(), "neg_speedup@silago");
        assert_eq!(e2.objectives[2].id(), "energy_uj@silago");
        assert_eq!(e2.ga.generations, 15);

        let e3 = ExperimentSpec::exp3_bitfusion(true);
        assert!(e3.beacon.is_some());
        assert!(ExperimentSpec::exp3_bitfusion(false).beacon.is_none());
    }

    #[test]
    fn cross_platform_preset_binds_both_platforms() {
        let spec = ExperimentSpec::cross_platform();
        let names: Vec<&str> = spec.platforms.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["silago", "bitfusion"]);
        assert_eq!(spec.platforms[0].f64("sram_mb"), Some(6.0));
        assert_eq!(spec.platforms[1].f64("sram_mb"), Some(2.0));
        let ids: Vec<String> = spec.objectives.iter().map(ScoredObjective::id).collect();
        assert_eq!(ids, ["error", "neg_speedup@silago", "neg_speedup@bitfusion"]);

        let (bound, bindings) = spec.resolve_objectives().unwrap();
        assert_eq!(bindings.len(), 2);
        let labels: Vec<&str> = bound.iter().map(|o| o.label.as_str()).collect();
        assert_eq!(labels, ["WER_V", "-speedup@silago", "-speedup@bitfusion"]);
        assert_eq!(bound[1].platform(&bindings), Some("silago"));
        assert_eq!(bound[2].platform(&bindings), Some("bitfusion"));
        // SiLago in the table forces the tied genome at session time.
        assert!(bindings.iter().any(|b| b.platform.tied_wa()));
    }

    #[test]
    fn builder_rejects_invalid_combinations() {
        // No objectives.
        assert!(ExperimentSpec::builder().build().is_err());
        // Duplicate objective.
        assert!(ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::error())
            .build()
            .is_err());
        // Hardware objective without platform.
        assert!(ExperimentSpec::builder()
            .objective(ScoredObjective::neg_speedup())
            .build()
            .is_err());
        // Energy on a platform without an energy model.
        assert!(ExperimentSpec::builder()
            .platform("bitfusion")
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::energy_uj())
            .build()
            .is_err());
        // Untying a tied platform.
        assert!(ExperimentSpec::builder()
            .platform("silago")
            .objective(ScoredObjective::error())
            .tied(false)
            .build()
            .is_err());
        // Unknown platform surfaces the registry's helpful error.
        let err = ExperimentSpec::builder()
            .platform("tpu")
            .objective(ScoredObjective::error())
            .build()
            .unwrap_err();
        assert!(matches!(err, SearchError::UnknownPlatform { .. }), "{err}");
        // sram_mb without a platform.
        assert!(ExperimentSpec::builder()
            .sram_mb(4.0)
            .objective(ScoredObjective::error())
            .build()
            .is_err());
    }

    #[test]
    fn multi_platform_bindings_validate() {
        // Unbound hardware objective with two platforms is ambiguous.
        let err = ExperimentSpec::builder()
            .platform("silago")
            .platform("bitfusion")
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::neg_speedup())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("ambiguous"), "{err}");

        // Platform-independent objectives reject bindings.
        let err = ExperimentSpec::builder()
            .platform("silago")
            .objective(ScoredObjective::error().on("silago"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("platform-independent"), "{err}");

        // A binding outside the table auto-adds the platform...
        let spec = ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::neg_speedup().on("bitfusion"))
            .build()
            .unwrap();
        assert_eq!(spec.platforms.len(), 1);
        assert_eq!(spec.platforms[0].name, "bitfusion");

        // ...and does so BEFORE the implicit-binding rule runs, so a bare
        // hardware objective binds to the lone binding-implied platform
        // regardless of objective order.
        let spec = ExperimentSpec::builder()
            .objective(ScoredObjective::neg_speedup())
            .objective(ScoredObjective::energy_uj().on("silago"))
            .build()
            .unwrap();
        assert_eq!(spec.objectives[0].id(), "neg_speedup@silago");

        // ...but an unknown registry name still fails to resolve.
        let err = ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::neg_speedup().on("tpu"))
            .build()
            .unwrap_err();
        assert!(matches!(err, SearchError::UnknownPlatform { .. }), "{err}");

        // Duplicate platform table entries are rejected.
        let err = ExperimentSpec::builder()
            .platform("silago")
            .platform("silago")
            .objective(ScoredObjective::error())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("twice"), "{err}");

        // The same metric bound to two platforms is NOT a duplicate.
        let spec = ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .platform_objective("silago", ScoredObjective::neg_speedup())
            .platform_objective("bitfusion", ScoredObjective::neg_speedup())
            .build()
            .unwrap();
        assert_eq!(spec.objectives.len(), 3);
        // But binding it twice to the SAME platform is.
        let err = ExperimentSpec::builder()
            .platform_objective("silago", ScoredObjective::neg_speedup())
            .platform_objective("silago", ScoredObjective::neg_speedup())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn resolve_objectives_revalidates_edited_specs() {
        let mut spec = ExperimentSpec::cross_platform();
        // A driver edit pointing an objective at a platform that was
        // dropped from the table is caught at resolve time.
        spec.platforms.retain(|p| p.name != "bitfusion");
        let err = spec.resolve_objectives().unwrap_err();
        assert!(err.to_string().contains("outside the spec's table"), "{err}");
    }

    #[test]
    fn sram_mb_applies_before_or_after_platform() {
        let a = ExperimentSpec::builder()
            .platform("silago")
            .sram_mb(4.0)
            .objective(ScoredObjective::error())
            .build()
            .unwrap();
        assert_eq!(a.platforms[0].f64("sram_mb"), Some(4.0));

        // Per-platform: each sram_mb call binds to the latest platform.
        let b = ExperimentSpec::builder()
            .platform("silago")
            .sram_mb(4.0)
            .platform("bitfusion")
            .sram_mb(1.5)
            .objective(ScoredObjective::error())
            .build()
            .unwrap();
        assert_eq!(b.platforms[0].f64("sram_mb"), Some(4.0));
        assert_eq!(b.platforms[1].f64("sram_mb"), Some(1.5));
    }

    #[test]
    fn island_settings_validate_and_roundtrip() {
        let spec = ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .objective(ScoredObjective::size_mb())
            .islands(4)
            .migration_interval(3)
            .topology(Topology::FullyConnected)
            .migrants(2)
            .build()
            .unwrap();
        let isl = spec.island.as_ref().unwrap();
        assert_eq!(isl.islands, 4);
        assert_eq!(isl.migration_interval, 3);
        assert_eq!(isl.topology, Topology::FullyConnected);
        let back = ExperimentSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(spec, back, "island settings lost in JSON roundtrip");

        // migrants >= pop_size cannot be satisfied.
        let err = ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .pop_size(4)
            .islands(2)
            .migrants(4)
            .build()
            .unwrap_err();
        assert!(matches!(err, SearchError::InvalidSpec(_)), "{err}");

        // Zero islands / zero interval rejected.
        assert!(ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .islands(0)
            .build()
            .is_err());
        assert!(ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .islands(2)
            .migration_interval(0)
            .build()
            .is_err());

        // Unknown topology in a config file is a Config error.
        let bad = r#"{"name": "x", "objectives": ["error"],
                      "island": {"islands": 4, "topology": "torus"}}"#;
        let err = ExperimentSpec::from_json_str(bad).unwrap_err();
        assert!(matches!(err, SearchError::Config(_)), "{err}");
    }

    #[test]
    fn large_seeds_roundtrip_losslessly() {
        // f64 JSON numbers lose precision above 2^53; the string encoding
        // must carry the full u64 so a saved config reproduces its search.
        let spec = ExperimentSpec::builder()
            .objective(ScoredObjective::error())
            .seed(u64::MAX - 12345)
            .build()
            .unwrap();
        let back = ExperimentSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back.ga.seed, u64::MAX - 12345);
        assert_eq!(spec, back);
    }

    #[test]
    fn json_roundtrip_is_identity_for_presets() {
        for spec in [
            ExperimentSpec::exp1(),
            ExperimentSpec::exp2_silago(),
            ExperimentSpec::exp3_bitfusion(false),
            ExperimentSpec::exp3_bitfusion(true),
            ExperimentSpec::cross_platform(),
        ] {
            let back = ExperimentSpec::from_json_str(&spec.to_json_string()).unwrap();
            assert_eq!(spec, back, "roundtrip changed {}", spec.name);
        }
    }

    #[test]
    fn platform_bound_objectives_roundtrip_with_parameters() {
        // Explicit bindings + per-platform parameters survive the trip.
        let spec = ExperimentSpec::builder()
            .name("joint")
            .platform_spec(PlatformSpec::new("silago").with_f64("sram_mb", 4.5))
            .platform_spec(PlatformSpec::new("bitfusion").with_f64("sram_mb", 1.5))
            .objective(ScoredObjective::error())
            .platform_objective("silago", ScoredObjective::neg_speedup())
            .platform_objective("silago", ScoredObjective::energy_uj())
            .platform_objective("bitfusion", ScoredObjective::neg_speedup())
            .build()
            .unwrap();
        let json = spec.to_json_string();
        assert!(json.contains("neg_speedup@silago"), "{json}");
        assert!(json.contains("energy_uj@silago"), "{json}");
        assert!(json.contains("neg_speedup@bitfusion"), "{json}");
        let back = ExperimentSpec::from_json_str(&json).unwrap();
        assert_eq!(spec, back, "platform-bound objectives lost in roundtrip:\n{json}");
        assert_eq!(back.platforms[0].f64("sram_mb"), Some(4.5));
        assert_eq!(back.platforms[1].f64("sram_mb"), Some(1.5));
    }
}
